#!/usr/bin/env python3
"""Perf-trajectory gate over the committed BENCH_*.json files.

The repo commits one BENCH_<n>.json per perf-bearing PR
(tools/bench_capture.sh). Until now CI only parse-checked them, so the
19-query-sweep trajectory could silently regress. This tool compares the
newest capture against the *best* prior value of every same-named entry
and fails on a >10% regression.

Gating policy: entries whose name contains "sweep" (the all-19 TPC-H
sweep rows, the whole point of the trajectory) gate the build; all other
entries — e.g. the kernel/* python-mirror microbenchmarks, whose
wall-clock jitters with the capture host — are compared advisorily and
only print. Projection entries (a "claim" without a numeric metric,
committed when the capture host had no Rust toolchain) never gate, but
they do appear in the summary table as "-" rows so the serving
(p50/p99/qps) and durability trajectory stays visible in the CI log
until a toolchain host replaces them with measured values.

Besides the gate verdicts, the tool prints a markdown newest-vs-best
summary table (one row per compared metric) so the CI log carries a
skimmable perf trajectory; the table is informational and changes no
gating behaviour.

Usage: python3 tools/bench_compare.py [--tolerance 0.10] [--strict]
  --strict   gate every entry, not just sweep entries
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# metric key -> direction ("lower" = smaller is better)
METRICS = {
    "ms_per_iter": "lower",
    "ms": "lower",
    "wall_ms": "lower",
    "wall_s": "lower",
    "p50_ms": "lower",
    "p99_ms": "lower",
    "ns_per_row": "lower",
    "cycles": "lower",
    "cycles_total": "lower",
    "scan_steps": "lower",
    "instructions": "lower",
    "ratio": "higher",
    "speedup": "higher",
    "qps": "higher",
    "rows_per_s": "higher",
}


def load_captures(root: str):
    caps = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if not m:
            continue
        with open(path) as f:
            doc = json.load(f)
        caps.append((int(m.group(1)), os.path.basename(path), doc))
    caps.sort()
    return caps


def numeric_metrics(entry: dict):
    for key, direction in METRICS.items():
        v = entry.get(key)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            yield key, direction, float(v)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 0.10)")
    ap.add_argument("--strict", action="store_true",
                    help="gate every entry, not just sweep entries")
    ap.add_argument("--root", default=os.path.join(os.path.dirname(__file__), ".."))
    args = ap.parse_args()

    caps = load_captures(args.root)
    if len(caps) < 2:
        print(f"bench_compare: {len(caps)} capture(s) committed, nothing to compare")
        return 0

    newest_issue, newest_name, newest = caps[-1]
    # best prior value per (entry name, metric key) across all older files
    best: dict = {}
    for issue, fname, doc in caps[:-1]:
        for entry in doc.get("entries", []):
            for key, direction, v in numeric_metrics(entry):
                k = (entry["name"], key)
                if k not in best:
                    best[k] = (v, fname)
                else:
                    b, _ = best[k]
                    if (direction == "lower") == (v < b):
                        best[k] = (v, fname)

    failures = []
    compared = 0
    rows = []  # (entry, metric, newest, best prior, source, delta, verdict)
    for entry in newest.get("entries", []):
        gate = args.strict or "sweep" in entry["name"]
        metrics = list(numeric_metrics(entry))
        if not metrics and "claim" in entry:
            # projection-only entry: surface it in the table (never
            # compared, never gated) so the serving/durability
            # trajectory is visible before a measured capture lands
            rows.append((entry["name"], "claim", None, None, "-", None,
                         "projection"))
            continue
        for key, direction, v in metrics:
            prior = best.get((entry["name"], key))
            if prior is None:
                rows.append((entry["name"], key, v, None, "-", None, "new"))
                continue
            b, bfname = prior
            compared += 1
            if direction == "lower":
                regressed = b > 0 and v > b * (1 + args.tolerance)
                delta = (v - b) / b if b else 0.0
            else:
                regressed = v < b * (1 - args.tolerance)
                delta = (b - v) / b if b else 0.0
            tag = "GATED" if gate else "advisory"
            verdict = "REGRESSED" if regressed else "ok"
            print(f"[{tag}] {entry['name']}.{key}: {v:g} vs best prior "
                  f"{b:g} ({bfname}) -> {verdict} ({delta:+.1%} worse)"
                  if regressed else
                  f"[{tag}] {entry['name']}.{key}: {v:g} vs best prior "
                  f"{b:g} ({bfname}) -> ok")
            if regressed and gate:
                failures.append(f"{entry['name']}.{key}: {v:g} is "
                                f"{delta:+.1%} worse than {b:g} ({bfname})")
            rows.append((entry["name"], key, v, b, bfname, delta, verdict))

    if rows:
        print(f"\n### Bench summary: {newest_name} vs best prior\n")
        print("| entry | metric | newest | best prior | from | delta | verdict |")
        print("|---|---|---:|---:|---|---:|---|")
        for name, key, v, b, src, delta, verdict in rows:
            newest_cell = f"{v:g}" if v is not None else "-"
            prior_cell = f"{b:g}" if b is not None else "-"
            delta_cell = f"{delta:+.1%}" if delta is not None else "-"
            print(f"| {name} | {key} | {newest_cell} | {prior_cell} | {src} "
                  f"| {delta_cell} | {verdict} |")
        print()

    print(f"bench_compare: {newest_name} vs {len(caps) - 1} prior capture(s), "
          f"{compared} metric(s) compared, {len(failures)} gated regression(s)")
    if failures:
        for f in failures:
            print(f"::error::perf regression: {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
