#!/usr/bin/env python3
"""Microbenchmark of the bit-plane kernel word layouts.

Measures the per-plane inner loop of the functional engine in both word
layouts — the pre-change ``[u32; 32]`` and the current ``[u64; 16]``
(rust/src/util/bits.rs) — on the same 1024-row planes, mirroring
``exec_instr``'s AND/OR/XOR/compare word loops. The work per word is
identical; the u64 layout halves the word count per plane, so the
measured ratio is the layout's kernel-level speedup independent of the
host language. Emits ``BENCH {...}`` json lines compatible with
tools/bench_capture.sh.

Usage: python3 tools/kernel_bench.py [--json]
"""

from __future__ import annotations

import json
import sys
import time

ROWS = 1024
PLANES = 32  # one 32-bit column's worth of planes
COLS = 64  # distinct columns per iteration, keeps data out of registers
REPS = 40


def make_planes(words: int, bits: int, seed: int) -> list[list[int]]:
    """COLS*PLANES planes of `words` words of `bits` bits each (xorshift)."""
    mask = (1 << bits) - 1
    x = seed | 1
    out = []
    for _ in range(COLS * PLANES):
        plane = []
        for _ in range(words):
            x ^= (x << 13) & ((1 << 64) - 1)
            x ^= x >> 7
            x ^= (x << 17) & ((1 << 64) - 1)
            plane.append(x & mask)
        out.append(plane)
    return out


def kernel_pass(a: list[list[int]], b: list[list[int]], words: int, mask: int) -> int:
    """One AND + OR + XOR + carry-chain sweep over every plane pair —
    the op mix of a compare-plus-accumulate program step."""
    acc = 0
    for pa, pb in zip(a, b):
        carry = 0
        for w in range(words):
            x = pa[w]
            y = pb[w]
            n = x & y
            o = x | y
            e = x ^ y
            s = (e ^ carry) & mask
            carry = (n | (e & carry)) >> (mask.bit_length() - 1)
            acc ^= n ^ o ^ s
    return acc


def time_layout(words: int, bits: int) -> float:
    mask = (1 << bits) - 1
    a = make_planes(words, bits, 0x9E3779B9)
    b = make_planes(words, bits, 0x85EBCA6B)
    kernel_pass(a, b, words, mask)  # warmup
    t0 = time.perf_counter()
    sink = 0
    for _ in range(REPS):
        sink ^= kernel_pass(a, b, words, mask)
    dt = time.perf_counter() - t0
    assert sink is not None
    return dt / REPS


def main() -> None:
    as_json = "--json" in sys.argv[1:]
    t32 = time_layout(words=32, bits=32)
    t64 = time_layout(words=16, bits=64)
    ratio = t32 / t64
    rows = [
        {"name": "kernel/u32x32-layout", "ms_per_iter": round(t32 * 1e3, 3)},
        {"name": "kernel/u64x16-layout", "ms_per_iter": round(t64 * 1e3, 3)},
        {"name": "kernel/u64-over-u32-speedup", "ratio": round(ratio, 2)},
    ]
    for r in rows:
        if as_json:
            print("BENCH " + json.dumps(r, separators=(",", ":")))
        else:
            print(r)


if __name__ == "__main__":
    main()
