#!/usr/bin/env python3
"""Microbenchmark of the bit-plane kernel word layouts.

Measures the per-plane inner loop of the functional engine in both word
layouts — the pre-change ``[u32; 32]`` and the current ``[u64; 16]``
(rust/src/util/bits.rs) — on the same 1024-row planes, mirroring
``exec_instr``'s AND/OR/XOR/compare word loops. The work per word is
identical; the u64 layout halves the word count per plane, so the
measured ratio is the layout's kernel-level speedup independent of the
host language. Emits ``BENCH {...}`` json lines compatible with
tools/bench_capture.sh.

Usage: python3 tools/kernel_bench.py [--json]
"""

from __future__ import annotations

import json
import sys
import time

ROWS = 1024
PLANES = 32  # one 32-bit column's worth of planes
COLS = 64  # distinct columns per iteration, keeps data out of registers
REPS = 40


def make_planes(words: int, bits: int, seed: int) -> list[list[int]]:
    """COLS*PLANES planes of `words` words of `bits` bits each (xorshift)."""
    mask = (1 << bits) - 1
    x = seed | 1
    out = []
    for _ in range(COLS * PLANES):
        plane = []
        for _ in range(words):
            x ^= (x << 13) & ((1 << 64) - 1)
            x ^= x >> 7
            x ^= (x << 17) & ((1 << 64) - 1)
            plane.append(x & mask)
        out.append(plane)
    return out


def kernel_pass(a: list[list[int]], b: list[list[int]], words: int, mask: int) -> int:
    """One AND + OR + XOR + carry-chain sweep over every plane pair —
    the op mix of a compare-plus-accumulate program step."""
    acc = 0
    for pa, pb in zip(a, b):
        carry = 0
        for w in range(words):
            x = pa[w]
            y = pb[w]
            n = x & y
            o = x | y
            e = x ^ y
            s = (e ^ carry) & mask
            carry = (n | (e & carry)) >> (mask.bit_length() - 1)
            acc ^= n ^ o ^ s
    return acc


def time_layout(words: int, bits: int) -> float:
    mask = (1 << bits) - 1
    a = make_planes(words, bits, 0x9E3779B9)
    b = make_planes(words, bits, 0x85EBCA6B)
    kernel_pass(a, b, words, mask)  # warmup
    t0 = time.perf_counter()
    sink = 0
    for _ in range(REPS):
        sink ^= kernel_pass(a, b, words, mask)
    dt = time.perf_counter() - t0
    assert sink is not None
    return dt / REPS


def scan_prefix(cols: list[list[int]], valid: list[int], imm: int,
                words: int, mask: int) -> list[int]:
    """One filter prefix at word granularity: a bit-serial less-than
    compare chain over the attribute's planes (exec_instr's cmp_imm), an
    AND with the valid plane — the shape the fusion pass shares."""
    eq = [mask] * words
    lt = [0] * words
    for i in reversed(range(len(cols))):
        p = cols[i]
        if (imm >> i) & 1:
            for w in range(words):
                lt[w] |= eq[w] & ~p[w] & mask
                eq[w] &= p[w]
        else:
            for w in range(words):
                eq[w] &= ~p[w] & mask
    return [lt[w] & valid[w] for w in range(words)]


BATCH = 8  # members per batch
DISTINCT = 4  # distinct filter prefixes among them (2-way sharing)


def time_batch_scan(fused: bool) -> float:
    """An 8-member batch whose members repeat 4 distinct filter prefixes
    over one attribute. Serial runs every member's prefix; fused runs
    each distinct prefix once (the cross-query CSE of
    rust/src/query/opt/fusion.rs dedups the whole prefix), so the ratio
    is the kernel-level scan-work reduction at this sharing factor."""
    words, bits = 16, 64
    mask = (1 << bits) - 1
    cols = make_planes(words, bits, 0xC0FFEE)[:PLANES]
    valid = make_planes(words, bits, 0x5EED)[0]
    imms = [(q % DISTINCT) * 977 + 13 for q in range(BATCH)]
    todo = sorted(set(imms)) if fused else imms
    # a pass is ~100x cheaper than the layout sweeps; more reps for a
    # stable ratio
    reps = REPS * 8

    def one_pass() -> int:
        acc = 0
        for imm in todo:
            out = scan_prefix(cols, valid, imm, words, mask)
            acc ^= out[0]
        return acc

    one_pass()  # warmup
    t0 = time.perf_counter()
    sink = 0
    for _ in range(reps):
        sink ^= one_pass()
    dt = time.perf_counter() - t0
    assert sink is not None
    return dt / reps


XBARS = 16  # shards in the pruning microbench
DISJOINT = 12  # shards whose zone maps prove the filter selects nothing


def time_pruned_scan(mode: str) -> float:
    """A selective two-predicate AND filter over ``XBARS`` shards of
    which ``DISJOINT`` provably match nothing. Three execution modes
    mirror the three consumption levels of the statistics subsystem
    (rust/src/query/opt/prune.rs):

    - ``full``       — scan every shard, both predicates (no stats);
    - ``shortcut``   — scan every shard but abandon the second
      predicate when the first mask comes back all-zero (the runtime
      popcount-is-zero short-circuit);
    - ``pruned``     — consult a precomputed skip bitmap and never
      dispatch the disjoint shards at all (plan-time zone-map pruning).

    Disjoint shards run the same compare shape with an immediate of 0
    (a less-than no row satisfies), so per-prefix work is identical
    across shards and the measured ratios isolate the scheduling
    effect.
    """
    words, bits = 16, 64
    mask = (1 << bits) - 1
    shards = []
    for x in range(XBARS):
        cols_a = make_planes(words, bits, 0xBEEF01 + x)[:PLANES]
        cols_b = make_planes(words, bits, 0xFACE01 + x)[:PLANES]
        valid = make_planes(words, bits, 0x5EED01 + x)[0]
        disjoint = x < DISJOINT
        imm = 0 if disjoint else 977 * 2 + 13  # lt 0 matches nothing
        shards.append((cols_a, cols_b, valid, imm, disjoint))
    skip = [d for (_, _, _, _, d) in shards]  # the plan-time bitmap
    reps = REPS * 2

    def one_pass() -> int:
        acc = 0
        for x, (ca, cb, valid, imm, _) in enumerate(shards):
            if mode == "pruned" and skip[x]:
                continue
            m1 = scan_prefix(ca, valid, imm, words, mask)
            if mode == "shortcut" and not any(m1):
                continue
            m2 = scan_prefix(cb, valid, 977 + 13, words, mask)
            acc ^= m1[0] ^ m2[0]
        return acc

    one_pass()  # warmup
    t0 = time.perf_counter()
    sink = 0
    for _ in range(reps):
        sink ^= one_pass()
    dt = time.perf_counter() - t0
    assert sink is not None
    return dt / reps


def main() -> None:
    as_json = "--json" in sys.argv[1:]
    t32 = time_layout(words=32, bits=32)
    t64 = time_layout(words=16, bits=64)
    ratio = t32 / t64
    ts = time_batch_scan(fused=False)
    tf = time_batch_scan(fused=True)
    tu = time_pruned_scan("full")
    tc = time_pruned_scan("shortcut")
    tp = time_pruned_scan("pruned")
    rows = [
        {"name": "kernel/u32x32-layout", "ms_per_iter": round(t32 * 1e3, 3)},
        {"name": "kernel/u64x16-layout", "ms_per_iter": round(t64 * 1e3, 3)},
        {"name": "kernel/u64-over-u32-speedup", "ratio": round(ratio, 2)},
        {"name": "kernel/scan-serial-8q", "ms_per_iter": round(ts * 1e3, 3)},
        {"name": "kernel/scan-fused-8q", "ms_per_iter": round(tf * 1e3, 3)},
        {"name": "kernel/fused-over-serial-speedup", "ratio": round(ts / tf, 2)},
        {"name": "kernel/scan-unpruned-16shard", "ms_per_iter": round(tu * 1e3, 3)},
        {"name": "kernel/scan-shortcircuit-16shard", "ms_per_iter": round(tc * 1e3, 3)},
        {"name": "kernel/scan-pruned-16shard", "ms_per_iter": round(tp * 1e3, 3)},
        {"name": "kernel/shortcircuit-over-unpruned-speedup", "ratio": round(tu / tc, 2)},
        {"name": "kernel/pruned-over-unpruned-speedup", "ratio": round(tu / tp, 2)},
    ]
    for r in rows:
        if as_json:
            print("BENCH " + json.dumps(r, separators=(",", ":")))
        else:
            print(r)


if __name__ == "__main__":
    main()
