#!/bin/sh
# Capture the committed perf trajectory: collect every `BENCH {...}` json
# line from the query benches (and the kernel-layout microbenchmark) into
# BENCH_<n>.json at the repo root.
#
#   sh tools/bench_capture.sh [n]        # default n=6
#
# With a Rust toolchain present this runs `cargo bench --bench
# bench_queries` for the real per-query / 19-query-sweep wall-clock;
# without one (the authoring container) it still captures the
# python-mirror kernel microbenchmark and records the degraded
# provenance, so the committed file always says exactly how its numbers
# were produced.
set -eu
n="${1:-6}"
cd "$(dirname "$0")/.."
out="BENCH_${n}.json"
tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT

if command -v cargo >/dev/null 2>&1; then
    provenance="cargo bench --bench bench_queries + tools/kernel_bench.py"
    cargo bench --bench bench_queries | tee /dev/stderr | grep '^BENCH ' >>"$tmp" || true
else
    provenance="tools/kernel_bench.py only (no rust toolchain in capture environment; rust sweep entries pending a toolchain run of this script)"
    echo "bench_capture: cargo not found, capturing kernel microbenchmark only" >&2
fi
python3 tools/kernel_bench.py --json | grep '^BENCH ' >>"$tmp"

python3 - "$out" "$tmp" "$n" "$provenance" <<'EOF'
import json
import platform
import sys
import time

out, src, n, provenance = sys.argv[1:5]
entries = []
with open(src) as f:
    for line in f:
        entries.append(json.loads(line[len("BENCH "):]))
doc = {
    "issue": int(n),
    "captured": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    "host": {"platform": platform.platform(), "machine": platform.machine()},
    "provenance": provenance,
    "entries": entries,
}
with open(out, "w") as f:
    json.dump(doc, f, indent=2)
    f.write("\n")
print(f"wrote {out} ({len(entries)} entries)")
EOF
