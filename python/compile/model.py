"""Layer-2: JAX compute graphs implementing the PIM module ISA.

Each exported function is the functional model of one PIM instruction
(paper Table 4) applied to a batch of XB_TILE crossbars, expressed over the
bit-plane layout and calling the Layer-1 Pallas kernels. The rust runtime
(rust/src/runtime/) loads the AOT-lowered HLO of these graphs and executes
them on the PJRT CPU client — python never runs on the request path.

Also exports a fused filter+aggregate showcase graph (`q6_filter_agg`) that
evaluates a TPC-H Q6-shaped predicate and masked sums in a single HLO
module, demonstrating XLA fusing the full instruction pipeline of a query
phase (used by the L3 engine's fused path and the perf study).
"""

import jax
import jax.numpy as jnp

from compile.kernels import bitwise as k

XB_TILE = k.XB_TILE
PLANES = k.PLANES
MUL_PLANES = k.MUL_PLANES
WORDS = k.WORDS


def _planes_spec(n=PLANES):
    return jax.ShapeDtypeStruct((XB_TILE, n, WORDS), jnp.uint32)


def _mask_spec():
    return jax.ShapeDtypeStruct((XB_TILE, WORDS), jnp.uint32)


def _immbits_spec(n=PLANES):
    return jax.ShapeDtypeStruct((n,), jnp.uint32)


# --- instruction-level graphs (one exported executable each) ---------------


def cmp_imm(planes, immbits):
    eq, lt = k.cmp_imm(planes, immbits)
    return eq, lt


def cmp_cols(a, b):
    eq, lt = k.cmp_cols(a, b)
    return eq, lt


def add_cols(a, b):
    return (k.add_cols(a, b),)


def add_imm(a, immbits):
    return (k.add_imm(a, immbits),)


def mul_cols(a, b):
    return (k.mul_cols(a, b),)


def mask_and(a, b):
    return (k.mask_and(a, b),)


def mask_or(a, b):
    return (k.mask_or(a, b),)


def mask_not(a):
    return (k.mask_not(a),)


def reduce_sum(planes, mask):
    return (k.reduce_sum(planes, mask),)


def reduce_min(planes, mask):
    return k.reduce_min(planes, mask)


def reduce_max(planes, mask):
    return k.reduce_max(planes, mask)


def column_transform(mask):
    return (k.column_transform(mask),)


# --- fused showcase: TPC-H Q6-shaped filter + aggregate ---------------------
#
#   SELECT SUM(extendedprice * discount) FROM lineitem
#   WHERE shipdate in [d0, d1) AND discount in [lo, hi] AND quantity < q
#
# Inputs are the bit-plane sets of the four attributes plus immediate bit
# vectors; output is the per-plane popcount array of the masked product.


def q6_filter_agg(
    shipdate,
    discount,
    quantity,
    eprice_x_disc,
    d0_bits,
    d1_bits,
    dlo_bits,
    dhi_bits,
    q_bits,
    valid,
):
    _, lt_d0 = k.cmp_imm(shipdate, d0_bits)
    _, lt_d1 = k.cmp_imm(shipdate, d1_bits)
    m_date = k.mask_and(k.mask_not(lt_d0), lt_d1)  # d0 <= shipdate < d1

    eq_lo, lt_lo = k.cmp_imm(discount, dlo_bits)
    eq_hi, lt_hi = k.cmp_imm(discount, dhi_bits)
    ge_lo = k.mask_not(lt_lo)
    le_hi = k.mask_or(lt_hi, eq_hi)
    m_disc = k.mask_and(ge_lo, le_hi)

    _, lt_q = k.cmp_imm(quantity, q_bits)

    m = k.mask_and(k.mask_and(m_date, m_disc), k.mask_and(lt_q, valid))
    counts = k.reduce_sum(eprice_x_disc, m)
    mask_counts = k.reduce_sum(_ones_planes_like(eprice_x_disc), m)
    return counts, mask_counts[:, :1]  # record count in plane 0


def _ones_planes_like(planes):
    # plane 0 all-ones, rest zero: value 1 per row, so its masked sum is the
    # selected-record count (the paper's COUNT via SUM on the filter column)
    one = jnp.concatenate(
        [
            jnp.full((planes.shape[0], 1, WORDS), 0xFFFFFFFF, jnp.uint32),
            jnp.zeros((planes.shape[0], planes.shape[1] - 1, WORDS), jnp.uint32),
        ],
        axis=1,
    )
    return one


# --- export registry ---------------------------------------------------------

EXPORTS = {
    "cmp_imm": (cmp_imm, [_planes_spec(), _immbits_spec()]),
    "cmp_cols": (cmp_cols, [_planes_spec(), _planes_spec()]),
    "add_cols": (add_cols, [_planes_spec(), _planes_spec()]),
    "add_imm": (add_imm, [_planes_spec(), _immbits_spec()]),
    "mul_cols": (
        mul_cols,
        [_planes_spec(MUL_PLANES), _planes_spec(MUL_PLANES)],
    ),
    "mask_and": (mask_and, [_mask_spec(), _mask_spec()]),
    "mask_or": (mask_or, [_mask_spec(), _mask_spec()]),
    "mask_not": (mask_not, [_mask_spec()]),
    "reduce_sum": (reduce_sum, [_planes_spec(), _mask_spec()]),
    "reduce_min": (reduce_min, [_planes_spec(), _mask_spec()]),
    "reduce_max": (reduce_max, [_planes_spec(), _mask_spec()]),
    "column_transform": (column_transform, [_mask_spec()]),
    "q6_filter_agg": (
        q6_filter_agg,
        [
            _planes_spec(),  # shipdate
            _planes_spec(),  # discount
            _planes_spec(),  # quantity
            _planes_spec(),  # eprice*discount (precomputed product planes)
            _immbits_spec(),
            _immbits_spec(),
            _immbits_spec(),
            _immbits_spec(),
            _immbits_spec(),
            _mask_spec(),  # valid column
        ],
    ),
}
