"""Pure-jnp/numpy oracle for the Pallas bit-plane kernels.

The oracle works at *value level*: bit-plane tensors are unpacked into
per-row integer values, the operation is computed with ordinary integer
semantics, and results are repacked. Kernel == oracle is therefore a strong
check that the bit-serial plane algorithms implement the intended integer
semantics (the same check the paper runs between its MAGIC NOR sequences
and the SQL-level semantics).
"""

import numpy as np

ROWS = 1024
WORDS = ROWS // 32
PLANES = 64


def pack_values(values, nplanes=PLANES):
    """u64[XB, ROWS] -> u32[XB, nplanes, WORDS] LSB-first bit-planes."""
    values = np.asarray(values, dtype=np.uint64)
    xb, rows = values.shape
    assert rows == ROWS
    out = np.zeros((xb, nplanes, WORDS), dtype=np.uint32)
    for i in range(nplanes):
        bits = ((values >> np.uint64(i)) & np.uint64(1)).astype(np.uint32)
        # pack 32 row-bits per word, row r -> word r//32 bit r%32
        out[:, i, :] = (
            bits.reshape(xb, WORDS, 32)
            << np.arange(32, dtype=np.uint32)[None, None, :]
        ).sum(axis=-1, dtype=np.uint32)
    return out


def unpack_planes(planes):
    """u32[XB, N, WORDS] -> u64[XB, ROWS] values."""
    planes = np.asarray(planes, dtype=np.uint32)
    xb, nplanes, words = planes.shape
    vals = np.zeros((xb, words * 32), dtype=np.uint64)
    for i in range(nplanes):
        bits = (
            (planes[:, i, :, None] >> np.arange(32, dtype=np.uint32)) & 1
        ).reshape(xb, words * 32)
        vals |= bits.astype(np.uint64) << np.uint64(i)
    return vals


def pack_mask(mask_bool):
    """bool[XB, ROWS] -> u32[XB, WORDS]."""
    m = np.asarray(mask_bool, dtype=np.uint32)
    xb, rows = m.shape
    return (
        m.reshape(xb, WORDS, 32) << np.arange(32, dtype=np.uint32)[None, None, :]
    ).sum(axis=-1, dtype=np.uint32)


def unpack_mask(mask):
    """u32[XB, WORDS] -> bool[XB, ROWS]."""
    mask = np.asarray(mask, dtype=np.uint32)
    xb, words = mask.shape
    return (
        ((mask[:, :, None] >> np.arange(32, dtype=np.uint32)) & 1)
        .reshape(xb, words * 32)
        .astype(bool)
    )


def imm_to_bits(imm, nplanes=PLANES):
    """Immediate int -> u32[nplanes] bit vector (LSB first)."""
    return np.array(
        [(int(imm) >> i) & 1 for i in range(nplanes)], dtype=np.uint32
    )


def _trunc(values, nplanes):
    if nplanes >= 64:
        return np.asarray(values, dtype=np.uint64)
    return np.asarray(values, dtype=np.uint64) & np.uint64((1 << nplanes) - 1)


def cmp_imm(values, imm, nplanes=PLANES):
    v = _trunc(values, nplanes)
    c = np.uint64(imm)
    return (v == c), (v < c)


def cmp_cols(a, b, nplanes=PLANES):
    a, b = _trunc(a, nplanes), _trunc(b, nplanes)
    return (a == b), (a < b)


def add_cols(a, b, nplanes=PLANES):
    return _trunc(np.asarray(a, np.uint64) + np.asarray(b, np.uint64), nplanes)


def add_imm(a, imm, nplanes=PLANES):
    return _trunc(np.asarray(a, np.uint64) + np.uint64(imm), nplanes)


def mul_cols(a, b, nplanes=32):
    a = _trunc(a, nplanes)
    b = _trunc(b, nplanes)
    return _trunc(a * b, 2 * nplanes)


def reduce_sum(values, mask_bool, nplanes=PLANES):
    """Masked per-crossbar sum as exact python ints (one per crossbar)."""
    v = _trunc(values, nplanes)
    out = []
    for b in range(v.shape[0]):
        out.append(int(sum(int(x) for x in v[b][mask_bool[b]])))
    return out


def reduce_sum_from_counts(counts):
    """Recombine kernel per-plane popcounts into exact sums (host combine)."""
    counts = np.asarray(counts)
    return [
        sum(int(c) << i for i, c in enumerate(counts[b]))
        for b in range(counts.shape[0])
    ]


def reduce_min(values, mask_bool, nplanes=PLANES):
    v = _trunc(values, nplanes)
    out = []
    for b in range(v.shape[0]):
        sel = v[b][mask_bool[b]]
        out.append((int(sel.min()), 1) if sel.size else (0, 0))
    return out


def reduce_max(values, mask_bool, nplanes=PLANES):
    v = _trunc(values, nplanes)
    out = []
    for b in range(v.shape[0]):
        sel = v[b][mask_bool[b]]
        out.append((int(sel.max()), 1) if sel.size else (0, 0))
    return out


def column_transform(mask):
    """u32[XB, WORDS] mask -> u32[XB, 2*WORDS] of 16-bit read groups."""
    mask = np.asarray(mask, dtype=np.uint32)
    lo = mask & np.uint32(0xFFFF)
    hi = mask >> np.uint32(16)
    return np.stack([lo, hi], axis=-1).reshape(mask.shape[0], -1)
