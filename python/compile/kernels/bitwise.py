"""Layer-1 Pallas kernels: bulk-bitwise crossbar operations on bit-planes.

The paper's compute fabric is an RRAM crossbar executing bit-serial MAGIC
NOR sequences in parallel across all 1024 rows of a crossbar, across all
crossbars of a huge-page (PIMDB, Perach et al., IEEE TETC 2022).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): a crossbar row is
a vector-lane element. 1024 rows pack into WORDS=32 u32 words, so a bulk
column-wise logic op over all rows of a crossbar batch becomes a single
vectorized u32 op over a [XB, WORDS] tile. The bit-serial FSM loop over
attribute bit positions (the paper's Table 4 sequences) becomes a
`jax.lax.fori_loop` over bit-planes inside one Pallas kernel, so one kernel
invocation == one PIM instruction over a whole crossbar batch.

Layout convention:
  * planes:  u32[XB, PLANES, WORDS]  -- bit i of row r of crossbar b is
             (planes[b, i, r // 32] >> (r % 32)) & 1   (LSB-first planes)
  * mask:    u32[XB, WORDS]          -- one bit per row (a crossbar column)
  * immbits: u32[PLANES]             -- immediate operand, one 0/1 per bit;
             the FSM specializes its control sequence on these (Alg. 1),
             here they select plane vs ~plane branchlessly.

All kernels use interpret=True: on this CPU image, real TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot execute. The exported HLO
(see aot.py) is the interpret-mode lowering, which the rust runtime runs
via the PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Crossbar geometry (paper Table 3: 1024x512 crossbars).
ROWS = 1024
WORDS = ROWS // 32  # u32 words per bit-plane column
PLANES = 64  # max attribute width supported by the generic ALU ops
MUL_PLANES = 32  # multiply is exported at 32x32 -> 64 bits
XB_TILE = 16  # crossbars per exported executable invocation
XB_BLOCK = 8  # crossbars per pallas grid step (VMEM tile)

# numpy scalars stay literals during pallas tracing (jnp scalars would be
# captured closure constants, which pallas_call rejects).
_U32_ALL = np.uint32(0xFFFFFFFF)


def _sel_by_bit(plane, bit):
    """plane if bit==1 else ~plane, branchless: plane ^ (bit - 1) in u32."""
    return plane ^ (bit + _U32_ALL)  # bit-1 mod 2^32: 0 -> all-ones, 1 -> 0


def _bcast_bit(bit):
    """All-ones u32 word if bit==1 else 0 (0 - bit in u32)."""
    return np.uint32(0) - bit


# ---------------------------------------------------------------------------
# cmp_imm: compare an in-memory value (bit-planes) against an immediate.
# Mirrors Algorithm 1 (equality) extended with the standard MSB-first
# less-than recurrence. One pass over the planes yields both eq and lt.
# ---------------------------------------------------------------------------


def _cmp_imm_kernel(planes_ref, immbits_ref, eq_ref, lt_ref, *, nplanes):
    xb = planes_ref.shape[0]
    eq0 = jnp.full((xb, WORDS), _U32_ALL, jnp.uint32)
    lt0 = jnp.zeros((xb, WORDS), jnp.uint32)

    def body(j, carry):
        eq, lt = carry
        i = nplanes - 1 - j  # MSB -> LSB
        p = pl.load(planes_ref, (slice(None), pl.ds(i, 1), slice(None)))
        p = p[:, 0, :]
        bit = pl.load(immbits_ref, (pl.ds(i, 1),))[0]
        # value < imm at the first differing bit where imm has 1, value 0.
        lt = lt | (eq & ~p & _bcast_bit(bit))
        eq = eq & _sel_by_bit(p, bit)
        return eq, lt

    eq, lt = jax.lax.fori_loop(0, nplanes, body, (eq0, lt0))
    eq_ref[...] = eq
    lt_ref[...] = lt


def cmp_imm(planes, immbits, *, nplanes=PLANES):
    """(eq, lt) masks of value-vs-immediate unsigned comparison."""
    xb = planes.shape[0]
    grid = (xb // XB_BLOCK,)
    out_shape = [
        jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
        jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
    ]
    return pl.pallas_call(
        functools.partial(_cmp_imm_kernel, nplanes=nplanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda b: (b, 0, 0)),
            pl.BlockSpec((nplanes,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((XB_BLOCK, WORDS), lambda b: (b, 0)),
            pl.BlockSpec((XB_BLOCK, WORDS), lambda b: (b, 0)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(planes, immbits)


# ---------------------------------------------------------------------------
# cmp_cols: compare two in-memory values (both bit-plane sets).
# ---------------------------------------------------------------------------


def _cmp_cols_kernel(a_ref, b_ref, eq_ref, lt_ref, *, nplanes):
    xb = a_ref.shape[0]
    eq0 = jnp.full((xb, WORDS), _U32_ALL, jnp.uint32)
    lt0 = jnp.zeros((xb, WORDS), jnp.uint32)

    def body(j, carry):
        eq, lt = carry
        i = nplanes - 1 - j
        a = pl.load(a_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        b = pl.load(b_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        lt = lt | (eq & ~a & b)
        eq = eq & ~(a ^ b)
        return eq, lt

    eq, lt = jax.lax.fori_loop(0, nplanes, body, (eq0, lt0))
    eq_ref[...] = eq
    lt_ref[...] = lt


def cmp_cols(a, b, *, nplanes=PLANES):
    xb = a.shape[0]
    grid = (xb // XB_BLOCK,)
    spec3 = pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0))
    spec2 = pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0))
    return pl.pallas_call(
        functools.partial(_cmp_cols_kernel, nplanes=nplanes),
        grid=grid,
        in_specs=[spec3, spec3],
        out_specs=[spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
        ],
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# add_cols / add_imm: bit-serial ripple-carry adder (the paper's iterated
# full-adder FSM, Table 4 "Addition": 18n+1 NOR cycles). Wraps mod 2^PLANES.
# ---------------------------------------------------------------------------


def _add_cols_kernel(a_ref, b_ref, o_ref, *, nplanes):
    xb = a_ref.shape[0]
    c0 = jnp.zeros((xb, WORDS), jnp.uint32)

    def body(i, c):
        a = pl.load(a_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        b = pl.load(b_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        axb = a ^ b
        s = axb ^ c
        c = (a & b) | (c & axb)
        pl.store(o_ref, (slice(None), pl.ds(i, 1), slice(None)), s[:, None, :])
        return c

    jax.lax.fori_loop(0, nplanes, body, c0)


def add_cols(a, b, *, nplanes=PLANES):
    xb = a.shape[0]
    spec3 = pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0))
    return pl.pallas_call(
        functools.partial(_add_cols_kernel, nplanes=nplanes),
        grid=(xb // XB_BLOCK,),
        in_specs=[spec3, spec3],
        out_specs=spec3,
        out_shape=jax.ShapeDtypeStruct((xb, nplanes, WORDS), jnp.uint32),
        interpret=True,
    )(a, b)


def _add_imm_kernel(a_ref, immbits_ref, o_ref, *, nplanes):
    xb = a_ref.shape[0]
    c0 = jnp.zeros((xb, WORDS), jnp.uint32)

    def body(i, c):
        a = pl.load(a_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        bit = pl.load(immbits_ref, (pl.ds(i, 1),))[0]
        b = jnp.broadcast_to(_bcast_bit(bit), a.shape)
        axb = a ^ b
        s = axb ^ c
        c = (a & b) | (c & axb)
        pl.store(o_ref, (slice(None), pl.ds(i, 1), slice(None)), s[:, None, :])
        return c

    jax.lax.fori_loop(0, nplanes, body, c0)


def add_imm(a, immbits, *, nplanes=PLANES):
    xb = a.shape[0]
    spec3 = pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0))
    return pl.pallas_call(
        functools.partial(_add_imm_kernel, nplanes=nplanes),
        grid=(xb // XB_BLOCK,),
        in_specs=[spec3, pl.BlockSpec((nplanes,), lambda g: (0,))],
        out_specs=spec3,
        out_shape=jax.ShapeDtypeStruct((xb, nplanes, WORDS), jnp.uint32),
        interpret=True,
    )(a, immbits)


# ---------------------------------------------------------------------------
# mul_cols: bit-serial shift-add multiply (paper Table 4 "Multiply":
# 24nm - 19n + 2m - 1 cycles). 32x32 -> 64-bit product planes.
# ---------------------------------------------------------------------------


def _mul_cols_kernel(a_ref, b_ref, o_ref, *, nplanes):
    xb = a_ref.shape[0]
    out_planes = 2 * nplanes
    acc0 = jnp.zeros((xb, out_planes, WORDS), jnp.uint32)

    def outer(i, acc):
        m = pl.load(b_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]

        def inner(jj, carry):
            acc, c = carry
            j = i + jj  # target plane for a-bit jj shifted by i
            a = pl.load(a_ref, (slice(None), pl.ds(jj, 1), slice(None)))
            ad = a[:, 0, :] & m
            t = jax.lax.dynamic_slice_in_dim(acc, j, 1, axis=1)[:, 0, :]
            txa = t ^ ad
            s = txa ^ c
            c = (t & ad) | (c & txa)
            acc = jax.lax.dynamic_update_slice_in_dim(
                acc, s[:, None, :], j, axis=1
            )
            return acc, c

        def carry_prop(k, carry):
            # propagate the final carry into planes >= i + nplanes
            acc, c = carry
            j = i + nplanes + k
            t = jax.lax.dynamic_slice_in_dim(acc, j, 1, axis=1)[:, 0, :]
            s = t ^ c
            c = t & c
            acc = jax.lax.dynamic_update_slice_in_dim(
                acc, s[:, None, :], j, axis=1
            )
            return acc, c

        acc, c = jax.lax.fori_loop(0, nplanes, inner, (acc, jnp.zeros((xb, WORDS), jnp.uint32)))
        acc, _ = jax.lax.fori_loop(0, nplanes - i, carry_prop, (acc, c))
        return acc

    acc = jax.lax.fori_loop(0, nplanes, outer, acc0)
    o_ref[...] = acc


def mul_cols(a, b, *, nplanes=MUL_PLANES):
    xb = a.shape[0]
    spec_in = pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0))
    spec_out = pl.BlockSpec((XB_BLOCK, 2 * nplanes, WORDS), lambda g: (g, 0, 0))
    return pl.pallas_call(
        functools.partial(_mul_cols_kernel, nplanes=nplanes),
        grid=(xb // XB_BLOCK,),
        in_specs=[spec_in, spec_in],
        out_specs=spec_out,
        out_shape=jax.ShapeDtypeStruct((xb, 2 * nplanes, WORDS), jnp.uint32),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# mask logic: single-plane bulk-bitwise ops (the paper's Bitwise AND/OR/NOT,
# Table 4) used to combine filter results.
# ---------------------------------------------------------------------------


def _mask2_kernel(a_ref, b_ref, o_ref, *, op):
    a, b = a_ref[...], b_ref[...]
    if op == "and":
        o_ref[...] = a & b
    elif op == "or":
        o_ref[...] = a | b
    elif op == "nor":
        o_ref[...] = ~(a | b)
    else:
        raise ValueError(op)


def _mask_binop(a, b, op):
    xb = a.shape[0]
    spec = pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0))
    return pl.pallas_call(
        functools.partial(_mask2_kernel, op=op),
        grid=(xb // XB_BLOCK,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
        interpret=True,
    )(a, b)


def mask_and(a, b):
    return _mask_binop(a, b, "and")


def mask_or(a, b):
    return _mask_binop(a, b, "or")


def mask_nor(a, b):
    return _mask_binop(a, b, "nor")


def _mask_not_kernel(a_ref, o_ref):
    o_ref[...] = ~a_ref[...]


def mask_not(a):
    xb = a.shape[0]
    spec = pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0))
    return pl.pallas_call(
        _mask_not_kernel,
        grid=(xb // XB_BLOCK,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((xb, WORDS), jnp.uint32),
        interpret=True,
    )(a)


# ---------------------------------------------------------------------------
# reduce_sum: per-crossbar masked sum, returned as per-plane popcounts.
# The host combines cnt[b, i] * 2^i in wide integer arithmetic, mirroring
# the paper's host-side combine of per-crossbar partial aggregates.
# ---------------------------------------------------------------------------


def _reduce_sum_kernel(planes_ref, mask_ref, cnt_ref, *, nplanes):
    mask = mask_ref[...]

    def body(i, _):
        p = pl.load(planes_ref, (slice(None), pl.ds(i, 1), slice(None)))
        cnt = jnp.sum(
            jax.lax.population_count(p[:, 0, :] & mask), axis=-1
        ).astype(jnp.uint32)
        pl.store(cnt_ref, (slice(None), pl.ds(i, 1)), cnt[:, None])
        return 0

    jax.lax.fori_loop(0, nplanes, body, 0)


def reduce_sum(planes, mask, *, nplanes=PLANES):
    xb = planes.shape[0]
    return pl.pallas_call(
        functools.partial(_reduce_sum_kernel, nplanes=nplanes),
        grid=(xb // XB_BLOCK,),
        in_specs=[
            pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0)),
            pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((XB_BLOCK, nplanes), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((xb, nplanes), jnp.uint32),
        interpret=True,
    )(planes, mask)


# ---------------------------------------------------------------------------
# reduce_min / reduce_max: bitwise candidate-narrowing MSB->LSB (the in-array
# tree reduce of Fig. 7, but expressed over bit-planes). Returns the value as
# (lo, hi) u32 halves plus a valid flag (0 when the mask is empty).
# ---------------------------------------------------------------------------


def _reduce_minmax_kernel(planes_ref, mask_ref, lo_ref, hi_ref, valid_ref, *, nplanes, is_min):
    xb = planes_ref.shape[0]
    mask = mask_ref[...]
    lo0 = jnp.zeros((xb,), jnp.uint32)
    hi0 = jnp.zeros((xb,), jnp.uint32)

    def body(j, carry):
        cand, lo, hi = carry
        i = nplanes - 1 - j
        p = pl.load(planes_ref, (slice(None), pl.ds(i, 1), slice(None)))[:, 0, :]
        narrowed = cand & (~p if is_min else p)
        have = (jnp.sum(narrowed, axis=-1, dtype=jnp.uint32) != 0)
        cand = jnp.where(have[:, None], narrowed, cand)
        # chosen bit: min -> 0 where narrowing succeeded; max -> 1.
        bit = (~have if is_min else have).astype(jnp.uint32)
        in_hi = i >= 32
        shift = jnp.uint32(i % 32)
        lo = jnp.where(in_hi, lo, lo | (bit << shift))
        hi = jnp.where(in_hi, hi | (bit << shift), hi)
        return cand, lo, hi

    cand, lo, hi = jax.lax.fori_loop(0, nplanes, body, (mask, lo0, hi0))
    valid = (jnp.sum(mask, axis=-1, dtype=jnp.uint32) != 0).astype(jnp.uint32)
    lo_ref[...] = lo * valid
    hi_ref[...] = hi * valid
    valid_ref[...] = valid


def _reduce_minmax(planes, mask, is_min, nplanes):
    xb = planes.shape[0]
    spec1 = pl.BlockSpec((XB_BLOCK,), lambda g: (g,))
    return pl.pallas_call(
        functools.partial(_reduce_minmax_kernel, nplanes=nplanes, is_min=is_min),
        grid=(xb // XB_BLOCK,),
        in_specs=[
            pl.BlockSpec((XB_BLOCK, nplanes, WORDS), lambda g: (g, 0, 0)),
            pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0)),
        ],
        out_specs=[spec1, spec1, spec1],
        out_shape=[
            jax.ShapeDtypeStruct((xb,), jnp.uint32),
            jax.ShapeDtypeStruct((xb,), jnp.uint32),
            jax.ShapeDtypeStruct((xb,), jnp.uint32),
        ],
        interpret=True,
    )(planes, mask)


def reduce_min(planes, mask, *, nplanes=PLANES):
    return _reduce_minmax(planes, mask, True, nplanes)


def reduce_max(planes, mask, *, nplanes=PLANES):
    return _reduce_minmax(planes, mask, False, nplanes)


# ---------------------------------------------------------------------------
# column_transform: repack one crossbar column (a result mask) into
# row-oriented 16-bit read groups (paper Fig. 6; crossbar read = 16 bits).
# Functionally a bit-field extraction; in hardware, 2050 NOR cycles.
# ---------------------------------------------------------------------------


def _column_transform_kernel(mask_ref, o_ref):
    m = mask_ref[...]  # [XB, WORDS]
    lo = m & jnp.uint32(0xFFFF)
    hi = m >> jnp.uint32(16)
    # interleave: out[:, 2w] = lo word w, out[:, 2w+1] = hi word w
    out = jnp.stack([lo, hi], axis=-1).reshape(m.shape[0], 2 * WORDS)
    o_ref[...] = out


def column_transform(mask):
    xb = mask.shape[0]
    return pl.pallas_call(
        _column_transform_kernel,
        grid=(xb // XB_BLOCK,),
        in_specs=[pl.BlockSpec((XB_BLOCK, WORDS), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((XB_BLOCK, 2 * WORDS), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((xb, 2 * WORDS), jnp.uint32),
        interpret=True,
    )(mask)
