"""AOT bridge: lower the Layer-2 graphs to HLO text artifacts.

HLO *text* (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 rust crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Writes one <name>.hlo.txt per exported graph plus manifest.txt describing
input/output shapes, which the rust runtime checks at load time.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    return "{}[{}]".format(s.dtype, ",".join(str(d) for d in s.shape))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest_lines = []
    for name, (fn, specs) in model.EXPORTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = lowered.out_info
        out_specs = jax.tree_util.tree_leaves(outs)
        line = "{}|in:{}|out:{}".format(
            name,
            ";".join(_spec_str(s) for s in specs),
            ";".join(_spec_str(s) for s in out_specs),
        )
        manifest_lines.append(line)
        print(f"wrote {path} ({len(text)} chars)")

    if only is None:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
        print(f"wrote manifest with {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
