"""Python mirror of the Rust PIM-program optimizer (rust/src/query/opt/).

The Rust crate's authoring environment has no toolchain, so the optimizer
passes are validated here against a line-by-line port of the compiler
(rust/src/query/compiler.rs), the functional engine
(rust/src/exec/engine.rs::exec_instr) and the Table 4 cost model, fuzzed
over random queries and random data (python/tests/test_optmirror.py).
Keep this file in sync with the Rust sources when the passes change; the
port favours structural similarity over Pythonic style on purpose.

Bit-planes are arbitrary-precision ints (bit r = crossbar row r), which
matches the Rust u32-word planes exactly for any row count.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

# --- ISA (rust/src/pim/isa.rs) ----------------------------------------------

EQ_IMM, NE_IMM, LT_IMM, GT_IMM, ADD_IMM = "eq_imm", "ne_imm", "lt_imm", "gt_imm", "add_imm"
EQ, LT, SET, RESET, NOT, AND, OR, ADD, MUL = (
    "eq", "lt", "set", "reset", "not", "and", "or", "add", "mul")
RSUM, RMIN, RMAX, COLT = "reduce_sum", "reduce_min", "reduce_max", "column_transform"

IMM_OPS = {EQ_IMM, NE_IMM, LT_IMM, GT_IMM, ADD_IMM}
REDUCES = {RSUM, RMIN, RMAX}
SIDE_EFFECT = REDUCES | {COLT}


@dataclass(frozen=True)
class ColRange:
    start: int
    len: int

    @property
    def end(self) -> int:
        return self.start + self.len


@dataclass(frozen=True)
class Instr:
    op: str
    src_a: ColRange
    src_b: Optional[ColRange]
    dst: ColRange
    imm: int = 0


@dataclass(frozen=True)
class Step:
    instr: Instr
    category: str = "filter"


def unary(op, src, dst):
    return Instr(op, src, None, dst)


def binary(op, a, b, dst):
    return Instr(op, a, b, dst)


def with_imm(op, src, dst, imm):
    return Instr(op, src, None, dst, imm)


# --- functional engine (rust/src/exec/engine.rs) -----------------------------

class Xbar:
    """planes[c]: int bitmask over rows."""

    def __init__(self, cols: int, rows: int):
        self.rows = rows
        self.full = (1 << rows) - 1
        self.planes = [0] * cols

    def value_at(self, row: int, r: ColRange) -> int:
        v = 0
        for i in range(r.len):
            if (self.planes[r.start + i] >> row) & 1:
                v |= 1 << i
        return v

    def popcount_col(self, col: int) -> int:
        return bin(self.planes[col]).count("1")


def _plane_or_zero(st: Xbar, r: Optional[ColRange], i: int) -> int:
    if r is not None and i < r.len:
        return st.planes[r.start + i]
    return 0


def _cmp_imm_planes(st: Xbar, a: ColRange, imm: int):
    eq, lt = st.full, 0
    for i in reversed(range(a.len)):
        p = st.planes[a.start + i]
        if (imm >> i) & 1:
            lt |= eq & ~p & st.full
            eq &= p
        else:
            eq &= ~p & st.full
    return eq, lt


def _cmp_cols_planes(st: Xbar, a: ColRange, b: ColRange):
    eq, lt = st.full, 0
    for i in reversed(range(a.len)):
        pa = st.planes[a.start + i]
        pb = _plane_or_zero(st, b, i)
        lt |= eq & ~pa & pb & st.full
        eq &= ~(pa ^ pb) & st.full
    return eq, lt


def exec_instr(st: Xbar, instr: Instr, reduce_out: list):
    a, d, full = instr.src_a, instr.dst, st.full
    op = instr.op
    if op in (EQ_IMM, NE_IMM, LT_IMM, GT_IMM):
        eq, lt = _cmp_imm_planes(st, a, instr.imm)
        out = {EQ_IMM: eq, NE_IMM: ~eq & full, LT_IMM: lt,
               GT_IMM: ~(lt | eq) & full}[op]
        st.planes[d.start] = out
    elif op in (EQ, LT):
        eq, lt = _cmp_cols_planes(st, a, instr.src_b)
        st.planes[d.start] = eq if op == EQ else lt
    elif op == ADD_IMM:
        # mirrors ADD: source zero-extends to the destination width so a
        # widening add-immediate propagates its final carry
        carry = 0
        for i in range(d.len):
            pa = _plane_or_zero(st, a, i)
            pb = full if (instr.imm >> i) & 1 else 0
            s = pa ^ pb ^ carry
            carry = (pa & pb) | (carry & (pa ^ pb))
            st.planes[d.start + i] = s
    elif op == ADD:
        b, carry = instr.src_b, 0
        for i in range(d.len):
            pa = _plane_or_zero(st, a, i)
            pb = _plane_or_zero(st, b, i)
            s = pa ^ pb ^ carry
            carry = (pa & pb) | (carry & (pa ^ pb))
            st.planes[d.start + i] = s
    elif op == MUL:
        b, n = instr.src_b, d.len
        acc = [0] * n
        for i in range(b.len):
            m = st.planes[b.start + i]
            carry = 0
            for j in range(min(a.len, n - i)):
                ad = st.planes[a.start + j] & m
                s = acc[i + j] ^ ad ^ carry
                carry = (acc[i + j] & ad) | (carry & (acc[i + j] ^ ad))
                acc[i + j] = s
            k = i + a.len
            while k < n and carry:
                s = acc[k] ^ carry
                carry = acc[k] & carry
                acc[k] = s
                k += 1
        for j in range(n):
            st.planes[d.start + j] = acc[j]
    elif op == SET:
        for i in range(d.len):
            st.planes[d.start + i] = full
    elif op == RESET:
        for i in range(d.len):
            st.planes[d.start + i] = 0
    elif op == NOT:
        for i in range(a.len):
            st.planes[d.start + i] = ~st.planes[a.start + i] & full
    elif op in (AND, OR):
        b = instr.src_b
        broadcast = b.len == 1 and a.len > 1
        for i in range(a.len):
            pb = st.planes[b.start] if broadcast else _plane_or_zero(st, b, i)
            pa = st.planes[a.start + i]
            st.planes[d.start + i] = (pa & pb) if op == AND else (pa | pb)
    elif op == RSUM:
        total = 0
        for i in range(a.len):
            total += bin(st.planes[a.start + i]).count("1") << i
        reduce_out.append(total)
    elif op in (RMIN, RMAX):
        is_min = op == RMIN
        cand, val = full, 0
        for j in reversed(range(a.len)):
            p = st.planes[a.start + j]
            narrowed = (cand & ~p & full) if is_min else (cand & p)
            if narrowed:
                cand = narrowed
                if not is_min:
                    val |= 1 << j
            elif is_min:
                val |= 1 << j
        reduce_out.append(val)
    elif op == COLT:
        pass
    else:  # pragma: no cover
        raise AssertionError(op)


def exec_steps(st: Xbar, steps: list[Step], mask_col: int):
    out: list = []
    for s in steps:
        exec_instr(st, s.instr, out)
    return out, st.popcount_col(mask_col)


# --- cost model (rust/src/pim/controller.rs, totals only) --------------------

def _popcounts(imm: int, n: int):
    masked = imm if n >= 64 else imm & ((1 << n) - 1)
    ones = bin(masked).count("1")
    return n - ones, ones


def _levels(rows: int) -> int:
    return rows.bit_length() - 1


def _reduce_row_cycles(rows: int, width_at) -> int:
    total = 0
    for k in range(_levels(rows)):
        total += 2 * (rows >> (k + 1)) * width_at(k)
    return total


def _scale_reduce_total(total_at_1024: int, rows: int) -> int:
    return (total_at_1024 * _levels(rows)) // 10


def cost_total(i: Instr, rows: int) -> int:
    n = i.src_a.len
    m = i.src_b.len if i.src_b else 0
    op = i.op
    if op == EQ_IMM:
        i0, i1 = _popcounts(i.imm, n)
        return i0 + 3 * i1 + 1
    if op == NE_IMM:
        i0, i1 = _popcounts(i.imm, n)
        return i0 + 3 * i1 + 3
    if op == LT_IMM:
        i0, i1 = _popcounts(i.imm, n)
        return 11 * i0 + 3 * i1 + 4
    if op == GT_IMM:
        i0, i1 = _popcounts(i.imm, n)
        return 11 * i0 + 3 * i1 + 2
    if op == ADD_IMM:
        return 18 * n + 3
    if op == EQ:
        return 11 * n + 3
    if op == LT:
        return 16 * n + 2
    if op in (SET, RESET):
        return n
    if op == NOT:
        return 2 * n
    if op == AND:
        return 6 * n
    if op == OR:
        return 4 * n
    if op == ADD:
        return 18 * n + 1
    if op == MUL:
        return max(0, 24 * n * m + 2 * m - (19 * n + 1))
    if op == RSUM:
        return _scale_reduce_total(2254 * n + 3006, rows)
    if op in (RMIN, RMAX):
        return _scale_reduce_total(2306 * n + 200, rows)
    if op == COLT:
        return 2 + 2 * rows
    raise AssertionError(op)  # pragma: no cover


def program_cycles(steps: list[Step], rows: int) -> int:
    return sum(cost_total(s.instr, rows) for s in steps)


# --- compiler (rust/src/query/compiler.rs) -----------------------------------

@dataclass
class Attr:
    name: str
    bits: int
    start: int  # column slot
    domain: int = 0  # dict domain size for group-by attrs (0 = not dict)


@dataclass
class Layout:
    """A fake relation layout: attrs, valid col, compute base."""
    attrs: dict[str, Attr]
    valid_col: int
    compute_base: int


@dataclass(frozen=True)
class AllocSpan:
    start: int
    width: int
    born_step: int


class ColAlloc:
    def __init__(self, base, limit):
        self.base, self.limit = base, limit
        self.persistent_top = self.scratch_top = base
        self.peak = 0
        self.spans: list[AllocSpan] = []

    def persistent(self, n, at_step):
        assert self.persistent_top == self.scratch_top
        at = self.persistent_top
        if at + n > self.limit:
            raise MemoryError("compute area exhausted")
        self.persistent_top += n
        self.scratch_top = self.persistent_top
        self._note(at, n, at_step)
        return at

    def scratch(self, n, at_step):
        at = self.scratch_top
        if at + n > self.limit:
            raise MemoryError("compute area exhausted")
        self.scratch_top += n
        self._note(at, n, at_step)
        return at

    def release_to(self, mark):
        self.scratch_top = mark

    def mark(self):
        return self.scratch_top

    def _note(self, at, n, at_step):
        self.spans.append(AllocSpan(at, n, at_step))
        self.peak = max(self.peak, self.scratch_top - self.base)


@dataclass
class Compiled:
    steps: list[Step]
    mask_col: int
    peak_inter_cells: int
    spans: list[AllocSpan]
    compute_base: int
    valid_col: int
    n_reduces: int


class Compiler:
    """Port of the Rust Compiler: predicates are nested tuples:
    ("cmp", attr, op, value) with op in {"==","!=","<","<=",">",">="},
    ("in", attr, [values]), ("between", attr, lo, hi),
    ("cmpcols", a, op, b), ("and", [..]), ("or", [..]), ("not", p),
    ("true",).  Aggregates: ("sum"/"min"/"max"/"count"/"avg", valexpr)
    with valexpr ("attr", name) | ("one",) | ("mul", a, b) |
    ("mulcomp", attr, scale, other) | ("mulsum", attr, scale, other) |
    ("mulcompsum", attr, s1, o1, s2, o2).
    """

    def __init__(self, layout: Layout, xbar_cols: int):
        self.layout = layout
        self.alloc = ColAlloc(layout.compute_base, xbar_cols)
        self.steps: list[Step] = []
        self.n_reduces = 0

    # -- helpers --
    def emit(self, instr, cat="filter"):
        self.steps.append(Step(instr, cat))

    def attr_range(self, name):
        a = self.layout.attrs[name]
        return ColRange(a.start, a.bits)

    def compile(self, filter_pred, group_by, aggregates) -> Compiled:
        mask = self.alloc.persistent(1, 0)
        mark = self.alloc.mark()
        self.lower_pred(filter_pred, mask)
        self.emit(binary(AND, ColRange(mask, 1), ColRange(self.layout.valid_col, 1),
                         ColRange(mask, 1)))
        self.alloc.release_to(mark)

        if not aggregates:
            self.emit(unary(COLT, ColRange(mask, 1), ColRange(mask, 1)), "coltrans")
            return Compiled(self.steps, mask, self.alloc.peak, self.alloc.spans,
                            self.layout.compute_base, self.layout.valid_col, 0)

        groups = self.expand_groups(group_by)
        for key in groups:
            if not key:
                gmask = mask
            else:
                gm = self.alloc.scratch(1, len(self.steps))
                self.group_mask(mask, key, gm)
                gmask = gm
            group_mark = self.alloc.mark()
            needs_count = any(a[0] in ("count", "avg") for a in aggregates)
            if needs_count:
                self.emit_reduce(RSUM, ColRange(gmask, 1))
            for kind, expr in aggregates:
                m2 = self.alloc.mark()
                if kind == "count":
                    pass
                elif kind in ("sum", "avg"):
                    cols = self.lower_masked_value(expr, gmask)
                    self.emit_reduce(RSUM, cols)
                else:  # min / max
                    cols = self.lower_minmax(expr, gmask, kind)
                    self.emit_reduce(RMIN if kind == "min" else RMAX, cols)
                self.alloc.release_to(m2)
            self.alloc.release_to(group_mark)
        return Compiled(self.steps, mask, self.alloc.peak, self.alloc.spans,
                        self.layout.compute_base, self.layout.valid_col,
                        self.n_reduces)

    def expand_groups(self, group_by):
        if not group_by:
            return [[]]
        combos = [[]]
        for attr in group_by:
            domain = range(self.layout.attrs[attr].domain)
            combos = [c + [(attr, v)] for c in combos for v in domain]
        return combos

    def lower_pred(self, p, dst, cat="filter"):
        d = ColRange(dst, 1)
        tag = p[0]
        if tag == "true":
            self.emit(unary(SET, d, d), cat)
        elif tag == "cmp":
            _, attr, op, value = p
            self.lower_cmp_imm(self.attr_range(attr), op, value, dst, cat)
        elif tag == "in":
            _, attr, values = p
            a = self.attr_range(attr)
            self.emit(unary(RESET, d, d), cat)
            mark = self.alloc.mark()
            t = self.alloc.scratch(1, len(self.steps))
            for v in values:
                self.lower_cmp_imm(a, "==", v, t, cat)
                self.emit(binary(OR, d, ColRange(t, 1), d), cat)
            self.alloc.release_to(mark)
        elif tag == "between":
            _, attr, lo, hi = p
            a = self.attr_range(attr)
            mark = self.alloc.mark()
            t = self.alloc.scratch(1, len(self.steps))
            self.lower_cmp_imm(a, ">=", lo, dst, cat)
            self.lower_cmp_imm(a, "<=", hi, t, cat)
            self.emit(binary(AND, d, ColRange(t, 1), d), cat)
            self.alloc.release_to(mark)
        elif tag == "cmpcols":
            _, an, op, bn = p
            ra, rb = self.attr_range(an), self.attr_range(bn)
            assert ra.len == rb.len
            if op == "==":
                self.emit(binary(EQ, ra, rb, d), cat)
            elif op == "!=":
                self.emit(binary(EQ, ra, rb, d), cat)
                self.emit(unary(NOT, d, d), cat)
            elif op == "<":
                self.emit(binary(LT, ra, rb, d), cat)
            elif op == ">":
                self.emit(binary(LT, rb, ra, d), cat)
            elif op == "<=":
                self.emit(binary(LT, rb, ra, d), cat)
                self.emit(unary(NOT, d, d), cat)
            else:  # >=
                self.emit(binary(LT, ra, rb, d), cat)
                self.emit(unary(NOT, d, d), cat)
        elif tag in ("and", "or"):
            combine = AND if tag == "and" else OR
            first = True
            mark = self.alloc.mark()
            t = self.alloc.scratch(1, len(self.steps))
            for sub in p[1]:
                if first:
                    self.lower_pred(sub, dst, cat)
                    first = False
                else:
                    self.lower_pred(sub, t, cat)
                    self.emit(binary(combine, d, ColRange(t, 1), d), cat)
            if first:
                self.emit(unary(SET if combine == AND else RESET, d, d), cat)
            self.alloc.release_to(mark)
        elif tag == "not":
            self.lower_pred(p[1], dst, cat)
            self.emit(unary(NOT, d, d), cat)
        else:  # pragma: no cover
            raise AssertionError(tag)

    def lower_cmp_imm(self, a, op, value, dst, cat):
        d = ColRange(dst, 1)
        maxv = (1 << a.len) - 1 if a.len < 64 else (1 << 64) - 1
        mk = lambda o, v: with_imm(o, a, d, v)
        # immediates wider than the attribute canonicalize to constant
        # masks (the engine truncates CmpImm immediates to the operand
        # width — rust/src/query/compiler.rs lower_cmp_imm)
        if op == "==":
            if value > maxv:
                self.emit(unary(RESET, d, d), cat)
            else:
                self.emit(mk(EQ_IMM, value), cat)
        elif op == "!=":
            if value > maxv:
                self.emit(unary(SET, d, d), cat)
            else:
                self.emit(mk(NE_IMM, value), cat)
        elif op == "<":
            if value == 0:
                self.emit(unary(RESET, d, d), cat)
            elif value > maxv:
                self.emit(unary(SET, d, d), cat)
            else:
                self.emit(mk(LT_IMM, value), cat)
        elif op == ">":
            if value >= maxv:
                self.emit(unary(RESET, d, d), cat)
            else:
                self.emit(mk(GT_IMM, value), cat)
        elif op == "<=":
            if value >= maxv:
                self.emit(unary(SET, d, d), cat)
            else:
                self.emit(mk(LT_IMM, value + 1), cat)
        else:  # >=
            if value == 0:
                self.emit(unary(SET, d, d), cat)
            elif value > maxv:
                self.emit(unary(RESET, d, d), cat)
            else:
                self.emit(mk(GT_IMM, value - 1), cat)

    def group_mask(self, base, key, dst):
        d = ColRange(dst, 1)
        mark = self.alloc.mark()
        t = self.alloc.scratch(1, len(self.steps))
        first = True
        for attr, v in key:
            a = self.attr_range(attr)
            target = dst if first else t
            self.lower_cmp_imm(a, "==", v, target, "filter")
            if not first:
                self.emit(binary(AND, d, ColRange(t, 1), d))
            first = False
        self.emit(binary(AND, d, ColRange(base, 1), d))
        self.alloc.release_to(mark)

    def widen_copy(self, src, width):
        at = self.alloc.scratch(width, len(self.steps))
        dst = ColRange(at, width)
        self.emit(unary(RESET, dst, dst), "arith")
        zero = self.alloc.scratch(1, len(self.steps))
        z = ColRange(zero, 1)
        self.emit(unary(RESET, z, z), "arith")
        self.emit(binary(OR, src, z, ColRange(at, src.len)), "arith")
        return dst

    def complement_field(self, other, scale):
        o = self.attr_range(other)
        width = max(scale.bit_length(), o.len)
        f = self.widen_copy(o, width)
        self.emit(unary(NOT, f, f), "arith")
        modw = 1 << width
        imm = (scale + modw - (modw - 1)) % modw
        self.emit(with_imm(ADD_IMM, f, f, imm), "arith")
        return f

    def sum_field(self, other, scale):
        o = self.attr_range(other)
        width = max(scale.bit_length(), o.len) + 1
        f = self.widen_copy(o, width)
        self.emit(with_imm(ADD_IMM, f, f, scale), "arith")
        return f

    def masked_attr(self, attr, mask):
        a = self.attr_range(attr)
        at = self.alloc.scratch(a.len, len(self.steps))
        dst = ColRange(at, a.len)
        self.emit(binary(AND, a, ColRange(mask, 1), dst), "arith")
        return dst

    def lower_masked_value(self, e, mask):
        tag = e[0]
        if tag == "attr":
            return self.masked_attr(e[1], mask)
        if tag == "one":
            return ColRange(mask, 1)
        if tag == "mul":
            ma = self.masked_attr(e[1], mask)
            rb = self.attr_range(e[2])
            w = ma.len + rb.len
            at = self.alloc.scratch(w, len(self.steps))
            dst = ColRange(at, w)
            self.emit(binary(MUL, ma, rb, dst), "arith")
            return dst
        if tag in ("mulcomp", "mulsum"):
            _, attr, scale, other = e
            f = (self.complement_field if tag == "mulcomp" else self.sum_field)(other, scale)
            ma = self.masked_attr(attr, mask)
            w = ma.len + f.len
            at = self.alloc.scratch(w, len(self.steps))
            dst = ColRange(at, w)
            self.emit(binary(MUL, ma, f, dst), "arith")
            return dst
        if tag == "mulcompsum":
            _, attr, s1, o1, s2, o2 = e
            f1 = self.complement_field(o1, s1)
            f2 = self.sum_field(o2, s2)
            ma = self.masked_attr(attr, mask)
            w1 = ma.len + f1.len
            t = ColRange(self.alloc.scratch(w1, len(self.steps)), w1)
            self.emit(binary(MUL, ma, f1, t), "arith")
            w2 = w1 + f2.len
            dst = ColRange(self.alloc.scratch(w2, len(self.steps)), w2)
            self.emit(binary(MUL, t, f2, dst), "arith")
            return dst
        raise AssertionError(tag)  # pragma: no cover

    def lower_minmax(self, e, mask, kind):
        cols = self.lower_masked_value(e, mask)
        if kind == "max":
            return cols
        if cols.start == mask:
            # ("one",) returns the mask column itself; mask | ~mask is
            # all-ones, materialized in fresh scratch (Rust: same fix)
            t = self.alloc.scratch(1, len(self.steps))
            tr = ColRange(t, 1)
            self.emit(unary(SET, tr, tr), "arith")
            return tr
        nm = self.alloc.scratch(1, len(self.steps))
        n = ColRange(nm, 1)
        self.emit(unary(NOT, ColRange(mask, 1), n), "arith")
        self.emit(binary(OR, cols, n, cols), "arith")
        return cols

    def emit_reduce(self, op, cols):
        self.emit(unary(op, cols, cols), "agg")
        self.n_reduces += 1


# --- passes (rust/src/query/opt/passes.rs) -----------------------------------

def read_lens(i: Instr):
    al = i.src_a.len
    bl = i.src_b.len if i.src_b else 0
    dl = i.dst.len
    op = i.op
    if op in (EQ_IMM, NE_IMM, LT_IMM, GT_IMM, NOT):
        return al, 0
    if op in (EQ, LT):
        return al, bl
    if op == ADD_IMM:
        return min(al, dl), 0
    if op == ADD:
        return min(al, dl), min(bl, dl)
    if op == MUL:
        return min(al, dl), bl
    if op in (SET, RESET):
        return 0, 0
    if op in (AND, OR):
        if bl == 1 and al > 1:
            return al, 1
        return al, min(bl, al)
    return al, 0  # reduces / column-transform


def write_span(i: Instr) -> Optional[ColRange]:
    al, d, op = i.src_a.len, i.dst, i.op
    if op in (EQ_IMM, NE_IMM, LT_IMM, GT_IMM, EQ, LT):
        return ColRange(d.start, 1)
    if op in (NOT, AND, OR):
        return ColRange(d.start, al)
    if op in (ADD_IMM, ADD, MUL, SET, RESET):
        return d
    return None


def accesses(i: Instr):
    la, lb = read_lens(i)
    reads = []
    if la > 0:
        reads.append(ColRange(i.src_a.start, la))
    if lb > 0:
        reads.append(ColRange(i.src_b.start, lb))
    return reads, write_span(i)


def _overlaps(r: ColRange, start: int, width: int) -> bool:
    return r.start < start + width and start < r.end


def max_col(steps):
    m = 0
    for s in steps:
        reads, write = accesses(s.instr)
        for r in reads + ([write] if write else []):
            m = max(m, r.end)
    return m


def peephole_in_set(steps, mask_col):
    out, i = [], 0
    while i < len(steps):
        if i + 2 < len(steps) and _in_set_prefix_at(steps, i, mask_col):
            eq = steps[i + 1]
            out.append(Step(replace(eq.instr, dst=steps[i].instr.dst), eq.category))
            i += 3
        else:
            out.append(steps[i])
            i += 1
    return out


def _in_set_prefix_at(steps, i, mask_col):
    r, e, o = steps[i].instr, steps[i + 1].instr, steps[i + 2].instr
    shape = (r.op == RESET and r.dst.len == 1
             and e.op == EQ_IMM and e.dst.len == 1 and e.dst.start != r.dst.start
             and e.dst.start != mask_col
             and not _overlaps(e.src_a, r.dst.start, 1)
             and o.op == OR and o.src_a == r.dst and o.src_b == e.dst
             and o.dst == r.dst)
    if not shape:
        return False
    t = e.dst.start
    for s in steps[i + 3:]:
        reads, write = accesses(s.instr)
        if any(_overlaps(rr, t, 1) for rr in reads):
            return False
        if write and _overlaps(write, t, 1):
            return True
    return True


def _ones(length):
    return (1 << length) - 1


def _value_of(vals, r: ColRange):
    v = 0
    for i in range(r.len):
        if vals[r.start + i]:
            v |= 1 << i
    return v


def _store(vals, start, length, v):
    for i in range(length):
        vals[start + i] = bool((v >> i) & 1)


def zero_row_exec(vals, i: Instr):
    a, d = i.src_a, i.dst
    al, dl, op = a.len, d.len, i.op
    if op in (EQ_IMM, NE_IMM, LT_IMM, GT_IMM):
        v = _value_of(vals, a)
        imm = i.imm & _ones(al)
        out = {EQ_IMM: v == imm, NE_IMM: v != imm,
               LT_IMM: v < imm, GT_IMM: v > imm}[op]
        vals[d.start] = out
    elif op in (EQ, LT):
        b = i.src_b
        va = _value_of(vals, a)
        vb = _value_of(vals, ColRange(b.start, min(b.len, al)))
        vals[d.start] = (va == vb) if op == EQ else (va < vb)
    elif op == ADD_IMM:
        v = _value_of(vals, ColRange(a.start, min(al, dl)))
        _store(vals, d.start, dl, (v + (i.imm & _ones(dl))) & _ones(dl))
    elif op == ADD:
        b = i.src_b
        va = _value_of(vals, ColRange(a.start, min(al, dl)))
        vb = _value_of(vals, ColRange(b.start, min(b.len, dl)))
        _store(vals, d.start, dl, (va + vb) & _ones(dl))
    elif op == MUL:
        b = i.src_b
        va = _value_of(vals, ColRange(a.start, min(al, dl)))
        vb = _value_of(vals, b)
        _store(vals, d.start, dl, (va * vb) & _ones(dl))
    elif op == SET:
        _store(vals, d.start, dl, _ones(dl))
    elif op == RESET:
        _store(vals, d.start, dl, 0)
    elif op == NOT:
        _store(vals, d.start, al, ~_value_of(vals, a) & _ones(al))
    elif op in (AND, OR):
        b = i.src_b
        va = _value_of(vals, a)
        if b.len == 1 and al > 1:
            vb = _ones(al) if vals[b.start] else 0
        else:
            vb = _value_of(vals, ColRange(b.start, min(b.len, al)))
        _store(vals, d.start, al, (va & vb) if op == AND else (va | vb))
    # reduces / column-transform: nothing


def valid_elide(steps, valid_col):
    vals = [False] * (max_col(steps) + 1)
    out = []
    for step in steps:
        i = step.instr
        elidable = (i.op == AND and i.src_b == ColRange(valid_col, 1)
                    and i.src_a.len == 1 and i.dst == i.src_a
                    and not vals[i.src_a.start])
        if elidable:
            continue
        zero_row_exec(vals, i)
        out.append(step)
    return out


def cse(steps, mask_col, compute_base):
    ncols = max(max_col(steps), mask_col) + 1
    col_vn = list(range(ncols))
    redirect: list[Optional[int]] = [None] * ncols
    next_vn = 1 << 32
    table: dict = {}

    out = []
    for idx, step in enumerate(steps):
        instr = step.instr
        la, lb = read_lens(instr)
        for fieldno, l in ((0, la), (1, lb)):
            if l == 0:
                continue
            r = instr.src_a if fieldno == 0 else instr.src_b
            s = r.start
            if s < compute_base:
                continue
            mapped0 = redirect[s] if redirect[s] is not None else s
            for k in range(1, l):
                mk = redirect[s + k] if redirect[s + k] is not None else s + k
                if mk != mapped0 + k:
                    raise AssertionError("non-contiguous CSE redirect")
            if mapped0 != s:
                nr = ColRange(mapped0, r.len)
                instr = replace(instr, src_a=nr) if fieldno == 0 else replace(instr, src_b=nr)

        w = write_span(instr)
        if w is None:
            # reduces / column-transform: pure observers; keep the cosmetic
            # dst field mirroring the (possibly redirected) source
            out.append(Step(replace(instr, dst=instr.src_a), step.category))
            continue
        w0, ww = w.start, w.len

        reads, _ = accesses(instr)
        srcs = tuple(col_vn[r.start + k] for r in reads for k in range(r.len))
        key = (instr.op, instr.imm if instr.op in IMM_OPS else 0, ww, la, lb, srcs)
        if key not in table:
            vns = tuple(range(next_vn, next_vn + ww))
            next_vn += ww
            table[key] = [vns, None]
        vns, home = table[key]

        home_intact = home if (home is not None and
                               all(col_vn[home + k] == vns[k] for k in range(ww))) else None
        if home_intact is not None:
            if home_intact == w0:
                if all(redirect[w0 + k] is None for k in range(ww)):
                    continue
            elif _elision_safe(steps[idx + 1:], w0, ww, home_intact, mask_col):
                for k in range(ww):
                    redirect[w0 + k] = home_intact + k
                    col_vn[w0 + k] = vns[k]
                continue

        for k in range(ww):
            redirect[w0 + k] = None
            col_vn[w0 + k] = vns[k]
        table[key][1] = w0
        out.append(Step(instr, step.category))

    mask = redirect[mask_col] if redirect[mask_col] is not None else mask_col
    return out, mask


def _elision_safe(rest, d0, w, h0, mask_col):
    live = [True] * w
    n_live = w
    h_written = False
    for s in rest:
        reads, write = accesses(s.instr)
        if write and _overlaps(write, h0, w):
            h_written = True
        for r in reads:
            if not _overlaps(r, d0, w):
                continue
            within = r.start >= d0 and r.end <= d0 + w
            if not within or h_written:
                return False
            if any(not live[k] for k in range(r.start - d0, r.end - d0)):
                return False
        if write:
            for c in range(write.start, write.end):
                if d0 <= c < d0 + w and live[c - d0]:
                    live[c - d0] = False
                    n_live -= 1
            if n_live == 0:
                return True
    if d0 <= mask_col < d0 + w and live[mask_col - d0] and h_written:
        return False
    return True


def dce(steps, mask_col):
    ncols = max(max_col(steps), mask_col) + 1
    live = [False] * ncols
    live[mask_col] = True
    keep = [True] * len(steps)
    for j in reversed(range(len(steps))):
        reads, write = accesses(steps[j].instr)
        if steps[j].instr.op in SIDE_EFFECT:
            for r in reads:
                for c in range(r.start, r.end):
                    live[c] = True
            continue
        assert write is not None
        if not any(live[c] for c in range(write.start, write.end)):
            keep[j] = False
            continue
        for c in range(write.start, write.end):
            live[c] = False
        for r in reads:
            for c in range(r.start, r.end):
                live[c] = True
    return [s for s, k in zip(steps, keep) if k]


# --- virtualize + realloc (rust/src/query/opt/alloc.rs) ----------------------

@dataclass
class Virt:
    steps: list[Step]
    mask_col: int
    blocks: list[tuple[int, int]]  # (vstart, width)


def virtualize(c: Compiled) -> Optional[Virt]:
    base = c.compute_base
    if not c.spans:
        return None
    phys_cols = max(max(s.start + s.width for s in c.spans),
                    max_col(c.steps), c.mask_col + 1)
    history: list[list[tuple[int, int]]] = [[] for _ in range(phys_cols)]
    blocks = []
    vtop = base
    for i, s in enumerate(c.spans):
        if s.start < base:
            return None
        blocks.append((vtop, s.width))
        vtop += s.width
        for col in range(s.start, s.start + s.width):
            if history[col] and history[col][-1][0] == s.born_step:
                return None
            history[col].append((s.born_step, i))

    owner: list[Optional[int]] = [None] * phys_cols

    def latest_span(col, step):
        cand = None
        for born, j in history[col]:
            if born <= step:
                cand = j
            else:
                break
        return cand

    def map_read(r: ColRange) -> Optional[int]:
        s = r.start
        if s < base:
            return s if r.end <= base else None
        j = owner[s]
        if j is None:
            return None
        span = c.spans[j]
        for col in range(s, s + r.len):
            if col >= phys_cols or owner[col] != j:
                return None
        if s + r.len > span.start + span.width:
            return None
        return blocks[j][0] + (s - span.start)

    steps = []
    for idx, step in enumerate(c.steps):
        instr = step.instr
        la, lb = read_lens(instr)
        if la > 0:
            ns = map_read(ColRange(instr.src_a.start, la))
            if ns is None:
                return None
            instr = replace(instr, src_a=ColRange(ns, instr.src_a.len))
        if lb > 0:
            b = instr.src_b
            ns = map_read(ColRange(b.start, lb))
            if ns is None:
                return None
            instr = replace(instr, src_b=ColRange(ns, b.len))
        w = write_span(instr)
        if w is not None:
            w0 = step.instr.dst.start
            if w0 < base:
                return None
            j = latest_span(w0, idx)
            if j is None:
                return None
            span = c.spans[j]
            if w0 + w.len > span.start + span.width:
                return None
            for col in range(w0, w0 + w.len):
                if latest_span(col, idx) != j:
                    return None
                owner[col] = j
            instr = replace(instr, dst=ColRange(blocks[j][0] + (w0 - span.start),
                                                step.instr.dst.len))
            if la == 0:
                # Set/Reset read nothing: keep the cosmetic src_a field
                # mirroring the (remapped) destination
                instr = replace(instr, src_a=instr.dst)
        else:
            instr = replace(instr, dst=instr.src_a)
        steps.append(Step(instr, step.category))

    mo = owner[c.mask_col]
    if mo is None:
        return None
    span = c.spans[mo]
    return Virt(steps, blocks[mo][0] + (c.mask_col - span.start), blocks)


@dataclass
class PlacedP:
    steps: list[Step]
    mask_col: int
    peak: int


def realloc(steps, blocks, mask_col, compute_base, orig_peak) -> Optional[PlacedP]:
    vtop = blocks[-1][0] + blocks[-1][1] if blocks else compute_base
    block_of = [-1] * vtop
    for i, (vs, w) in enumerate(blocks):
        for col in range(vs, vs + w):
            block_of[col] = i

    def lookup(r: ColRange) -> Optional[int]:
        s = r.start
        if s < compute_base:
            return -2 if r.end <= compute_base else None  # -2 == data
        if s >= vtop or r.end - 1 >= vtop:
            return None
        i = block_of[s]
        last = block_of[r.end - 1]
        return i if (i != -1 and i == last) else None

    nb = len(blocks)
    first_write = [None] * nb
    last_access = [0] * nb
    written = [False] * vtop
    for idx, step in enumerate(steps):
        reads, write = accesses(step.instr)
        for r in reads:
            i = lookup(r)
            if i is None:
                return None
            if i == -2:
                continue
            if any(not written[c] for c in range(r.start, r.end)):
                return None
            last_access[i] = idx
        if write:
            i = lookup(write)
            if i is None or i == -2:
                return None
            if first_write[i] is None:
                first_write[i] = idx
            last_access[i] = idx
            for c in range(write.start, write.end):
                written[c] = True
    mb = lookup(ColRange(mask_col, 1))
    if mb is None or mb == -2 or first_write[mb] is None:
        return None
    last_access[mb] = 1 << 60

    # decreasing-lifetime placement: long-lived blocks sink to the bottom,
    # short-lived per-group scratch packs above them. Two blocks may share
    # columns only when their [first_write, last_access] intervals are
    # strictly disjoint (touching at one step counts as a conflict,
    # mirroring the engine's per-plane read/write interleave).
    order = sorted((i for i in range(nb) if first_write[i] is not None),
                   key=lambda i: (-(last_access[i] - first_write[i]),
                                  first_write[i], blocks[i][0]))
    placed: list[tuple[int, int, int, int]] = []  # (at, w, fw, la)
    peak = 0
    placement = [None] * nb
    for i in order:
        w = blocks[i][1]
        conflicts = sorted(
            (at, aw) for (at, aw, f, l) in placed
            if not (l < first_write[i] or last_access[i] < f))
        at = compute_base
        for cs, cw in conflicts:
            if at + w <= cs:
                break
            at = max(at, cs + cw)
        placement[i] = at
        placed.append((at, w, first_write[i], last_access[i]))
        peak = max(peak, at + w - compute_base)
    if peak > orig_peak:
        return None

    def remap(r: ColRange) -> Optional[ColRange]:
        s = r.start
        if s < compute_base:
            return r
        i = block_of[s] if s < vtop else -1
        if i == -1 or placement[i] is None:
            return None
        return ColRange(placement[i] + (s - blocks[i][0]), r.len)

    out = []
    for step in steps:
        instr = step.instr
        na = remap(instr.src_a)
        if na is None:
            return None
        instr = replace(instr, src_a=na)
        if instr.src_b is not None:
            nbr = remap(instr.src_b)
            if nbr is None:
                return None
            instr = replace(instr, src_b=nbr)
        nd = remap(instr.dst)
        if nd is None:
            return None
        instr = replace(instr, dst=nd)
        out.append(Step(instr, step.category))
    mask = placement[mb] + (mask_col - blocks[mb][0])
    return PlacedP(out, mask, peak)


# --- pipeline driver (rust/src/query/opt/mod.rs) -----------------------------

def run_o1(c: Compiled) -> Compiled:
    steps = peephole_in_set(list(c.steps), c.mask_col)
    steps = valid_elide(steps, c.valid_col)
    steps = dce(steps, c.mask_col)
    out = replace_compiled(c, steps, c.mask_col, c.peak_inter_cells)
    out.spans = []  # born_steps are stale after deletions (Rust: same)
    return out


def run_o2(c: Compiled) -> Compiled:
    v = virtualize(c)
    if v is None:
        return run_o1(c)
    steps = peephole_in_set(v.steps, v.mask_col)
    steps, mask = cse(steps, v.mask_col, c.compute_base)
    steps = valid_elide(steps, c.valid_col)
    steps = dce(steps, mask)
    placed = realloc(steps, v.blocks, mask, c.compute_base, c.peak_inter_cells)
    if placed is None:
        return run_o1(c)
    return replace_compiled(c, placed.steps, placed.mask_col, placed.peak)


def replace_compiled(c, steps, mask, peak):
    return Compiled(steps, mask, peak, c.spans, c.compute_base, c.valid_col,
                    c.n_reduces)


def optimize(c: Compiled, level: int) -> Compiled:
    if level == 0:
        return c
    if level == 1:
        return run_o1(c)
    return run_o2(c)
