"""Fuzz-validation of the optimizer pass pipeline via the Python mirror.

Random queries (predicate trees, group-bys, aggregate expressions in the
exact shapes the Rust compiler lowers) are compiled, executed at -O0,
checked against a scalar oracle, then re-executed after -O1 and -O2
optimization: reduce streams and mask popcounts must be identical, total
cycles must never grow, and the intermediate-cell peak must never grow.
This is the stand-in for `cargo test` in the toolchain-less authoring
environment; the Rust test-suite re-proves everything on a real
toolchain (tests/opt_equivalence.rs and the unit tests beside the
passes).
"""

import random

import pytest

import optmirror as m

ROWS = 32
XBAR_COLS = 220


def make_layout():
    attrs = {}
    start = 0
    for name, bits, domain in [
        ("k", 8, 0), ("v", 10, 0), ("w", 6, 0),
        ("d1", 2, 3), ("d2", 1, 2),
        ("x", 7, 0), ("y", 7, 0),
    ]:
        attrs[name] = m.Attr(name, bits, start, domain)
        start += bits
    valid = start
    return m.Layout(attrs, valid, valid + 1)


LAYOUT = make_layout()
ATTRS = list(LAYOUT.attrs)


def gen_records(rng, n):
    recs = []
    for _ in range(n):
        rec = {}
        for a in LAYOUT.attrs.values():
            hi = a.domain - 1 if a.domain else (1 << a.bits) - 1
            rec[a.name] = rng.randint(0, hi)
        recs.append(rec)
    return recs


def load(records):
    st = m.Xbar(XBAR_COLS, ROWS)
    for row, rec in enumerate(records):
        for a in LAYOUT.attrs.values():
            v = rec[a.name]
            for b in range(a.bits):
                if (v >> b) & 1:
                    st.planes[a.start + b] |= 1 << row
        st.planes[LAYOUT.valid_col] |= 1 << row
    return st


# --- oracle ------------------------------------------------------------------

def eval_pred(p, rec):
    tag = p[0]
    if tag == "true":
        return True
    if tag == "cmp":
        _, attr, op, value = p
        return _cmp(rec[attr], op, value)
    if tag == "in":
        return rec[p[1]] in p[2]
    if tag == "between":
        return p[2] <= rec[p[1]] <= p[3]
    if tag == "cmpcols":
        return _cmp(rec[p[1]], p[2], rec[p[3]])
    if tag == "and":
        return all(eval_pred(s, rec) for s in p[1])
    if tag == "or":
        return any(eval_pred(s, rec) for s in p[1])
    if tag == "not":
        return not eval_pred(p[1], rec)
    raise AssertionError(tag)


def _cmp(a, op, b):
    return {"==": a == b, "!=": a != b, "<": a < b,
            "<=": a <= b, ">": a > b, ">=": a >= b}[op]


def eval_expr(e, rec):
    tag = e[0]
    if tag == "attr":
        return rec[e[1]]
    if tag == "one":
        return 1
    if tag == "mul":
        return rec[e[1]] * rec[e[2]]
    if tag == "mulcomp":
        return rec[e[1]] * (e[2] - rec[e[3]])
    if tag == "mulsum":
        return rec[e[1]] * (e[2] + rec[e[3]])
    if tag == "mulcompsum":
        return rec[e[1]] * (e[2] - rec[e[3]]) * (e[4] + rec[e[5]])
    raise AssertionError(tag)


def oracle_reduces(records, pred, group_by, aggregates, compiler):
    """Mirror the compiled program's reduce stream ordering."""
    out = []
    groups = compiler.expand_groups(group_by)
    selected = [r for r in records if eval_pred(pred, r)]
    for key in groups:
        grp = [r for r in selected if all(r[a] == v for a, v in key)]
        needs_count = any(a[0] in ("count", "avg") for a in aggregates)
        if needs_count:
            out.append(("count", len(grp)))
        for kind, expr in aggregates:
            if kind == "count":
                continue
            vals = [eval_expr(expr, r) for r in grp]
            if kind in ("sum", "avg"):
                out.append(("sum", sum(vals)))
            elif kind == "max":
                out.append(("max", max(vals) if vals else 0))
            else:
                out.append(("min", min(vals) if vals else None))  # sentinel
    return out, len(selected)


# --- random query generation -------------------------------------------------

def rand_value(rng, attr):
    a = LAYOUT.attrs[attr]
    hi = a.domain - 1 if a.domain else (1 << a.bits) - 1
    # occasionally out-of-domain values to hit boundary rewrites
    if rng.random() < 0.15 and not a.domain:
        return rng.randint(0, (1 << a.bits) - 1)
    return rng.randint(0, hi)


def rand_pred(rng, depth):
    if depth == 0 or rng.random() < 0.35:
        attr = rng.choice(ATTRS)
        kind = rng.randrange(4)
        if kind == 0:
            op = rng.choice(["==", "!=", "<", "<=", ">", ">="])
            return ("cmp", attr, op, rand_value(rng, attr))
        if kind == 1:
            k = rng.randint(1, 5)
            return ("in", attr, [rand_value(rng, attr) for _ in range(k)])
        if kind == 2:
            a, b = rand_value(rng, attr), rand_value(rng, attr)
            return ("between", attr, min(a, b), max(a, b))
        return ("cmpcols", "x", rng.choice(["<", "<=", ">", ">=", "==", "!="]), "y")
    n = rng.randint(1, 3)
    subs = [rand_pred(rng, depth - 1) for _ in range(n)]
    c = rng.randrange(3)
    if c == 0:
        return ("and", subs)
    if c == 1:
        return ("or", subs)
    return ("not", rand_pred(rng, depth - 1))


def rand_aggregates(rng):
    if rng.random() < 0.3:
        return []
    aggs = []
    for _ in range(rng.randint(1, 4)):
        kind = rng.choice(["sum", "count", "min", "max", "avg"])
        ek = rng.randrange(6)
        if ek == 0:
            expr = ("attr", rng.choice(["k", "v", "w"]))
        elif ek == 1:
            expr = ("one",)
        elif ek == 2:
            expr = ("mul", "k", "w")
        elif ek == 3:
            expr = ("mulcomp", "v", 100, "w")
        elif ek == 4:
            expr = ("mulsum", "v", 100, "w")
        else:
            expr = ("mulcompsum", "v", 100, "w", 100, "d1")
        if kind in ("count",):
            expr = ("one",)
        aggs.append((kind, expr))
    return aggs


def run_compiled(c, records):
    st = load(records)
    return m.exec_steps(st, c.steps, c.mask_col)


def check_query(rng, pred, group_by, aggregates, records):
    comp = m.Compiler(LAYOUT, XBAR_COLS)
    c0 = comp.compile(pred, group_by, aggregates)
    red0, cnt0 = run_compiled(c0, records)

    # oracle
    oc = m.Compiler(LAYOUT, XBAR_COLS)  # fresh instance for expand_groups
    want, selected = oracle_reduces(records, pred, group_by, aggregates, oc)
    assert cnt0 == selected, f"mask count {cnt0} != oracle {selected}"
    assert len(red0) == len(want), (len(red0), len(want))
    for got, (kind, w) in zip(red0, want):
        if kind == "min" and w is None:
            continue  # empty group: engine returns the all-ones sentinel
        assert got == w, f"{kind}: engine {got} != oracle {w}"

    # optimized levels must be bit-identical and never cost more
    rows_model = 1024
    cyc0 = m.program_cycles(c0.steps, rows_model)
    for level in (1, 2):
        c = m.optimize(c0, level)
        red, cnt = run_compiled(c, records)
        assert red == red0, f"-O{level} reduce drift"
        assert cnt == cnt0, f"-O{level} mask drift"
        cyc = m.program_cycles(c.steps, rows_model)
        assert cyc <= cyc0, f"-O{level} cycles {cyc} > {cyc0}"
        assert c.peak_inter_cells <= c0.peak_inter_cells
        assert len(c.steps) <= len(c0.steps)
    return cyc0, m.program_cycles(m.optimize(c0, 2).steps, rows_model)


def test_fuzz_random_queries():
    rng = random.Random(0xC0FFEE)
    improved = total = 0
    for case in range(400):
        pred = rand_pred(rng, rng.randint(0, 2))
        aggs = rand_aggregates(rng)
        group_by = []
        if aggs and rng.random() < 0.4:
            group_by = rng.sample(["d1", "d2"], rng.randint(1, 2))
        records = gen_records(rng, rng.randint(0, ROWS))
        try:
            c0, c2 = check_query(rng, pred, group_by, aggs, records)
        except MemoryError:
            continue  # compute-area exhaustion: legitimate compile error
        total += 1
        improved += c2 < c0
    # the pipeline must find waste in a solid majority of random programs
    assert total > 300
    assert improved > total // 2, (improved, total)


def test_q1_shape_collapses():
    """Grouped aggregates with repeated arithmetic field chains (the Q1
    shape): CSE + DCE must collapse the per-group recomputation."""
    pred = ("cmp", "k", "<=", 200)
    aggs = [
        ("sum", ("attr", "v")),
        ("sum", ("mulcomp", "v", 100, "w")),
        ("sum", ("mulcompsum", "v", 100, "w", 100, "d2")),
        ("count", ("one",)),
    ]
    rng = random.Random(1)
    records = gen_records(rng, ROWS)
    comp = m.Compiler(LAYOUT, XBAR_COLS)
    c0 = comp.compile(pred, ["d1", "d2"], aggs)
    c2 = m.optimize(c0, 2)
    red0, cnt0 = run_compiled(c0, records)
    red2, cnt2 = run_compiled(c2, records)
    assert (red0, cnt0) == (red2, cnt2)
    # 6 groups recompute the complement/sum chains: most must disappear
    assert len(c2.steps) < len(c0.steps) - 15, (len(c0.steps), len(c2.steps))
    assert c2.peak_inter_cells < c0.peak_inter_cells


def test_in_set_peephole_and_valid_elide():
    pred = ("and", [("in", "k", [3, 5, 9]), ("cmp", "v", ">", 0)])
    rng = random.Random(2)
    records = gen_records(rng, ROWS - 5)
    comp = m.Compiler(LAYOUT, XBAR_COLS)
    c0 = comp.compile(pred, [], [])
    c1 = m.optimize(c0, 1)
    red0, cnt0 = run_compiled(c0, records)
    red1, cnt1 = run_compiled(c1, records)
    assert (red0, cnt0) == (red1, cnt1)
    # peephole removes Reset + first Or; k == 3 rejects the zero row only
    # if 0 not in the IN-set -> the valid-AND elides too
    ops0 = [s.instr.op for s in c0.steps]
    ops1 = [s.instr.op for s in c1.steps]
    assert ops0.count(m.RESET) > ops1.count(m.RESET)
    assert ops0.count(m.AND) > ops1.count(m.AND)


def test_valid_and_kept_when_zero_row_passes():
    # k <= 200 accepts the all-zero record: the valid-AND must survive,
    # and invalid rows must stay unselected
    pred = ("cmp", "k", "<=", 200)
    rng = random.Random(3)
    records = gen_records(rng, 10)  # 22 invalid rows
    comp = m.Compiler(LAYOUT, XBAR_COLS)
    c0 = comp.compile(pred, [], [])
    for level in (1, 2):
        c = m.optimize(c0, level)
        _, cnt = run_compiled(c, records)
        want = sum(eval_pred(pred, r) for r in records)
        assert cnt == want
        ands = [s for s in c.steps
                if s.instr.op == m.AND
                and s.instr.src_b == m.ColRange(LAYOUT.valid_col, 1)]
        assert ands, "valid-AND wrongly elided"


def test_empty_and_full_relations():
    rng = random.Random(4)
    for n in (0, ROWS):
        records = gen_records(rng, n)
        pred = ("or", [("cmp", "k", ">", 10), ("in", "d1", [1])])
        aggs = [("sum", ("attr", "v")), ("avg", ("attr", "w"))]
        check_query(rng, pred, [], aggs, records)


def test_deep_nesting_and_demorgan_shapes():
    rng = random.Random(5)
    records = gen_records(rng, ROWS)
    pred = ("not", ("or", [
        ("and", [("cmp", "k", ">=", 1), ("not", ("between", "v", 10, 900))]),
        ("in", "w", [0, 1, 2, 63]),
        ("cmpcols", "x", "<=", "y"),
    ]))
    check_query(rng, pred, [], [], records)
