"""Fuzz + golden suite for the WAL codec mirror (``walmirror.py``).

Validates the contract the Rust ``storage::wal`` module promises:

* the record codec is an exact inverse (encode -> decode identity, for
  arbitrary payloads);
* cutting a WAL image at *any* byte offset either reproduces a
  record-boundary prefix (torn tail, truncated at the last boundary) or
  raises — never a record that was not fully appended;
* a bit flip anywhere in a *complete* frame is refused as
  :class:`walmirror.CorruptError`, never silently truncated — the
  torn-vs-corrupt split that makes crash recovery land on a batch
  boundary while bit rot stays a hard error;
* the crash-point-sweep digest is pinned cross-language via
  ``GOLDEN_WAL_DIGEST`` (also asserted in ``rust/src/storage/wal.rs``).
"""

import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import walmirror as m  # noqa: E402


def test_golden_wal_digest_pin():
    assert m.golden_wal_digest() == m.GOLDEN_WAL_DIGEST


def _random_record(rng: random.Random, epoch: int) -> m.WalRecord:
    fold = [
        (rng.randrange(1024), rng.randrange(1, 1 << 40))
        for _ in range(rng.randrange(4))
    ]
    stmts = [
        bytes(rng.randrange(256) for _ in range(rng.randrange(50)))
        for _ in range(rng.randrange(1, 4))
    ]
    return m.WalRecord(rng.randrange(6), epoch, fold, stmts)


def _image(rng: random.Random, fp: int, n: int):
    """A WAL image of ``n`` records plus its record boundaries."""
    buf = bytearray(m.WAL_MAGIC) + fp.to_bytes(8, "little")
    boundaries = [len(buf)]
    records = []
    for e in range(n):
        rec = _random_record(rng, e + 1)
        buf += rec.encode_frame()
        boundaries.append(len(buf))
        records.append(rec)
    return bytes(buf), boundaries, records


def test_record_codec_round_trips():
    rng = random.Random(0xA1)
    for e in range(200):
        rec = _random_record(rng, e)
        assert m.decode_payload(rec.encode_payload()) == rec


def test_clean_scan_returns_every_record():
    rng = random.Random(7)
    fp = rng.getrandbits(64)
    buf, _, records = _image(rng, fp, 5)
    scan = m.scan_records(buf, fp)
    assert scan.records == records
    assert not scan.torn
    assert scan.valid_len == len(buf)


def test_truncation_at_any_offset_never_yields_a_partial_batch():
    rng = random.Random(21)
    for _ in range(30):
        fp = rng.getrandbits(64)
        buf, boundaries, records = _image(rng, fp, rng.randrange(1, 5))
        for cut in range(len(buf) + 1):
            scan = m.scan_records(buf[:cut], fp)
            if cut < m.WAL_HEADER:
                assert scan.torn and not scan.records and scan.valid_len == 0
                continue
            k = sum(1 for b in boundaries if b <= cut) - 1
            assert scan.records == records[:k], f"cut {cut}"
            assert scan.torn == (cut != boundaries[k])
            assert scan.valid_len == boundaries[k]


def test_bit_flips_in_complete_frames_are_corruption_not_torn_tails():
    rng = random.Random(42)
    fp = rng.getrandbits(64)
    buf, boundaries, _ = _image(rng, fp, 3)
    for _ in range(200):
        pos = rng.randrange(len(buf))
        bit = 1 << rng.randrange(8)
        flipped = bytearray(buf)
        flipped[pos] ^= bit
        if pos < m.WAL_HEADER:
            # header damage refuses the whole file
            with pytest.raises(m.CorruptError):
                m.scan_records(bytes(flipped), fp)
            continue
        try:
            scan = m.scan_records(bytes(flipped), fp)
        except m.CorruptError:
            continue
        # the only survivable flips are in a frame *length* field, and
        # then the scan must still land on a record boundary with a
        # strict checksum-verified prefix — never a mangled record
        assert scan.valid_len in boundaries
        assert scan.torn
        k = boundaries.index(scan.valid_len)
        assert len(scan.records) == k


def test_wrong_fingerprint_and_magic_are_refused():
    rng = random.Random(5)
    fp = rng.getrandbits(64)
    buf, _, _ = _image(rng, fp, 1)
    with pytest.raises(m.CorruptError):
        m.scan_records(buf, fp ^ 1)
    bad = bytearray(buf)
    bad[0] ^= 1
    with pytest.raises(m.CorruptError):
        m.scan_records(bytes(bad), fp)
    # shorter than the header: torn at 0, not corrupt
    scan = m.scan_records(buf[:7], fp)
    assert scan.torn and not scan.records and scan.valid_len == 0
