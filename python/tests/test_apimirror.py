"""Fuzz + golden suite for the plan-cache key mirror (``apimirror.py``).

Validates the normalization contract the Rust side promises:

* insensitive to aliases (aggregate labels) and query names;
* deterministic, and *injective in practice* over randomized query
  populations (duplicate detection: equal keys iff equal canonical
  structure);
* sensitive to literals, operators, predicate structure, group-by sets,
  aggregate kinds/expressions, opt level, and the schema fingerprint;
* byte-format pinned cross-language via ``DEFAULT_FINGERPRINT`` and the
  ``GOLDEN_KEY`` below (both also asserted in ``rust/src/api/cache.rs``).
"""

import copy
import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import apimirror as m  # noqa: E402

ATTRS = [a for _, attrs in m.DEFAULT_SCHEMA for a, _, _, _ in attrs]
RELS = [name for name, _ in m.DEFAULT_SCHEMA]
OPS = list(m.CMP_TAGS)
AGGS = list(m.AGG_TAGS)


def rand_pred(rng: random.Random, depth: int = 0) -> tuple:
    roll = rng.random()
    if depth >= 3 or roll < 0.45:
        leaf = rng.randrange(5)
        attr = rng.choice(ATTRS)
        if leaf == 0:
            return ("cmp_imm", attr, rng.choice(OPS), rng.randrange(1 << 20))
        if leaf == 1:
            vals = [rng.randrange(1 << 10) for _ in range(rng.randrange(1, 6))]
            return ("in_set", attr, vals)
        if leaf == 2:
            lo = rng.randrange(1 << 10)
            return ("between", attr, lo, lo + rng.randrange(1 << 10))
        if leaf == 3:
            return ("cmp_cols", attr, rng.choice(OPS), rng.choice(ATTRS))
        return ("true",)
    if roll < 0.65:
        n = rng.randrange(2, 4)
        return ("and", [rand_pred(rng, depth + 1) for _ in range(n)])
    if roll < 0.85:
        n = rng.randrange(2, 4)
        return ("or", [rand_pred(rng, depth + 1) for _ in range(n)])
    return ("not", rand_pred(rng, depth + 1))


def rand_vexpr(rng: random.Random) -> tuple:
    roll = rng.randrange(6)
    a, b, c = (rng.choice(ATTRS) for _ in range(3))
    if roll == 0:
        return ("attr", a)
    if roll == 1:
        return ("one",)
    if roll == 2:
        return ("mul_attrs", a, b)
    if roll == 3:
        return ("mul_complement", a, rng.randrange(1, 200), b)
    if roll == 4:
        return ("mul_sum", a, rng.randrange(1, 200), b)
    return ("mul_complement_sum", a, rng.randrange(1, 200), b, rng.randrange(1, 200), c)


def rand_query(rng: random.Random) -> dict:
    full = rng.random() < 0.5
    rels = []
    for _ in range(rng.randrange(1, 3)):
        aggs = []
        if full:
            for i in range(rng.randrange(1, 4)):
                aggs.append({
                    "kind": rng.choice(AGGS),
                    "expr": rand_vexpr(rng),
                    "label": f"label_{rng.randrange(1000)}_{i}",
                })
        rels.append({
            "rel": rng.choice(RELS),
            "filter": rand_pred(rng),
            "group_by": rng.sample(ATTRS, rng.randrange(0, 3)) if full else [],
            "aggregates": aggs,
        })
    return {
        "kind": "full" if full else "filter_only",
        "name": f"q_{rng.randrange(10_000)}",
        "rels": rels,
    }


def key(q: dict, opt: str = "O2", fp: int = m.DEFAULT_FINGERPRINT) -> int:
    return m.plan_key(q, opt, fp)


def test_pinned_default_fingerprint() -> None:
    assert m.default_fingerprint() == m.DEFAULT_FINGERPRINT


def test_alias_and_name_invariance_fuzz() -> None:
    rng = random.Random(0xA11A5)
    for _ in range(2000):
        q = rand_query(rng)
        renamed = copy.deepcopy(q)
        renamed["name"] = "completely_different"
        for rq in renamed["rels"]:
            for i, a in enumerate(rq["aggregates"]):
                a["label"] = f"alias_{rng.randrange(1 << 30)}_{i}"
        assert key(q) == key(renamed), q


def test_duplicate_detection_fuzz() -> None:
    # equal keys <=> equal canonical structure, over a population with
    # forced duplicates (same query re-labeled) and near-misses
    rng = random.Random(0xD0B1E)
    by_structure: dict[str, int] = {}
    by_key: dict[int, str] = {}
    pop = []
    for _ in range(1500):
        q = rand_query(rng)
        pop.append(q)
        if rng.random() < 0.3:  # forced alias-duplicate
            d = copy.deepcopy(q)
            d["name"] = "dup"
            for rq in d["rels"]:
                for a in rq["aggregates"]:
                    a["label"] = "dup_label"
            pop.append(d)
    for q in pop:
        s = m.canonical_structure(q)
        k = key(q)
        if s in by_structure:
            assert by_structure[s] == k, f"same structure, different key: {s}"
        else:
            by_structure[s] = k
        if k in by_key:
            assert by_key[k] == s, f"key collision: {s} vs {by_key[k]}"
        else:
            by_key[k] = s


def test_sensitivity_to_every_structural_dimension() -> None:
    rng = random.Random(0x5E45)
    q = {
        "kind": "full",
        "name": "base",
        "rels": [{
            "rel": "LINEITEM",
            "filter": ("and", [
                ("cmp_imm", "l_quantity", "lt", 24),
                ("between", "l_discount", 5, 7),
            ]),
            "group_by": ["l_returnflag"],
            "aggregates": [
                {"kind": "sum",
                 "expr": ("mul_complement", "l_extendedprice", 100, "l_discount"),
                 "label": "rev"},
            ],
        }],
    }
    base = key(q)

    def mutated(fn):
        d = copy.deepcopy(q)
        fn(d)
        return key(d)

    perturbations = [
        lambda d: d["rels"][0]["filter"][1].__setitem__(
            0, ("cmp_imm", "l_quantity", "lt", 25)),        # literal
        lambda d: d["rels"][0]["filter"][1].__setitem__(
            0, ("cmp_imm", "l_quantity", "le", 24)),        # operator
        lambda d: d["rels"][0]["filter"][1].__setitem__(
            0, ("cmp_imm", "l_tax", "lt", 24)),             # attribute
        lambda d: d["rels"][0]["filter"][1].reverse(),      # conjunct order
        lambda d: d["rels"][0].__setitem__("group_by", []), # group-by set
        lambda d: d["rels"][0]["aggregates"][0].__setitem__("kind", "avg"),
        lambda d: d["rels"][0].__setitem__("rel", "ORDERS"),
        lambda d: d.__setitem__("kind", "filter_only"),
        lambda d: d["rels"][0]["aggregates"].append(
            {"kind": "count", "expr": ("one",), "label": "n"}),
    ]
    keys = [mutated(fn) for fn in perturbations]
    keys += [key(q, opt="O0"), key(q, opt="O1"), key(q, fp=m.DEFAULT_FINGERPRINT ^ 1)]
    assert base not in keys
    assert len(set(keys)) == len(keys), "perturbed keys must be distinct"


def golden_query() -> dict:
    """Exercises every predicate, expression and aggregate tag — the
    cross-language golden key fixture (same literal query is built in
    ``rust/src/api/cache.rs``)."""
    return {
        "kind": "full",
        "name": "golden",
        "rels": [{
            "rel": "LINEITEM",
            "filter": ("and", [
                ("cmp_imm", "l_quantity", "lt", 24),
                ("between", "l_discount", 5, 7),
                ("not", ("in_set", "l_shipmode", [1, 3])),
                ("or", [
                    ("cmp_cols", "l_commitdate", "lt", "l_receiptdate"),
                    ("true",),
                ]),
            ]),
            "group_by": ["l_returnflag", "l_linestatus"],
            "aggregates": [
                {"kind": "count", "expr": ("one",), "label": "n"},
                {"kind": "sum",
                 "expr": ("mul_complement", "l_extendedprice", 100, "l_discount"),
                 "label": "rev"},
                {"kind": "avg", "expr": ("attr", "l_quantity"), "label": "avg_q"},
                {"kind": "min", "expr": ("mul_attrs", "l_quantity", "l_tax"), "label": "m1"},
                {"kind": "max",
                 "expr": ("mul_complement_sum", "l_extendedprice", 100, "l_discount",
                          100, "l_tax"),
                 "label": "m2"},
                {"kind": "sum",
                 "expr": ("mul_sum", "l_extendedprice", 100, "l_tax"),
                 "label": "m3"},
            ],
        }],
    }


#: Pinned in Rust too (`golden_key_matches_the_python_mirror_pin`).
GOLDEN_KEY = 0xF4681E9459AE97DE


def test_golden_key_pin() -> None:
    assert key(golden_query()) == GOLDEN_KEY
