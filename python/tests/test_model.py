"""L2 model tests: fused graphs vs oracle, export lowering sanity."""

import numpy as np
import pytest

import jax

from compile import model
from compile.kernels import ref

XB = model.XB_TILE
R = ref.ROWS


def _mk_q6_inputs(seed=0):
    rng = np.random.default_rng(seed)
    shipdate = rng.integers(0, 3000, size=(XB, R), dtype=np.uint64)
    discount = rng.integers(0, 11, size=(XB, R), dtype=np.uint64)
    quantity = rng.integers(1, 51, size=(XB, R), dtype=np.uint64)
    eprice = rng.integers(0, 10_000_00, size=(XB, R), dtype=np.uint64)
    exd = eprice * discount
    valid = np.ones((XB, R), dtype=bool)
    valid[-1, 512:] = False  # emulate a partially-filled last crossbar
    return shipdate, discount, quantity, eprice, exd, valid


def test_q6_filter_agg_matches_oracle():
    shipdate, discount, quantity, eprice, exd, valid = _mk_q6_inputs()
    d0, d1, dlo, dhi, q = 1000, 1365, 5, 7, 24
    counts, nrec = model.q6_filter_agg(
        ref.pack_values(shipdate),
        ref.pack_values(discount),
        ref.pack_values(quantity),
        ref.pack_values(exd),
        ref.imm_to_bits(d0),
        ref.imm_to_bits(d1),
        ref.imm_to_bits(dlo),
        ref.imm_to_bits(dhi),
        ref.imm_to_bits(q),
        ref.pack_mask(valid),
    )
    sel = (
        (shipdate >= d0)
        & (shipdate < d1)
        & (discount >= dlo)
        & (discount <= dhi)
        & (quantity < q)
        & valid
    )
    want_sum = int(exd[sel].sum())
    got_sum = sum(ref.reduce_sum_from_counts(np.array(counts)))
    assert got_sum == want_sum
    got_n = sum(int(c) for c in np.array(nrec)[:, 0])
    assert got_n == int(sel.sum())


def test_q6_selects_nothing_when_range_empty():
    shipdate, discount, quantity, eprice, exd, valid = _mk_q6_inputs(1)
    counts, nrec = model.q6_filter_agg(
        ref.pack_values(shipdate),
        ref.pack_values(discount),
        ref.pack_values(quantity),
        ref.pack_values(exd),
        ref.imm_to_bits(100),
        ref.imm_to_bits(100),  # d0 == d1 -> empty range
        ref.imm_to_bits(0),
        ref.imm_to_bits(10),
        ref.imm_to_bits(51),
        ref.pack_mask(valid),
    )
    assert sum(ref.reduce_sum_from_counts(np.array(counts))) == 0
    assert sum(int(c) for c in np.array(nrec)[:, 0]) == 0


@pytest.mark.parametrize("name", sorted(model.EXPORTS))
def test_exports_lower_to_hlo_text(name):
    from compile.aot import to_hlo_text

    fn, specs = model.EXPORTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 100


def test_manifest_spec_strings():
    from compile.aot import _spec_str

    s = jax.ShapeDtypeStruct((16, 64, 32), np.uint32)
    assert _spec_str(s) == "uint32[16,64,32]"
