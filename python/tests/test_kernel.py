"""Kernel vs oracle tests: the core L1 correctness signal.

Every Pallas bit-plane kernel is checked against the value-level numpy
oracle (ref.py). Hypothesis sweeps values and immediates; bitwise-domain
results must match exactly.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitwise as k
from compile.kernels import ref

XB = k.XB_TILE
R = ref.ROWS

# interpret-mode pallas is slow; keep example counts modest and disable the
# per-example deadline.
HSETTINGS = dict(max_examples=6, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


def _rand_values(seed, bits=64):
    hi = (1 << bits) - 1
    return _rng(seed).integers(0, hi, size=(XB, R), dtype=np.uint64, endpoint=True)


def _structured_values(seed, bits=64):
    """Values with clustering/duplicates to exercise eq paths."""
    rng = _rng(seed)
    base = rng.integers(0, 1 << min(bits, 16), size=(XB, R), dtype=np.uint64)
    mask = (1 << bits) - 1
    return (base * np.uint64(int(rng.integers(1, 5)))) & np.uint64(mask)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31), structured=st.booleans())
def test_cmp_imm(seed, structured):
    vals = _structured_values(seed) if structured else _rand_values(seed)
    imm = int(vals[0, 0])  # guarantee at least one equal row
    eq, lt = k.cmp_imm(ref.pack_values(vals), ref.imm_to_bits(imm))
    req, rlt = ref.cmp_imm(vals, imm)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(eq)), req)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(lt)), rlt)


@pytest.mark.parametrize("imm", [0, 1, (1 << 64) - 1, 0xDEADBEEF])
def test_cmp_imm_edge_immediates(imm):
    vals = _rand_values(7)
    vals[0, 0] = imm  # force an equality hit
    eq, lt = k.cmp_imm(ref.pack_values(vals), ref.imm_to_bits(imm))
    req, rlt = ref.cmp_imm(vals, imm)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(eq)), req)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(lt)), rlt)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31))
def test_cmp_cols(seed):
    a, b = _rand_values(seed), _rand_values(seed + 1)
    b[:, ::3] = a[:, ::3]  # force equal rows
    eq, lt = k.cmp_cols(ref.pack_values(a), ref.pack_values(b))
    req, rlt = ref.cmp_cols(a, b)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(eq)), req)
    np.testing.assert_array_equal(ref.unpack_mask(np.array(lt)), rlt)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31))
def test_add_cols_wraps_mod_2_64(seed):
    a, b = _rand_values(seed), _rand_values(seed + 1)
    s = k.add_cols(ref.pack_values(a), ref.pack_values(b))
    np.testing.assert_array_equal(
        ref.unpack_planes(np.array(s)), ref.add_cols(a, b)
    )


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31), imm=st.integers(0, 2**63))
def test_add_imm(seed, imm):
    a = _rand_values(seed)
    s = k.add_imm(ref.pack_values(a), ref.imm_to_bits(imm))
    np.testing.assert_array_equal(
        ref.unpack_planes(np.array(s)), ref.add_imm(a, imm)
    )


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_mul_cols_32x32(seed):
    a = _rand_values(seed, bits=32)
    b = _rand_values(seed + 1, bits=32)
    p = k.mul_cols(ref.pack_values(a, 32), ref.pack_values(b, 32))
    np.testing.assert_array_equal(
        ref.unpack_planes(np.array(p)), ref.mul_cols(a, b)
    )


def test_mul_by_zero_and_one():
    a = _rand_values(3, bits=32)
    zero = np.zeros_like(a)
    one = np.ones_like(a)
    p0 = k.mul_cols(ref.pack_values(a, 32), ref.pack_values(zero, 32))
    assert (ref.unpack_planes(np.array(p0)) == 0).all()
    p1 = k.mul_cols(ref.pack_values(a, 32), ref.pack_values(one, 32))
    np.testing.assert_array_equal(ref.unpack_planes(np.array(p1)), a)


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31), density=st.floats(0.0, 1.0))
def test_reduce_sum(seed, density):
    vals = _rand_values(seed, bits=40)
    mask = _rng(seed).random((XB, R)) < density
    cnt = k.reduce_sum(ref.pack_values(vals), ref.pack_mask(mask))
    assert ref.reduce_sum_from_counts(np.array(cnt)) == ref.reduce_sum(
        vals, mask
    )


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31), density=st.floats(0.0, 1.0))
def test_reduce_min_max(seed, density):
    vals = _rand_values(seed)
    mask = _rng(seed + 9).random((XB, R)) < density
    pv, pm = ref.pack_values(vals), ref.pack_mask(mask)
    for kern, oracle in ((k.reduce_min, ref.reduce_min), (k.reduce_max, ref.reduce_max)):
        lo, hi, v = kern(pv, pm)
        got = [
            (int(l) | (int(h) << 32), int(vv))
            for l, h, vv in zip(np.array(lo), np.array(hi), np.array(v))
        ]
        assert got == oracle(vals, mask)


def test_reduce_empty_mask_reports_invalid():
    vals = _rand_values(11)
    mask = np.zeros((XB, R), dtype=bool)
    _, _, v = k.reduce_min(ref.pack_values(vals), ref.pack_mask(mask))
    assert (np.array(v) == 0).all()


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31))
def test_column_transform(seed):
    mask = _rng(seed).random((XB, R)) < 0.5
    pm = ref.pack_mask(mask)
    np.testing.assert_array_equal(
        np.array(k.column_transform(pm)), ref.column_transform(pm)
    )


@settings(**HSETTINGS)
@given(seed=st.integers(0, 2**31))
def test_mask_logic_identities(seed):
    rng = _rng(seed)
    a = ref.pack_mask(rng.random((XB, R)) < 0.5)
    b = ref.pack_mask(rng.random((XB, R)) < 0.5)
    m_and = np.array(k.mask_and(a, b))
    m_or = np.array(k.mask_or(a, b))
    m_not_a = np.array(k.mask_not(a))
    np.testing.assert_array_equal(m_and, a & b)
    np.testing.assert_array_equal(m_or, a | b)
    np.testing.assert_array_equal(m_not_a, ~a)
    # De Morgan through the kernels
    nor = np.array(k.mask_nor(a, b))
    np.testing.assert_array_equal(nor, ~(a | b))
    np.testing.assert_array_equal(nor, np.array(k.mask_not(k.mask_or(a, b))))


def test_pack_unpack_roundtrip():
    vals = _rand_values(5)
    np.testing.assert_array_equal(
        ref.unpack_planes(ref.pack_values(vals)), vals
    )
    mask = _rng(5).random((XB, R)) < 0.4
    np.testing.assert_array_equal(ref.unpack_mask(ref.pack_mask(mask)), mask)
