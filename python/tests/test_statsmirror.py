"""Fuzz + golden suite for the zone-map statistics mirror (``statsmirror.py``).

Validates the contract ``rust/src/db/stats.rs`` + ``query::opt::prune``
promise:

* the golden fixture digest is pinned cross-language
  (``GOLDEN_STATS_DIGEST``, also asserted by
  ``stats::tests::golden_digest_pinned_cross_language``);
* the skip-bitmap decision procedure is *sound*: ``True`` proves the
  filter selects no live row on that crossbar, checked on randomized
  relations and predicates against a scan-everything oracle;
* on the predicate shapes it reasons about exactly (single-attribute
  range compares over a zone with no dictionary gaps), the decision is
  also *complete* — no skip opportunity is missed;
* incremental maintenance (``RelStats.update``) equals a full rebuild
  and preserves object identity for untouched crossbars;
* the digest is sensitive to every serialized field.
"""

import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import statsmirror as m  # noqa: E402

SLOTS = m.SUPPLIER_SLOTS


def test_golden_digest_pin():
    assert m.golden_stats_digest() == m.GOLDEN_STATS_DIGEST


def test_rng_reference_stream_is_deterministic():
    a, b = m.Rng(42), m.Rng(42)
    stream = [a.next_u64() for _ in range(100)]
    assert stream == [b.next_u64() for _ in range(100)]
    assert all(0 <= v <= m.U64_MAX for v in stream)
    assert m.Rng(1).next_u64() != m.Rng(2).next_u64()


def random_states(rng, n_xbars):
    states = []
    for _ in range(n_xbars):
        rows = {}
        # small value domains so zone overlaps, gaps, and empty
        # crossbars all occur with useful frequency
        for row in range(rng.randrange(0, 40)):
            if rng.random() < 0.3:
                continue  # dead row
            rows[row] = {
                i: rng.randrange(0, min(1 << bits, 50))
                for i, (_, bits, _) in enumerate(SLOTS)
            }
        states.append(rows)
    return states


def random_pred(rng, depth=0):
    attrs = [name for name, _, _ in SLOTS]
    kind = rng.randrange(0, 8 if depth < 2 else 5)
    attr = rng.choice(attrs)
    v = rng.randrange(0, 55)
    if kind == 0:
        return ("true",)
    if kind == 1:
        op = rng.choice(["eq", "ne", "lt", "le", "gt", "ge"])
        return ("cmp", attr, op, v)
    if kind == 2:
        return ("inset", attr, [rng.randrange(0, 55) for _ in range(rng.randrange(0, 4))])
    if kind == 3:
        lo, hi = v, rng.randrange(0, 55)
        return ("between", attr, lo, hi)
    if kind == 4:
        op = rng.choice(["eq", "ne", "lt", "le", "gt", "ge"])
        return ("cmpcols", attr, op, rng.choice(attrs))
    if kind == 5:
        return ("and", [random_pred(rng, depth + 1) for _ in range(rng.randrange(1, 4))])
    if kind == 6:
        return ("or", [random_pred(rng, depth + 1) for _ in range(rng.randrange(0, 4))])
    return ("not", random_pred(rng, depth + 1))


def test_skip_bitmap_sound_against_scan_everything_oracle():
    rng = random.Random(0xDB10)
    for _ in range(300):
        states = random_states(rng, rng.randrange(1, 6))
        stats = m.RelStats.build(states, SLOTS)
        pred = random_pred(rng)
        skip = m.skip_bitmap(pred, SLOTS, stats)
        assert len(skip) == len(states)
        for x, (s, rows) in enumerate(zip(skip, states)):
            if s:
                # a skip is a proof: the oracle must select nothing
                assert not m.oracle_selects_any(pred, SLOTS, rows), (pred, x, rows)


def test_skip_bitmap_complete_on_range_compares():
    # On single-attribute *range* compares the decision table is exact
    # (min/max are exact bounds): it skips iff the oracle selects
    # nothing. `eq` is excluded — interior gaps of a non-dict zone are
    # invisible to min/max, so `eq` is sound but not complete there.
    rng = random.Random(0xDB11)
    for _ in range(300):
        states = random_states(rng, 3)
        stats = m.RelStats.build(states, SLOTS)
        attr = rng.choice(["s_suppkey", "s_nationkey", "s_acctbal"])
        op = rng.choice(["lt", "le", "gt", "ge"])
        pred = ("cmp", attr, op, rng.randrange(0, 55))
        for s, rows in zip(m.skip_bitmap(pred, SLOTS, stats), states):
            assert s == (not m.oracle_selects_any(pred, SLOTS, rows))


def test_decision_table_cases():
    # one crossbar, one live row domain: s_nationkey in {3, 7}
    rows = {0: {i: 0 for i in range(len(SLOTS))}, 1: {i: 0 for i in range(len(SLOTS))}}
    rows[0][1], rows[1][1] = 3, 7
    stats = m.RelStats.build([rows], SLOTS)
    z = stats.xbars[0].zones[1]
    assert (z.min, z.max, z.dict) == (3, 7, None)
    cases = [
        (("cmp", "s_nationkey", "eq", 2), True),
        (("cmp", "s_nationkey", "eq", 3), False),
        (("cmp", "s_nationkey", "ne", 3), False),
        (("cmp", "s_nationkey", "lt", 3), True),
        (("cmp", "s_nationkey", "lt", 4), False),
        (("cmp", "s_nationkey", "le", 2), True),
        (("cmp", "s_nationkey", "le", 3), False),
        (("cmp", "s_nationkey", "gt", 7), True),
        (("cmp", "s_nationkey", "gt", 6), False),
        (("cmp", "s_nationkey", "ge", 8), True),
        (("cmp", "s_nationkey", "ge", 7), False),
        (("between", "s_nationkey", 0, 2), True),
        (("between", "s_nationkey", 8, 20), True),
        (("between", "s_nationkey", 9, 8), True),  # inverted range
        (("between", "s_nationkey", 7, 9), False),
        (("inset", "s_nationkey", []), True),  # IN () is false
        (("inset", "s_nationkey", [1, 2]), True),
        (("inset", "s_nationkey", [1, 5]), False),
        (("and", [("true",), ("cmp", "s_nationkey", "lt", 3)]), True),
        (("or", []), True),
        (("or", [("cmp", "s_nationkey", "lt", 3), ("true",)]), False),
        (("not", ("cmp", "s_nationkey", "eq", 2)), False),  # no negation reasoning
        (("cmpcols", "s_nationkey", "eq", "s_suppkey"), False),
        (("true",), False),
    ]
    for pred, want in cases:
        assert m.pred_disjoint(pred, SLOTS, stats.xbars[0]) == want, pred


def test_ne_disjoint_only_on_constant_column():
    rows = {r: {i: 5 if i == 1 else 0 for i in range(len(SLOTS))} for r in range(4)}
    stats = m.RelStats.build([rows], SLOTS)
    assert m.pred_disjoint(("cmp", "s_nationkey", "ne", 5), SLOTS, stats.xbars[0])
    assert not m.pred_disjoint(("cmp", "s_nationkey", "ne", 4), SLOTS, stats.xbars[0])


def test_dict_bitmap_catches_in_range_gaps():
    # s_phone_cc (slot 2) is the dict column: values {10, 20} leave a
    # gap at 15 that min/max alone cannot see
    rows = {0: {i: 0 for i in range(len(SLOTS))}, 1: {i: 0 for i in range(len(SLOTS))}}
    rows[0][2], rows[1][2] = 10, 20
    stats = m.RelStats.build([rows], SLOTS)
    z = stats.xbars[0].zones[2]
    assert z.dict == (1 << 10) | (1 << 20)
    assert m.pred_disjoint(("cmp", "s_phone_cc", "eq", 15), SLOTS, stats.xbars[0])
    assert not m.pred_disjoint(("cmp", "s_phone_cc", "eq", 20), SLOTS, stats.xbars[0])
    assert m.pred_disjoint(("inset", "s_phone_cc", [11, 15, 19]), SLOTS, stats.xbars[0])


def test_empty_crossbar_skips_everything():
    stats = m.RelStats.build([{}], SLOTS)
    assert stats.xbars[0].live_rows == 0
    for z in stats.xbars[0].zones:
        assert z.min > z.max
    assert m.pred_disjoint(("true",), SLOTS, stats.xbars[0])
    assert m.pred_disjoint(("not", ("true",)), SLOTS, stats.xbars[0])


def test_incremental_update_equals_full_rebuild():
    rng = random.Random(0xDB12)
    for _ in range(50):
        old = random_states(rng, 4)
        prev = m.RelStats.build(old, SLOTS)
        new = [dict(rows) for rows in old]
        # mutate one crossbar, sometimes append another
        tgt = rng.randrange(0, 4)
        new[tgt] = random_states(rng, 1)[0]
        if rng.random() < 0.5:
            new.append(random_states(rng, 1)[0])
        inc = m.RelStats.update(prev, old, new, SLOTS)
        full = m.RelStats.build(new, SLOTS)
        assert inc.digest() == full.digest()
        for x in range(len(old)):
            if old[x] == new[x]:
                assert inc.xbars[x] is prev.xbars[x]  # reused, not rebuilt


def test_digest_sensitive_to_every_field():
    states = m.golden_states(SLOTS, 2, 9)
    base = m.RelStats.build(states, SLOTS)
    d0 = base.digest()
    tweaked = m.RelStats.build(states, SLOTS)
    tweaked.xbars[1].live_rows += 1
    assert tweaked.digest() != d0
    for field in ("min", "max", "dict"):
        t = m.RelStats.build(states, SLOTS)
        z = t.xbars[0].zones[2]  # the dict slot: all three fields present
        setattr(z, field, (getattr(z, field) or 0) ^ 1)
        assert t.digest() != d0, field
