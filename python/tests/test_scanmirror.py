"""Fuzz-validation of the shared-scan analysis via the Python mirror.

Two properties back the Rust execution path (api::Pimdb's per-relation
mask cache):

* **Cross-query key sharing** — the same predicate compiled into plans
  with *different* aggregate suffixes (then `-O2` optimized, so the
  compute-column placement differs) keys identically for the vast
  majority of programs, and **equal keys always mean equal masks**.
  Sharing is opportunistic: CSE elision decisions inspect the suffix,
  so a redundant predicate (e.g. an IN-list with duplicate values) can
  legitimately optimize to different prefix streams under different
  aggregates — a missed share, never a wrong one.
* **Replay equivalence** — transplanting the captured mask planes into
  a freshly loaded state and executing only the suffix must be
  bit-identical (reduce stream + mask popcount) to the full run.
"""

import random

import optmirror as m
import scanmirror as sm

from test_optmirror import LAYOUT, XBAR_COLS, gen_records, load, rand_pred, \
    rand_aggregates


def compile_opt(pred, group_by, aggregates, level=2):
    comp = m.Compiler(LAYOUT, XBAR_COLS)
    return m.optimize(comp.compile(pred, group_by, aggregates), level)


def run(c, records):
    st = load(records)
    return m.exec_steps(st, c.steps, c.mask_col)


def test_prefix_covers_filter_and_key_is_renaming_invariant():
    pred = ("cmp", "k", "<", 50)
    a = compile_opt(pred, [], [("count", ("one",))])
    b = compile_opt(pred, [], [("sum", ("attr", "v"))])
    ia, ib = sm.scan_info(a), sm.scan_info(b)
    assert ia is not None and ib is not None
    assert ia.prefix_len > 0
    assert ia.key == ib.key, "same filter must normalize to one key"


def test_key_is_sensitive_to_the_predicate():
    base = sm.scan_info(compile_opt(("cmp", "k", "<", 50), [], []))
    lit = sm.scan_info(compile_opt(("cmp", "k", "<", 51), [], []))
    attr = sm.scan_info(compile_opt(("cmp", "v", "<", 50), [], []))
    op = sm.scan_info(compile_opt(("cmp", "k", ">", 50), [], []))
    assert base is not None
    for other in (lit, attr, op):
        assert other is None or other.key != base.key


def test_side_effect_in_prefix_bails():
    a = m.ColRange(0, 8)
    mask = m.ColRange(30, 1)
    steps = [
        m.Step(m.with_imm(m.LT_IMM, a, mask, 50), "filter"),
        m.Step(m.unary(m.RSUM, a, a), "aggcol"),
        m.Step(m.with_imm(m.LT_IMM, a, mask, 50), "filter"),
    ]
    c = m.Compiled(steps, 30, 0, [], LAYOUT.compute_base, LAYOUT.valid_col, 1)
    assert sm.scan_info(c) is None


def test_fuzz_cross_query_key_sharing():
    rng = random.Random(0x5CA17)
    shared = total = 0
    for _ in range(300):
        pred = rand_pred(rng, rng.randint(0, 2))
        aggs_a = rand_aggregates(rng)
        aggs_b = rand_aggregates(rng)
        try:
            ca = compile_opt(pred, [], aggs_a)
            cb = compile_opt(pred, [], aggs_b)
        except MemoryError:
            continue  # compute-area exhaustion: legitimate compile error
        ia, ib = sm.scan_info(ca), sm.scan_info(cb)
        total += 1
        if ia is None or ib is None or ia.key != ib.key:
            continue
        shared += 1
        # equal keys must mean equal mask planes on the same data (the
        # suffix never writes the mask, so end-of-run masks compare the
        # prefixes exactly) — this is what makes cache replay safe
        records = gen_records(rng, rng.randint(0, 32))
        sa, sb = load(records), load(records)
        m.exec_steps(sa, ca.steps, ca.mask_col)
        m.exec_steps(sb, cb.steps, cb.mask_col)
        assert sa.planes[ca.mask_col] == sb.planes[cb.mask_col], (
            f"equal keys, diverging masks: {pred} / {aggs_a} vs {aggs_b}")
    # sharing must be the common case, not a lucky corner
    assert total > 200
    assert shared > total // 2, (shared, total)


def test_fuzz_replay_is_bit_identical_to_full_execution():
    rng = random.Random(0xD157)
    replayed = 0
    for _ in range(200):
        pred = rand_pred(rng, rng.randint(0, 2))
        aggs = rand_aggregates(rng)
        group_by = []
        if aggs and rng.random() < 0.4:
            group_by = rng.sample(["d1", "d2"], rng.randint(1, 2))
        records = gen_records(rng, rng.randint(0, 32))
        try:
            c = compile_opt(pred, group_by, aggs)
        except MemoryError:
            continue
        info = sm.scan_info(c)
        if info is None:
            continue
        # full run, capturing the mask planes at program end (no suffix
        # step writes the mask column, so end-of-run == split point)
        st_full = load(records)
        want = m.exec_steps(st_full, c.steps, c.mask_col)
        captured = st_full.planes[c.mask_col]
        # replay: fresh state (compute area zeroed), transplant, suffix
        st_replay = load(records)
        st_replay.planes[c.mask_col] = captured
        got = m.exec_steps(st_replay, c.steps[info.prefix_len:], c.mask_col)
        assert got == want, f"replay drift on {pred} / {aggs}"
        replayed += 1
    assert replayed > 100, replayed
