"""Fuzz + golden suite for the free-row allocator mirror (``dmlmirror.py``).

Validates the contract the Rust ``db::freerows::FreeRowMap`` promises:

* allocation always returns the free row minimizing ``(wear, index)``
  (checked against a from-scratch oracle on randomized op sequences, so
  stale entries in the incremental ordered-set bookkeeping cannot hide);
* per-row wear counters are monotonically nondecreasing;
* liveness bookkeeping is exact under arbitrary alloc/free/grow/charge
  interleavings;
* the allocation-order digest is pinned cross-language via
  ``GOLDEN_ALLOC_DIGEST`` (also asserted in ``rust/src/db/freerows.rs``).
"""

import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import dmlmirror as m  # noqa: E402


def test_golden_alloc_digest_pin():
    assert m.golden_alloc_digest() == m.GOLDEN_ALLOC_DIGEST


def test_alloc_prefers_least_worn_then_lowest_index():
    fm = m.FreeRowMap(capacity=8, initial_live=0, rows_per_xbar=8)
    fm.charge_row(0, 5)
    fm.charge_row(1, 2)
    fm.charge_row(3, 2)
    # rows 2,4..7 have wear 0 -> lowest index wins
    assert fm.alloc() == 2
    assert fm.alloc() == 4
    assert fm.alloc() == 5
    assert fm.alloc() == 6
    assert fm.alloc() == 7
    # ties at wear 2 -> row 1 before row 3; worn row 0 last
    assert fm.alloc() == 1
    assert fm.alloc() == 3
    assert fm.alloc() == 0
    assert fm.alloc() is None


def test_release_makes_row_allocatable_again_with_its_wear():
    fm = m.FreeRowMap(capacity=4, initial_live=4, rows_per_xbar=4)
    assert fm.alloc() is None
    fm.charge_row(1, 10)
    fm.release(1)
    fm.release(2)
    # row 2 (wear 0) beats row 1 (wear 10)
    assert fm.alloc() == 2
    assert fm.alloc() == 1


def test_charge_profile_repeats_per_crossbar():
    fm = m.FreeRowMap(capacity=8, initial_live=8, rows_per_xbar=4)
    fm.charge_profile([1, 2, 3, 4])
    assert fm.wear == [1, 2, 3, 4, 1, 2, 3, 4]


def test_fuzz_against_from_scratch_oracle():
    rng = random.Random(0xD31)
    for _case in range(300):
        cap = rng.randrange(1, 40)
        live0 = rng.randrange(0, cap + 1)
        rpx = rng.choice([1, 2, 4, 8, 16])
        fm = m.FreeRowMap(capacity=cap, initial_live=live0, rows_per_xbar=rpx)
        # shadow state for the oracle
        live = [i < live0 for i in range(cap)]
        wear = [0] * cap
        prev_wear = list(wear)
        for _step in range(60):
            op = rng.randrange(5)
            if op == 0:
                want = m.oracle_alloc_choice(live, wear)
                got = fm.alloc()
                assert got == want, (cap, live0, live, wear)
                if got is not None:
                    live[got] = True
            elif op == 1:
                live_rows = [i for i, v in enumerate(live) if v]
                if live_rows:
                    row = rng.choice(live_rows)
                    fm.release(row)
                    live[row] = False
            elif op == 2:
                row = rng.randrange(len(live))
                w = rng.randrange(1, 9)
                fm.charge_row(row, w)
                wear[row] += w
            elif op == 3:
                totals = [rng.randrange(0, 4) for _ in range(rpx)]
                fm.charge_profile(totals)
                for i in range(len(wear)):
                    wear[i] += totals[i % rpx]
            else:
                n = rng.choice([rpx, 2 * rpx])
                fm.grow(n)
                live.extend([False] * n)
                wear.extend([0] * n)
            # invariants: exact liveness/wear mirror + monotone wear
            assert [fm.is_live(i) for i in range(fm.capacity())] == live
            assert fm.wear == wear
            assert all(a >= b for a, b in zip(fm.wear, prev_wear))
            prev_wear = list(fm.wear)
            assert fm.live_count() == sum(live)


def test_update_run_rewrite_matches_direct_assignment():
    rng = random.Random(0xB17)
    for _ in range(2000):
        bits = rng.randrange(1, 37)
        value = rng.randrange(1 << bits)
        old = rng.randrange(1 << bits)
        runs = m.update_runs(value, bits)
        # runs partition [0, bits) exactly
        assert sum(length for _, length, _ in runs) == bits
        assert runs[0][0] == 0
        for (lo, ln, _), (lo2, _, _) in zip(runs, runs[1:]):
            assert lo + ln == lo2
        # selected rows end up holding exactly `value`
        assert m.apply_update_runs(runs, old, selected=True) == value
        # non-selected (and dead) rows are untouched
        assert m.apply_update_runs(runs, old, selected=False) == old


def test_digest_is_sensitive_to_the_policy():
    # flipping the tie-break (highest index instead of lowest) must change
    # the digest: monkey-patch alloc to take max instead of min
    class Flipped(m.FreeRowMap):
        def alloc(self):
            if not self.free_entries:
                return None
            entry = max(self.free_entries, key=lambda e: (e[0], -e[1]))
            self.free_entries.remove(entry)
            self.live[entry[1]] = True
            return entry[1]

    orig = m.FreeRowMap
    try:
        m.FreeRowMap = Flipped
        assert m.golden_alloc_digest() != m.GOLDEN_ALLOC_DIGEST
    finally:
        m.FreeRowMap = orig
