"""Fuzz-validation of the multi-query scan fusion pass via the mirror.

The property backing the Rust batch path (api::Pimdb::execute_batch):
for any batch of shared-scan prefixes, the fused program must compute
every member's mask bit-identically to running each member's prefix
alone on the same data — the cross-query CSE may only elide work, never
change it. The structural unit tests mirror fusion.rs's, and the golden
FNV-1a digest is pinned on both sides of the language boundary (the
Rust twin is fusion::tests::golden_digest_matches_python_mirror).
"""

import random

import fusionmirror as fm
import optmirror as m
import scanmirror as sm

from test_optmirror import LAYOUT, XBAR_COLS, gen_records, load, rand_pred
from test_scanmirror import compile_opt

BASE = 25
VALID = 24

# Pinned in fusion.rs::tests::golden_digest_matches_python_mirror.
GOLDEN_DIGEST = 0x22A458559DAACA33


def lt_prefix(imm, tmp, mask):
    """LtImm(attr < imm) -> tmp; And(tmp, VALID) -> mask — the fixture
    shape of the Rust unit tests."""
    return [
        m.Step(m.with_imm(m.LT_IMM, m.ColRange(0, 8), m.ColRange(tmp, 1), imm)),
        m.Step(m.binary(m.AND, m.ColRange(tmp, 1), m.ColRange(VALID, 1),
                        m.ColRange(mask, 1))),
    ]


def test_fuse_dedups_cross_query_subexpressions():
    p0 = lt_prefix(50, 26, 25)
    p1 = lt_prefix(50, 30, 28)
    p1.append(m.Step(m.with_imm(m.EQ_IMM, m.ColRange(8, 8), m.ColRange(29, 1), 3)))
    p1.append(m.Step(m.binary(m.AND, m.ColRange(28, 1), m.ColRange(29, 1),
                              m.ColRange(31, 1))))
    progs = [fm.ScanProgram(tuple(p0), 25), fm.ScanProgram(tuple(p1), 31)]
    fused = fm.fuse(progs, BASE, 64)
    assert len(fused) == 1
    f = fused[0]
    assert f.members == [0, 1]
    assert len(f.steps) == 4
    assert f.saved_steps == 2
    assert f.peak_cols == 4
    assert f.mask_cols == [BASE + 1, BASE + 3]
    fused2 = fm.fuse([fm.ScanProgram(tuple(p0), 25)] * 2, BASE, 64)
    assert len(fused2) == 1
    assert len(fused2[0].steps) == 2
    assert fused2[0].mask_cols == [BASE + 1, BASE + 1]


def test_column_budget_overflow_starts_a_new_chunk():
    progs = [fm.ScanProgram(tuple(lt_prefix(i, 26, 25)), 25) for i in (10, 20, 30)]
    fused = fm.fuse(progs, BASE, BASE + 5)
    assert len(fused) == 2
    assert fused[0].members == [0, 1]
    assert fused[1].members == [2]
    assert fused[1].mask_cols == [BASE + 1]


def test_unsafe_members_fall_back_to_singletons():
    bad = [m.Step(m.binary(m.AND, m.ColRange(40, 1), m.ColRange(VALID, 1),
                           m.ColRange(25, 1)))]
    progs = [fm.ScanProgram(tuple(bad), 25),
             fm.ScanProgram(tuple(lt_prefix(7, 26, 25)), 25)]
    fused = fm.fuse(progs, BASE, 64)
    assert len(fused) == 2
    assert fused[0].members == [0]
    assert fused[0].saved_steps == 0
    assert fused[0].steps == bad
    assert fused[0].mask_cols == [25]
    assert fused[1].members == [1]


def test_golden_digest():
    """The exact input of the Rust twin test; equal digests mean the two
    ports agree on the fused steps, mask columns, membership and CSE
    savings byte for byte."""
    p0 = lt_prefix(50, 26, 25)
    p1 = lt_prefix(50, 30, 28)
    p1.append(m.Step(m.with_imm(m.GT_IMM, m.ColRange(8, 8), m.ColRange(29, 1), 11)))
    p1.append(m.Step(m.binary(m.AND, m.ColRange(28, 1), m.ColRange(29, 1),
                              m.ColRange(31, 1))))
    p2 = lt_prefix(9, 27, 26)
    progs = [fm.ScanProgram(tuple(p0), 25),
             fm.ScanProgram(tuple(p1), 31),
             fm.ScanProgram(tuple(p2), 26)]
    fused = fm.fuse(progs, BASE, 64)
    assert fm.digest(fused) == GOLDEN_DIGEST


def run_prefix(steps, records):
    st = load(records)
    out = []
    for s in steps:
        m.exec_instr(st, s.instr, out)
    assert not out, "prefixes are side-effect free"
    return st


def test_fuzz_fused_masks_match_serial_execution():
    """Random batches of compiled+optimized prefixes, fused under both a
    roomy and a deliberately tight column budget: every chunk covers its
    members exactly once, the step accounting balances, and each member's
    fused mask plane equals its serial (prefix-alone) mask plane."""
    rng = random.Random(0xF05ED)
    batches = chunks_with_sharing = 0
    for _ in range(120):
        members = []
        for _ in range(rng.randint(2, 6)):
            pred = rand_pred(rng, rng.randint(0, 2))
            try:
                c = compile_opt(pred, [], [("count", ("one",))])
            except MemoryError:
                continue
            info = sm.scan_info(c)
            if info is None:
                continue
            members.append((c, info))
        if len(members) < 2:
            continue
        # duplicates exercise the CSE hit path and shared mask columns
        if rng.random() < 0.5:
            members.append(members[rng.randrange(len(members))])
        progs = [fm.ScanProgram(tuple(c.steps[:info.prefix_len]), c.mask_col)
                 for c, info in members]
        col_limit = (LAYOUT.compute_base + rng.randint(2, 12)
                     if rng.random() < 0.4 else XBAR_COLS)
        fused = fm.fuse(progs, LAYOUT.compute_base, col_limit)
        covered = sorted(i for f in fused for i in f.members)
        assert covered == list(range(len(progs))), "member lost or duplicated"
        records = gen_records(rng, rng.randint(0, 32))
        serial = [run_prefix(p.steps, records).planes[p.mask_col] for p in progs]
        for f in fused:
            st = run_prefix(f.steps, records)
            assert sum(len(progs[i].steps) for i in f.members) == \
                len(f.steps) + f.saved_steps, "step accounting out of balance"
            for mc, midx in zip(f.mask_cols, f.members):
                assert st.planes[mc] == serial[midx], (
                    f"fused mask diverged for member {midx}")
            if f.saved_steps > 0:
                chunks_with_sharing += 1
        batches += 1
    assert batches > 60, batches
    assert chunks_with_sharing > 20, chunks_with_sharing
