"""Fuzz + golden suite for the epoch visibility mirror (``epochmirror.py``).

Validates the contract the Rust ``util::bits::EpochMask`` +
``db::freerows::EpochRowMap`` pair promises:

* the committed view (``is_live``/``live_count``/wear) is *frozen* while
  a batch mutates its pending clone — snapshot stability, checked on
  randomized begin/mutate/commit/abort interleavings against a
  from-scratch two-version oracle (committed liveness vector + optional
  pending vector);
* commit atomically replaces the whole view and bumps the epoch; abort
  leaves committed state and wear untouched (an aborted batch charges
  no wear);
* after every commit/abort the active mask plane equals the committed
  map's liveness (the invariant the valid-AND elision relies on);
* the interleaving digest is pinned cross-language via
  ``GOLDEN_EPOCH_DIGEST`` (also asserted in ``rust/src/db/freerows.rs``).
"""

import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import epochmirror as m  # noqa: E402
from dmlmirror import FreeRowMap  # noqa: E402


def test_golden_epoch_digest_pin():
    assert m.golden_epoch_digest() == m.GOLDEN_EPOCH_DIGEST


def test_commit_flips_visibility_atomically():
    em = m.EpochRowMap(FreeRowMap(capacity=8, initial_live=4, rows_per_xbar=8))
    pending = em.begin_batch()
    pending.release(1)
    row = pending.alloc()
    assert row == 1  # ties at wear 0 break to lowest index
    pending.charge_row(row, 3)
    # committed view frozen mid-batch
    assert em.is_live(1)
    assert em.live_count() == 4
    assert em.committed().row_wear(1) == 0
    em.commit_batch(pending)
    assert em.epoch() == 1
    assert em.committed().row_wear(1) == 3


def test_abort_charges_no_wear_and_keeps_visibility():
    em = m.EpochRowMap(FreeRowMap(capacity=8, initial_live=4, rows_per_xbar=8))
    pending = em.begin_batch()
    pending.release(0)
    pending.charge_row(2, 99)
    em.abort_batch()
    assert em.epoch() == 0
    assert em.is_live(0)
    assert em.committed().row_wear(2) == 0
    # the next batch starts from the committed state, not the shadow
    p2 = em.begin_batch()
    assert p2.is_live(0)
    assert p2.row_wear(2) == 0


def test_commit_grows_mask_to_pending_capacity():
    em = m.EpochRowMap(FreeRowMap(capacity=4, initial_live=4, rows_per_xbar=4))
    pending = em.begin_batch()
    assert pending.alloc() is None
    pending.grow(4)
    assert pending.alloc() == 4
    em.commit_batch(pending)
    assert em.committed().capacity() == 8
    assert em.is_live(4) and not em.is_live(5)
    assert em.live_count() == 5


def test_fuzz_interleavings_against_two_version_oracle():
    rng = random.Random(0xE70C)
    for _case in range(300):
        cap = rng.randrange(1, 33)
        live0 = rng.randrange(0, cap + 1)
        em = m.EpochRowMap(FreeRowMap(capacity=cap, initial_live=live0, rows_per_xbar=8))
        committed = [i < live0 for i in range(cap)]
        pending = None  # (FreeRowMap clone, oracle liveness vector)
        epoch = 0
        prev_wear = list(em.committed().wear)
        for _step in range(50):
            op = rng.randrange(5)
            if op == 0 and pending is None:
                pending = (em.begin_batch(), list(committed))
            elif op == 1 and pending is not None:
                p, flags = pending
                kind = rng.randrange(3)
                if kind == 0:
                    r = p.alloc()
                    if r is not None:
                        flags[r] = True
                elif kind == 1:
                    live_rows = [i for i, v in enumerate(flags) if v]
                    if live_rows:
                        r = rng.choice(live_rows)
                        p.release(r)
                        flags[r] = False
                else:
                    p.grow(8)
                    flags.extend([False] * 8)
            elif op == 2 and pending is not None:
                p, flags = pending
                em.commit_batch(p)
                committed = flags
                epoch += 1
                pending = None
            elif op == 3 and pending is not None:
                em.abort_batch()
                pending = None
            # committed view == oracle committed vector, always —
            # including mid-batch (snapshot stability)
            assert em.epoch() == epoch
            assert em.in_batch() == (pending is not None)
            assert [em.is_live(r) for r in range(len(committed))] == committed
            assert [
                em.committed().is_live(r) for r in range(len(committed))
            ] == committed
            assert em.live_count() == sum(committed)
            # active mask plane == committed liveness (padding rows dead)
            assert em.mask.count_ones() == sum(committed)
            # committed wear is monotone: a batch charges wear only at
            # commit (pending wear replaces, never decreases per row on
            # the surviving prefix), an abort charges none
            wear = em.committed().wear
            assert all(a >= b for a, b in zip(wear, prev_wear))
            prev_wear = list(wear)


def test_digest_is_sensitive_to_the_visibility_rule():
    # breaking the publish step — committing the map but never flipping
    # the mask plane, so readers keep the stale view — must change the
    # digest (the mid-batch/post-commit probes fold ``is_live`` answers)
    class StaleMask(m.EpochRowMap):
        def commit_batch(self, pending):
            assert self.in_batch_flag
            if pending.capacity() > self.mask.capacity():
                self.mask.grow(pending.capacity() - self.mask.capacity())
            self.mask.abort_batch()  # drop the shadow instead of publishing
            self.committed_map = pending
            self.epoch_ctr += 1
            self.in_batch_flag = False

    orig = m.EpochRowMap
    try:
        m.EpochRowMap = StaleMask
        assert m.golden_epoch_digest() != m.GOLDEN_EPOCH_DIGEST
    finally:
        m.EpochRowMap = orig
