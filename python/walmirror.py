"""Python mirror of the WAL record codec and torn-tail truncation
decision in ``rust/src/storage/wal.rs``.

Same discipline as ``dmlmirror.py`` / ``epochmirror.py``: the authoring
environment has no Rust toolchain, so the recovery decision procedure is
written here first, fuzz-validated (``tests/test_walmirror.py``), and
ported line by line to Rust. ``golden_wal_digest()`` builds a scripted
WAL image, scans it truncated at every record boundary plus off-boundary
cuts plus two bit-flipped variants, and folds the identical observations
into one constant pinned on both sides (``GOLDEN_WAL_DIGEST`` here,
asserted in the Rust unit tests of ``wal.rs``) — so a one-sided change
to the frame layout, the payload codec, *or* the torn-vs-corrupt rule
breaks exactly one of the two suites.

The rule being pinned: a frame cut short by a crash (fewer than 12 bytes
left, or a declared length past EOF) is a **torn tail**, silently
truncated at the last record boundary; a *complete* frame whose checksum
does not verify is **corruption** and refuses the whole file. Pure
truncation can only produce the former, so crash recovery always lands
on a batch boundary; bit rot always produces the latter.

Run directly to print the golden digest::

    python3 python/walmirror.py
"""

from __future__ import annotations

from dataclasses import dataclass, field

from dmlmirror import FNV_OFFSET, FNV_PRIME, MASK64, _fnv1a_fold

#: First 8 bytes of every WAL segment (mirror of ``WAL_MAGIC``).
WAL_MAGIC = b"PIMWAL01"
#: Header bytes: magic + schema/geometry fingerprint.
WAL_HEADER = 16
#: Frame prefix bytes: u32 payload length + u64 payload checksum.
FRAME_PREFIX = 12

#: Cross-language pin: ``golden_wal_digest()`` in both languages.
GOLDEN_WAL_DIGEST = 0xD4826F2D77DEBD67


class CorruptError(ValueError):
    """Mirror of ``PimdbError::Corrupt`` — on-disk state failed
    validation (checksum mismatch, mangled counts, trailing bytes)."""


def fnv1a(data: bytes) -> int:
    """FNV-1a 64 over a byte stream (mirror of ``api::cache::fnv1a``)."""
    state = FNV_OFFSET
    for byte in data:
        state = ((state ^ byte) * FNV_PRIME) & MASK64
    return state


@dataclass
class WalRecord:
    """One committed DML batch, as logged (mirror of the Rust struct)."""

    rel_tag: int
    epoch: int
    #: ``(crossbar row, cell writes)`` pairs — the reader-wear profile
    #: folded into the committed map at batch begin.
    fold: list = field(default_factory=list)
    #: Canonical ``dml_bytes`` per statement, in batch order.
    stmts: list = field(default_factory=list)

    def encode_payload(self) -> bytes:
        b = bytearray()
        b.append(self.rel_tag)
        b += self.epoch.to_bytes(8, "little")
        b += len(self.fold).to_bytes(4, "little")
        for idx, wear in self.fold:
            b += idx.to_bytes(4, "little")
            b += wear.to_bytes(8, "little")
        b += len(self.stmts).to_bytes(4, "little")
        for s in self.stmts:
            b += len(s).to_bytes(4, "little")
            b += s
        return bytes(b)

    def encode_frame(self) -> bytes:
        payload = self.encode_payload()
        return (
            len(payload).to_bytes(4, "little")
            + fnv1a(payload).to_bytes(8, "little")
            + payload
        )


class _De:
    """Bounded little-endian reader over untrusted bytes (mirror of
    ``De``): every overrun raises :class:`CorruptError`, never an
    ``IndexError``."""

    def __init__(self, buf: bytes, what: str):
        self.buf = buf
        self.pos = 0
        self.what = what

    def _corrupt(self, why: str) -> CorruptError:
        return CorruptError(f"{self.what}: {why} at byte {self.pos}")

    def take(self, n: int) -> bytes:
        if len(self.buf) - self.pos < n:
            raise self._corrupt("truncated field")
        s = self.buf[self.pos : self.pos + n]
        self.pos += n
        return s

    def u8(self) -> int:
        return self.take(1)[0]

    def u32(self) -> int:
        return int.from_bytes(self.take(4), "little")

    def u64(self) -> int:
        return int.from_bytes(self.take(8), "little")

    def count(self, min_elem_bytes: int) -> int:
        n = self.u32()
        if n * min_elem_bytes > len(self.buf) - self.pos:
            raise self._corrupt("element count exceeds remaining bytes")
        return n

    def bytes_(self) -> bytes:
        return self.take(self.count(1))

    def finish(self) -> None:
        if self.pos != len(self.buf):
            raise self._corrupt("trailing bytes after decode")


def decode_payload(payload: bytes) -> WalRecord:
    """Decode a checksum-verified payload (mirror of
    ``WalRecord::decode_payload``)."""
    d = _De(payload, "wal record")
    rel_tag = d.u8()
    epoch = d.u64()
    fold = [(d.u32(), d.u64()) for _ in range(d.count(12))]
    stmts = [d.bytes_() for _ in range(d.count(4))]
    d.finish()
    return WalRecord(rel_tag, epoch, fold, stmts)


@dataclass
class WalScan:
    """Mirror of the Rust ``WalScan`` result."""

    records: list
    valid_len: int
    torn: bool


def scan_records(buf: bytes, fingerprint: int) -> WalScan:
    """THE recovery decision procedure — mirrors
    ``wal::scan_records`` line by line. Incomplete tail frames report
    torn; complete frames failing checksum or payload decode raise
    :class:`CorruptError`; a wrong magic or fingerprint refuses the
    whole file. A file shorter than its header is torn at offset 0."""
    if len(buf) < WAL_HEADER:
        return WalScan([], 0, True)
    if buf[:8] != WAL_MAGIC:
        raise CorruptError("wal header: bad magic")
    fp = int.from_bytes(buf[8:16], "little")
    if fp != fingerprint:
        raise CorruptError(
            f"wal header: fingerprint {fp:#018x} does not match this "
            f"schema/geometry ({fingerprint:#018x})"
        )
    records = []
    off = WAL_HEADER
    torn = False
    while off < len(buf):
        rem = len(buf) - off
        if rem < FRAME_PREFIX:
            torn = True
            break
        length = int.from_bytes(buf[off : off + 4], "little")
        if rem - FRAME_PREFIX < length:
            torn = True
            break
        crc = int.from_bytes(buf[off + 4 : off + 12], "little")
        payload = buf[off + FRAME_PREFIX : off + FRAME_PREFIX + length]
        if fnv1a(payload) != crc:
            raise CorruptError(
                f"wal record {len(records)}: checksum mismatch at byte {off}"
            )
        records.append(decode_payload(payload))
        off += FRAME_PREFIX + length
    return WalScan(records, off if torn else len(buf), torn)


def golden_wal_digest() -> int:
    """Mirror of ``wal::golden_wal_digest()``: the scripted WAL image,
    the crash-point sweep, the two bit-flip probes, and the observation
    fold — identical on both sides, one constant."""
    fingerprint = 0x51AE77C0DE01F00D
    state_x = [9]

    def nxt() -> int:
        state_x[0] = (
            state_x[0] * 6364136223846793005 + 1442695040888963407
        ) & MASK64
        return state_x[0]

    buf = bytearray()
    buf += WAL_MAGIC
    buf += fingerprint.to_bytes(8, "little")
    boundaries = [0, WAL_HEADER]
    for i in range(5):
        rel_tag = (nxt() >> 4) % 6
        fold_n = nxt() % 4
        fold = [((nxt() >> 8) % 1024, nxt() % 100 + 1) for _ in range(fold_n)]
        stmt_n = nxt() % 3 + 1
        stmts = []
        for _ in range(stmt_n):
            length = nxt() % 40
            stmts.append(bytes((nxt() >> 16) & 0xFF for _ in range(length)))
        rec = WalRecord(rel_tag, i + 1, fold, stmts)
        buf += rec.encode_frame()
        boundaries.append(len(buf))
    cuts = []
    for b in boundaries:
        cuts.append(b)
        if b > 0:
            cuts.append(b - 1)
        if b + 5 <= len(buf):
            cuts.append(b + 5)

    state = FNV_OFFSET

    def observe(state: int, data: bytes) -> int:
        try:
            scan = scan_records(bytes(data), fingerprint)
        except CorruptError:
            return _fnv1a_fold(state, 0xDEAD)
        state = _fnv1a_fold(state, 1)
        state = _fnv1a_fold(state, len(scan.records))
        state = _fnv1a_fold(state, scan.valid_len)
        state = _fnv1a_fold(state, int(scan.torn))
        for rec in scan.records:
            state = _fnv1a_fold(state, rec.rel_tag)
            state = _fnv1a_fold(state, rec.epoch)
            state = _fnv1a_fold(state, len(rec.fold))
            for idx, wear in rec.fold:
                state = _fnv1a_fold(state, idx)
                state = _fnv1a_fold(state, wear)
            state = _fnv1a_fold(state, len(rec.stmts))
            for s in rec.stmts:
                state = _fnv1a_fold(state, fnv1a(s))
        return state

    for t in cuts:
        state = observe(state, buf[:t])
    # a bit flip inside the first record's complete payload must be
    # refused as corruption, not truncated as a torn tail
    flipped = bytearray(buf)
    flipped[WAL_HEADER + FRAME_PREFIX + 2] ^= 0x04
    state = observe(state, flipped)
    # ...and a flip in a frame length field must never surface a record
    # that was not cleanly framed
    flipped_len = bytearray(buf)
    flipped_len[WAL_HEADER] ^= 0x80
    state = observe(state, flipped_len)
    return state


if __name__ == "__main__":
    print(hex(golden_wal_digest()))
