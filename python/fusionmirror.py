"""Python mirror of the multi-query scan fusion pass
(rust/src/query/opt/fusion.rs).

Fuses a batch of shared-scan filter prefixes over one relation into one
program computing every member's mask in a single pass, with a
cross-query value-numbering CSE in SSA form: every emitted write
allocates fresh fused compute columns (written exactly once, so the
column id doubles as the value number) and each member carries a private
rename map from its original compute columns to fused columns. The Rust
crate's authoring environment has no toolchain, so the pass is validated
here against the compiler + engine mirrors in optmirror.py, fuzzed over
random query batches (python/tests/test_fusionmirror.py), with a golden
FNV-1a digest pinned on both sides of the language boundary. Keep in
sync with the Rust source; the port favours structural similarity over
Pythonic style on purpose.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import optmirror as m
from scanmirror import OP_TAG


@dataclass(frozen=True)
class ScanProgram:
    """One member query's shared-scan filter prefix, as split by
    scanmirror.scan_info: `steps` are the program's first prefix_len
    steps and `mask_col` the filter-mask column the prefix writes."""

    steps: tuple
    mask_col: int


@dataclass
class FusedScan:
    """One fused scan program covering a subset of the input members."""

    steps: list
    mask_cols: list  # fused mask column per member, parallel to members
    members: list  # indices into the fuse() input list
    saved_steps: int  # steps elided by the cross-query CSE
    peak_cols: int  # compute columns occupied above compute_base


def _singleton(idx: int, p: ScanProgram) -> FusedScan:
    """A one-member chunk running the member's original prefix verbatim
    (the fallback when a member refuses fusion)."""
    return FusedScan(list(p.steps), [p.mask_col], [idx], 0, 0)


class FuseErr(Exception):
    """Why a member could not join the current fused chunk."""


class Unfusable(FuseErr):
    """The member violates a fusion safety check; it can never fuse."""


class ChunkFull(FuseErr):
    """The chunk's column budget is exhausted; retry in a fresh chunk."""


class Fuser:
    """Incremental fusion state for one chunk (fusion.rs::Fuser)."""

    def __init__(self, compute_base: int, col_limit: int):
        self.compute_base = compute_base
        self.col_limit = col_limit
        self.next_col = compute_base
        self.table: dict = {}  # StepKey tuple -> home column
        self.steps: list = []
        self.mask_cols: list = []
        self.members: list = []
        self.saved = 0

    def clone(self) -> "Fuser":
        c = Fuser(self.compute_base, self.col_limit)
        c.next_col = self.next_col
        c.table = dict(self.table)
        c.steps = list(self.steps)
        c.mask_cols = list(self.mask_cols)
        c.members = list(self.members)
        c.saved = self.saved
        return c

    def rename_read(self, remap: dict, r: m.ColRange, read_len: int) -> m.ColRange:
        """Data ranges pass through; compute ranges must map contiguously
        onto already-written fused columns (safety checks 3 and 4). Only
        the first read_len columns are actually read by the engine;
        trailing unread columns of a wider field keep the mapped base
        without a contiguity obligation."""
        s = r.start
        if s < self.compute_base:
            if s + read_len > self.compute_base:
                raise Unfusable
            return r
        mapped0 = remap.get(s)
        if mapped0 is None:
            raise Unfusable
        for k in range(1, read_len):
            if remap.get(s + k) != mapped0 + k:
                raise Unfusable
        return m.ColRange(mapped0, r.len)

    def add(self, idx: int, p: ScanProgram) -> None:
        """Try to add member idx. On error the chunk state may be
        partially mutated — the caller attempts on a clone (see fuse)."""
        remap: dict = {}
        for step in p.steps:
            instr = step.instr
            if instr.op in m.SIDE_EFFECT:
                raise Unfusable  # safety check 1
            la, lb = m.read_lens(instr)
            if la > 0:
                instr = replace(instr, src_a=self.rename_read(remap, instr.src_a, la))
            if lb > 0:
                assert instr.src_b is not None, "read_lens reported a second operand"
                instr = replace(instr, src_b=self.rename_read(remap, instr.src_b, lb))
            _, write = m.accesses(instr)
            assert write is not None, "non-side-effect steps write"
            if write.start < self.compute_base:
                raise Unfusable  # safety check 2
            srcs = tuple(
                [instr.src_a.start + k for k in range(la)]
                + [instr.src_b.start + k for k in range(lb)]
            )
            key = (
                OP_TAG[instr.op],
                instr.imm if instr.op in m.IMM_OPS else 0,
                write.len,
                la,
                lb,
                srcs,
            )
            ww, w0 = write.len, write.start
            home = self.table.get(key)
            if home is not None:
                # cross-query CSE hit: rename instead of emitting
                for k in range(ww):
                    remap[w0 + k] = home + k
                self.saved += 1
            else:
                at = self.next_col
                if at + ww > self.col_limit:
                    raise ChunkFull
                self.next_col = at + ww
                for k in range(ww):
                    remap[w0 + k] = at + k
                self.table[key] = at
                instr = replace(instr, dst=m.ColRange(at, ww))
                if la == 0:
                    # Set/Reset read nothing: keep the cosmetic src_a
                    # field mirroring the destination (cse does the same)
                    instr = replace(instr, src_a=instr.dst)
                self.steps.append(m.Step(instr, step.category))
        mask = remap.get(p.mask_col)
        if mask is None:
            raise Unfusable
        self.mask_cols.append(mask)
        self.members.append(idx)

    def finish(self) -> FusedScan:
        return FusedScan(
            self.steps,
            self.mask_cols,
            self.members,
            self.saved,
            self.next_col - self.compute_base,
        )


def fuse(programs: list, compute_base: int, col_limit: int) -> list:
    """Mirror of fusion::fuse — greedy packing in input order; a member
    that refuses fusion comes back as a singleton chunk, a member that
    would overflow the column budget closes the chunk and retries fresh,
    so every input index appears in exactly one returned chunk."""
    out: list = []
    cur = Fuser(compute_base, col_limit)
    for idx, p in enumerate(programs):
        trial = cur.clone()
        try:
            trial.add(idx, p)
            cur = trial
        except ChunkFull:
            if cur.members:
                out.append(cur.finish())
                cur = Fuser(compute_base, col_limit)
                retry = cur.clone()
                try:
                    retry.add(idx, p)
                    cur = retry
                except FuseErr:
                    out.append(_singleton(idx, p))
            else:
                out.append(_singleton(idx, p))
        except Unfusable:
            out.append(_singleton(idx, p))
    if cur.members:
        out.append(cur.finish())
    return out


_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def digest(fused: list) -> int:
    """Mirror of fusion::digest — FNV-1a 64 over the fusion result, each
    value folded as 8 little-endian bytes, chunks delimited by a marker
    byte. The cross-language golden pin shared with the Rust unit test
    fusion::tests::golden_digest_matches_python_mirror."""
    h = _FNV_OFFSET

    def byte(h: int, b: int) -> int:
        return ((h ^ b) * _FNV_PRIME) & _MASK64

    def word(h: int, v: int) -> int:
        for b in (v & _MASK64).to_bytes(8, "little"):
            h = ((h ^ b) * _FNV_PRIME) & _MASK64
        return h

    for fs in fused:
        h = byte(h, 0xF5)
        for step in fs.steps:
            i = step.instr
            h = word(h, OP_TAG[i.op])
            h = word(h, i.imm if i.op in m.IMM_OPS else 0)
            h = word(h, i.src_a.start)
            h = word(h, i.src_a.len)
            if i.src_b is not None:
                h = word(h, 1)
                h = word(h, i.src_b.start)
                h = word(h, i.src_b.len)
            else:
                h = word(h, 0)
            h = word(h, i.dst.start)
            h = word(h, i.dst.len)
        for mc in fs.mask_cols:
            h = word(h, mc)
        for mm in fs.members:
            h = word(h, mm)
        h = word(h, fs.saved_steps)
    return h
