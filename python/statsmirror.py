"""Mirror of the zone-map statistics + shard-pruning decision procedure.

The container that grows this repo has no Rust toolchain, so the
algorithmic core of ``rust/src/db/stats.rs`` and
``rust/src/query/opt/prune.rs`` is re-implemented here, runnable, and
pinned cross-language: both sides build identical statistics over the
shared ``golden_states`` fixture and must produce the same FNV-1a
digest (``GOLDEN_STATS_DIGEST``, asserted by
``stats::tests::golden_digest_pinned_cross_language`` on the Rust side
and ``test_statsmirror.py::test_golden_digest_pin`` here).

Two deliberate representation differences from Rust, neither visible in
the digest or the decisions:

* Rust computes zones by walking bit-planes MSB-first (the engine's
  ReduceMin/ReduceMax narrowing); this mirror scans the decoded live
  values directly.  Agreement of the two *algorithms* is exactly what
  the golden digest pins.
* Crossbars are modelled as ``{row: {slot_index: value}}`` of live rows
  only — dead rows hold no data, matching the store invariant that the
  valid-AND relies on.

The pruning decision table (``pred_disjoint``) is mirrored line-by-line
and fuzzed against a scan-everything oracle: ``skip=True`` must *prove*
the filter selects no live row on that crossbar (``False`` may be
conservative, ``True`` may never lie).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from dmlmirror import FNV_OFFSET, MASK64, _fnv1a_fold  # noqa: E402

#: Cross-language pin: ``RelStats::build(&golden_states(.., 3, 0xDB))``
#: digested identically by both implementations.
GOLDEN_STATS_DIGEST = 0x06BE552B21FA62A7

#: Widest dict column (bits) that gets a distinct-id presence bitmap
#: (``stats::DICT_BITMAP_MAX_BITS``).
DICT_BITMAP_MAX_BITS = 6

#: SUPPLIER attribute slots in layout order: (name, bits, has_dict_bitmap).
#: Mirrors ``schema::SUPPLIER_ATTRS`` + ``wants_dict_bitmap`` — only the
#: 6-bit dictionary column ``s_phone_cc`` qualifies for a bitmap.
SUPPLIER_SLOTS = [
    ("s_suppkey", 24, False),
    ("s_nationkey", 5, False),
    ("s_phone_cc", 6, True),
    ("s_phone_rest", 36, False),
    ("s_acctbal", 21, False),
]

U64_MAX = MASK64


class Rng:
    """xoshiro256** seeded via splitmix64 — mirrors ``util::rng::Rng``."""

    def __init__(self, seed: int):
        s = []
        sm = seed & MASK64
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self) -> int:
        def rotl(x: int, k: int) -> int:
            return ((x << k) | (x >> (64 - k))) & MASK64

        s = self.s
        result = (rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result


class ColZone:
    """Zone map of one slot on one crossbar (``stats::ColZone``)."""

    def __init__(self, min_v: int, max_v: int, dict_bm):
        self.min = min_v
        self.max = max_v
        self.dict = dict_bm  # int bitmap or None

    @staticmethod
    def empty(dict_bitmap: bool) -> "ColZone":
        return ColZone(U64_MAX, 0, 0 if dict_bitmap else None)

    def __eq__(self, other):
        return (self.min, self.max, self.dict) == (other.min, other.max, other.dict)


class XbarStats:
    """Live-row count plus per-slot zones (``stats::XbarStats``)."""

    def __init__(self, live_rows: int, zones):
        self.live_rows = live_rows
        self.zones = zones

    def __eq__(self, other):
        return (self.live_rows, self.zones) == (other.live_rows, other.zones)


def xbar_stats(rows: dict, slots) -> XbarStats:
    """Stats of one crossbar: ``rows`` maps live row -> per-slot values."""
    zones = []
    for i, (_, _, dict_bm) in enumerate(slots):
        if not rows:
            zones.append(ColZone.empty(dict_bm))
            continue
        vals = [r[i] for r in rows.values()]
        bm = None
        if dict_bm:
            bm = 0
            for v in vals:
                bm |= 1 << v
        zones.append(ColZone(min(vals), max(vals), bm))
    return XbarStats(len(rows), zones)


class RelStats:
    """Per-crossbar stats of one relation version (``stats::RelStats``)."""

    def __init__(self, xbars):
        self.xbars = xbars

    @staticmethod
    def build(states, slots) -> "RelStats":
        return RelStats([xbar_stats(rows, slots) for rows in states])

    @staticmethod
    def update(prev: "RelStats", old_states, new_states, slots) -> "RelStats":
        """Incremental rebuild: unchanged crossbars keep prior stats."""
        xbars = []
        for x, rows in enumerate(new_states):
            if x < len(old_states) and old_states[x] == rows:
                xbars.append(prev.xbars[x])
            else:
                xbars.append(xbar_stats(rows, slots))
        return RelStats(xbars)

    def digest(self) -> int:
        """LE-u64 serialization folded through FNV-1a — byte-identical
        to ``RelStats::digest`` on the Rust side."""
        state = FNV_OFFSET
        state = _fnv1a_fold(state, len(self.xbars))
        for x in self.xbars:
            state = _fnv1a_fold(state, x.live_rows)
            for z in x.zones:
                state = _fnv1a_fold(state, z.min)
                state = _fnv1a_fold(state, z.max)
                state = _fnv1a_fold(state, 1 if z.dict is not None else 0)
                state = _fnv1a_fold(state, z.dict if z.dict is not None else 0)
        return state


def golden_states(slots, n: int, seed: int):
    """The shared golden fixture: mirrors ``stats::tests::golden_states``.

    Per crossbar, rows 0..200: liveness draw, then one value draw per
    slot *regardless of liveness* (the Rust fixture always consumes the
    stream; it only writes the value when the row is live). Rows
    200..1023 stay dead.
    """
    rng = Rng(seed)
    states = []
    for _ in range(n):
        rows = {}
        for row in range(200):
            live = rng.next_u64() % 4 != 0
            vals = [rng.next_u64() & ((1 << bits) - 1) for _, bits, _ in slots]
            if live:
                rows[row] = dict(enumerate(vals))
        states.append(rows)
    return states


def golden_stats_digest() -> int:
    """Digest of the pinned golden fixture (3 crossbars, seed 0xDB)."""
    return RelStats.build(golden_states(SUPPLIER_SLOTS, 3, 0xDB), SUPPLIER_SLOTS).digest()


# --- pruning decision procedure (mirror of query::opt::prune) ---------------
#
# Predicates are tuples:
#   ("true",)
#   ("cmp", attr, op, value)          op in {"eq","ne","lt","le","gt","ge"}
#   ("inset", attr, [values...])
#   ("between", attr, lo, hi)
#   ("and", [preds...]) / ("or", [preds...])
#   ("not", pred) / ("cmpcols", attr_a, op, attr_b)


def eq_disjoint(z: ColZone, v: int) -> bool:
    if v < z.min or v > z.max:
        return True
    return z.dict is not None and v < 64 and (z.dict >> v) & 1 == 0


def cmp_disjoint(z: ColZone, op: str, v: int) -> bool:
    if op == "eq":
        return eq_disjoint(z, v)
    if op == "ne":
        return z.min == z.max and z.min == v
    if op == "lt":
        return z.min >= v
    if op == "le":
        return z.min > v
    if op == "gt":
        return z.max <= v
    if op == "ge":
        return z.max < v
    raise ValueError(op)


def _slot_index(slots, attr: str):
    for i, (name, _, _) in enumerate(slots):
        if name == attr:
            return i
    return None


def pred_disjoint(p, slots, x: XbarStats) -> bool:
    """Whether ``p`` provably selects no live row of crossbar ``x`` —
    mirrors ``prune::pred_disjoint`` case for case."""
    if x.live_rows == 0:
        return True
    kind = p[0]
    if kind == "true":
        return False
    if kind == "cmp":
        i = _slot_index(slots, p[1])
        return i is not None and cmp_disjoint(x.zones[i], p[2], p[3])
    if kind == "inset":
        i = _slot_index(slots, p[1])
        return i is not None and all(eq_disjoint(x.zones[i], v) for v in p[2])
    if kind == "between":
        _, attr, lo, hi = p
        if lo > hi:
            return True
        i = _slot_index(slots, attr)
        return i is not None and (hi < x.zones[i].min or lo > x.zones[i].max)
    if kind == "and":
        return any(pred_disjoint(q, slots, x) for q in p[1])
    if kind == "or":
        return all(pred_disjoint(q, slots, x) for q in p[1])
    if kind in ("not", "cmpcols"):
        return False
    raise ValueError(kind)


def skip_bitmap(p, slots, stats: RelStats):
    """Per-crossbar skip bitmap — mirrors ``prune::skip_bitmap``."""
    return [pred_disjoint(p, slots, x) for x in stats.xbars]


def eval_pred(p, slots, vals) -> bool:
    """Scan-everything oracle: evaluate ``p`` on one live row's values."""
    kind = p[0]
    if kind == "true":
        return True
    if kind == "cmp":
        i = _slot_index(slots, p[1])
        if i is None:
            return False
        v, imm = vals[i], p[3]
        return {
            "eq": v == imm,
            "ne": v != imm,
            "lt": v < imm,
            "le": v <= imm,
            "gt": v > imm,
            "ge": v >= imm,
        }[p[2]]
    if kind == "inset":
        i = _slot_index(slots, p[1])
        return i is not None and vals[i] in p[2]
    if kind == "between":
        i = _slot_index(slots, p[1])
        return i is not None and p[2] <= vals[i] <= p[3]
    if kind == "and":
        return all(eval_pred(q, slots, vals) for q in p[1])
    if kind == "or":
        return any(eval_pred(q, slots, vals) for q in p[1])
    if kind == "not":
        return not eval_pred(p[1], slots, vals)
    if kind == "cmpcols":
        a, b = _slot_index(slots, p[1]), _slot_index(slots, p[3])
        if a is None or b is None:
            return False
        va, vb = vals[a], vals[b]
        return {
            "eq": va == vb,
            "ne": va != vb,
            "lt": va < vb,
            "le": va <= vb,
            "gt": va > vb,
            "ge": va >= vb,
        }[p[2]]
    raise ValueError(kind)


def oracle_selects_any(p, slots, rows: dict) -> bool:
    """Whether the filter selects at least one live row of a crossbar."""
    return any(eval_pred(p, slots, vals) for vals in rows.values())


if __name__ == "__main__":
    print(hex(golden_stats_digest()))
