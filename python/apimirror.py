"""Python mirror of the plan-cache key normalization in
``rust/src/api/cache.rs`` (``plan_key`` / ``plan_fingerprint``).

The authoring environment has no Rust toolchain, so — like
``optmirror.py`` for the optimizer passes — the cache-key algorithm is
ported line by line to Python and fuzz-validated here before the Rust
side is trusted. Two artifacts keep the implementations from drifting:

* the byte format is identical (version byte, length-prefixed UTF-8
  strings, little-endian integers, one tag byte per enum variant, FNV-1a
  64-bit), and
* the *default-schema fingerprint* is pinned to the same literal constant
  in both languages (``DEFAULT_FINGERPRINT`` here, asserted against
  ``plan_fingerprint(&SystemConfig::default())`` in the Rust unit tests)
  — any one-sided format change breaks one of the two suites.

Queries are plain tuples/dicts here (Python has no ``ast::Query``):

``query``:  ``{"kind": "full"|"filter_only", "name": str, "rels": [rel]}``
``rel``:    ``{"rel": str, "filter": pred, "group_by": [str],
              "aggregates": [{"kind": str, "expr": vexpr, "label": str}]}``
``pred``:   ``("cmp_imm", attr, op, value) | ("in_set", attr, values)
            | ("between", attr, lo, hi) | ("cmp_cols", a, op, b)
            | ("and", [pred]) | ("or", [pred]) | ("not", pred) | ("true",)``
``vexpr``:  ``("attr", a) | ("one",) | ("mul_attrs", a, b)
            | ("mul_complement", attr, scale, other)
            | ("mul_sum", attr, scale, other)
            | ("mul_complement_sum", attr, s1, o1, s2, o2)``
"""

from __future__ import annotations

FORMAT_VERSION = 1

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x00000100000001B3
MASK64 = (1 << 64) - 1

CMP_TAGS = {"eq": 0, "ne": 1, "lt": 2, "le": 3, "gt": 4, "ge": 5}
AGG_TAGS = {"sum": 0, "count": 1, "min": 2, "max": 3, "avg": 4}
ENC_TAGS = {"uint": 0, "dict": 1, "date": 2, "money": 3}
OPT_TAGS = {"O0": 0, "O1": 1, "O2": 2}
KIND_TAGS = {"full": 0, "filter_only": 1}


class Fnv:
    """Incremental FNV-1a 64-bit hasher (mirrors ``cache::Fnv``)."""

    def __init__(self) -> None:
        self.state = FNV_OFFSET

    def bytes(self, bs: bytes) -> None:
        s = self.state
        for b in bs:
            s = ((s ^ b) * FNV_PRIME) & MASK64
        self.state = s

    def u8(self, v: int) -> None:
        self.bytes(bytes([v & 0xFF]))

    def u32(self, v: int) -> None:
        self.bytes((v & 0xFFFFFFFF).to_bytes(4, "little"))

    def u64(self, v: int) -> None:
        self.bytes((v & MASK64).to_bytes(8, "little"))

    def i64(self, v: int) -> None:
        self.bytes((v & MASK64).to_bytes(8, "little"))  # two's complement

    def str(self, s: str) -> None:
        raw = s.encode("utf-8")
        self.u32(len(raw))
        self.bytes(raw)


def _hash_pred(h: Fnv, p: tuple) -> None:
    tag = p[0]
    if tag == "cmp_imm":
        h.u8(0)
        h.str(p[1])
        h.u8(CMP_TAGS[p[2]])
        h.u64(p[3])
    elif tag == "in_set":
        h.u8(1)
        h.str(p[1])
        h.u32(len(p[2]))
        for v in p[2]:
            h.u64(v)
    elif tag == "between":
        h.u8(2)
        h.str(p[1])
        h.u64(p[2])
        h.u64(p[3])
    elif tag == "cmp_cols":
        h.u8(3)
        h.str(p[1])
        h.u8(CMP_TAGS[p[2]])
        h.str(p[3])
    elif tag == "and":
        h.u8(4)
        h.u32(len(p[1]))
        for q in p[1]:
            _hash_pred(h, q)
    elif tag == "or":
        h.u8(5)
        h.u32(len(p[1]))
        for q in p[1]:
            _hash_pred(h, q)
    elif tag == "not":
        h.u8(6)
        _hash_pred(h, p[1])
    elif tag == "true":
        h.u8(7)
    else:  # pragma: no cover - malformed fixture
        raise ValueError(f"unknown pred tag {tag!r}")


def _hash_vexpr(h: Fnv, e: tuple) -> None:
    tag = e[0]
    if tag == "attr":
        h.u8(0)
        h.str(e[1])
    elif tag == "one":
        h.u8(1)
    elif tag == "mul_attrs":
        h.u8(2)
        h.str(e[1])
        h.str(e[2])
    elif tag == "mul_complement":
        h.u8(3)
        h.str(e[1])
        h.u64(e[2])
        h.str(e[3])
    elif tag == "mul_sum":
        h.u8(4)
        h.str(e[1])
        h.u64(e[2])
        h.str(e[3])
    elif tag == "mul_complement_sum":
        h.u8(5)
        h.str(e[1])
        h.u64(e[2])
        h.str(e[3])
        h.u64(e[4])
        h.str(e[5])
    else:  # pragma: no cover - malformed fixture
        raise ValueError(f"unknown vexpr tag {tag!r}")


def plan_fingerprint(schema: list, xbar_cols: int, xbar_rows: int) -> int:
    """Mirror of ``cache::plan_fingerprint``: geometry + schema hash.

    ``schema`` is ``[(rel_name, [(attr, bits, enc, money_offset)])]`` in
    PIM layout order.
    """
    h = Fnv()
    h.u8(FORMAT_VERSION)
    h.u32(xbar_cols)
    h.u32(xbar_rows)
    for rel_name, attrs in schema:
        h.str(rel_name)
        h.u32(len(attrs))
        for name, bits, enc, offset in attrs:
            h.str(name)
            h.u32(bits)
            h.u8(ENC_TAGS[enc])
            h.i64(offset)
    return h.state


def plan_key(query: dict, opt_level: str, fingerprint: int) -> int:
    """Mirror of ``cache::plan_key``: the canonical AST hash.

    Insensitive to ``query["name"]`` and aggregate labels (aliases);
    sensitive to structure, literals, ``opt_level`` and ``fingerprint``.
    """
    h = Fnv()
    h.u8(FORMAT_VERSION)
    h.u8(KIND_TAGS[query["kind"]])
    rels = query["rels"]
    h.u32(len(rels))
    for rq in rels:
        h.str(rq["rel"])
        _hash_pred(h, rq["filter"])
        h.u32(len(rq["group_by"]))
        for g in rq["group_by"]:
            h.str(g)
        h.u32(len(rq["aggregates"]))
        for a in rq["aggregates"]:
            # label omitted: aliases are rebound on the cached plan
            h.u8(AGG_TAGS[a["kind"]])
            _hash_vexpr(h, a["expr"])
    h.u8(OPT_TAGS[opt_level])
    h.u64(fingerprint)
    return h.state


# ---------------------------------------------------------------------------
# The default PIM schema (rust/src/db/schema.rs) and its pinned fingerprint.
# ---------------------------------------------------------------------------

#: Mirror of the ``*_ATTRS`` tables in ``schema.rs``, in
#: ``PIM_RELATIONS`` order. Money offsets mirror ``Attr::money``.
DEFAULT_SCHEMA = [
    ("PART", [
        ("p_partkey", 28, "uint", 0),
        ("p_mfgr", 3, "dict", 0),
        ("p_brand", 5, "dict", 0),
        ("p_type", 8, "dict", 0),
        ("p_size", 6, "uint", 0),
        ("p_container", 6, "dict", 0),
        ("p_retailprice", 21, "money", 0),
    ]),
    ("SUPPLIER", [
        ("s_suppkey", 24, "uint", 0),
        ("s_nationkey", 5, "uint", 0),
        ("s_phone_cc", 6, "dict", 0),
        ("s_phone_rest", 36, "uint", 0),
        ("s_acctbal", 21, "money", 100_000),
    ]),
    ("PARTSUPP", [
        ("ps_partkey", 28, "uint", 0),
        ("ps_suppkey", 24, "uint", 0),
        ("ps_availqty", 14, "uint", 0),
        ("ps_supplycost", 17, "money", 0),
    ]),
    ("CUSTOMER", [
        ("c_custkey", 28, "uint", 0),
        ("c_nationkey", 5, "uint", 0),
        ("c_phone_cc", 6, "dict", 0),
        ("c_phone_rest", 36, "uint", 0),
        ("c_acctbal", 21, "money", 100_000),
        ("c_mktsegment", 3, "dict", 0),
    ]),
    ("ORDERS", [
        ("o_orderkey", 33, "uint", 0),
        ("o_custkey", 28, "uint", 0),
        ("o_orderstatus", 2, "dict", 0),
        ("o_totalprice", 26, "money", 0),
        ("o_orderdate", 12, "date", 0),
        ("o_orderpriority", 3, "dict", 0),
        ("o_shippriority", 1, "uint", 0),
    ]),
    ("LINEITEM", [
        ("l_orderkey", 33, "uint", 0),
        ("l_partkey", 28, "uint", 0),
        ("l_suppkey", 24, "uint", 0),
        ("l_linenumber", 3, "uint", 0),
        ("l_quantity", 6, "uint", 0),
        ("l_extendedprice", 24, "money", 0),
        ("l_discount", 4, "uint", 0),
        ("l_tax", 4, "uint", 0),
        ("l_returnflag", 2, "dict", 0),
        ("l_linestatus", 1, "dict", 0),
        ("l_shipdate", 12, "date", 0),
        ("l_commitdate", 12, "date", 0),
        ("l_receiptdate", 12, "date", 0),
        ("l_shipinstruct", 2, "dict", 0),
        ("l_shipmode", 3, "dict", 0),
    ]),
]

#: Default crossbar geometry (SystemConfig::default()).
DEFAULT_XBAR_COLS = 512
DEFAULT_XBAR_ROWS = 1024


def default_fingerprint() -> int:
    """The fingerprint of the default schema + geometry."""
    return plan_fingerprint(DEFAULT_SCHEMA, DEFAULT_XBAR_COLS, DEFAULT_XBAR_ROWS)


#: Pinned cross-language golden value: must equal
#: ``cache::plan_fingerprint(&SystemConfig::default())`` (asserted on the
#: Rust side in ``rust/src/api/cache.rs`` and here in the pytest suite).
#: Regenerate with ``python -c "import apimirror; print(hex(apimirror.default_fingerprint()))"``
#: whenever the schema or the byte format changes — and bump
#: ``FORMAT_VERSION`` in both languages.
DEFAULT_FINGERPRINT = 0xDD8BB4AF22C11FDB


def canonical_structure(query: dict) -> str:
    """A readable canonical form for duplicate detection in the fuzz
    suite: everything the key hashes, nothing it omits (labels, names).
    Two queries are duplicates (same plan) iff their structures match.
    """
    rels = []
    for rq in query["rels"]:
        aggs = [(a["kind"], a["expr"]) for a in rq["aggregates"]]
        rels.append((rq["rel"], rq["filter"], tuple(rq["group_by"]), tuple(aggs)))
    return repr((query["kind"], tuple(rels)))
