"""Python mirror of the endurance-aware free-row allocator in
``rust/src/db/freerows.rs`` (``FreeRowMap``).

The authoring environment has no Rust toolchain, so — like
``optmirror.py`` for the optimizer passes and ``apimirror.py`` for the
plan-cache keys — the allocator is written here first, fuzz-validated
against a naive from-scratch oracle, and then ported line by line to
Rust. Two artifacts keep the implementations from drifting:

* the *allocation policy* is fully deterministic: an INSERT takes the
  free row minimizing ``(wear, row_index)`` — wear-leveling over the
  per-row cell-write counters that queries and DML statements charge;
* a scripted alloc/free/charge scenario is folded into an FNV-1a digest
  (``golden_alloc_digest``) and pinned to the same literal constant in
  both languages (``GOLDEN_ALLOC_DIGEST`` here, asserted in the Rust
  unit tests of ``freerows.rs``) — any one-sided policy change breaks
  exactly one of the two suites.

The mirror replicates the Rust bookkeeping structure (an ordered set of
``(wear, row)`` entries for the free rows, kept in sync with the wear
counters) rather than recomputing the minimum from scratch; the fuzz
suite in ``tests/test_dmlmirror.py`` compares it against the from-scratch
oracle so stale-entry bugs in the incremental structure cannot hide.
"""

from __future__ import annotations

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x00000100000001B3
MASK64 = (1 << 64) - 1

#: Cross-language pin: ``golden_alloc_digest()`` in both languages.
GOLDEN_ALLOC_DIGEST = 0x9468F2E2165F77A6


class FreeRowMap:
    """Per-relation row liveness + wear map (mirror of the Rust struct).

    ``capacity`` rows, the first ``initial_live`` live (the loaded
    records), the rest free.  ``rows_per_xbar`` is the crossbar row count:
    column-wise instruction wear repeats per crossbar, so a per-crossbar
    profile of that length charges every row of the relation.
    """

    def __init__(self, capacity: int, initial_live: int, rows_per_xbar: int):
        assert 0 <= initial_live <= capacity
        assert rows_per_xbar >= 1
        self.rows_per_xbar = rows_per_xbar
        self.live = [i < initial_live for i in range(capacity)]
        self.wear = [0] * capacity
        # mirror of the Rust BTreeSet<(wear, row)>: one entry per free row
        self.free_entries = {(0, i) for i in range(initial_live, capacity)}

    # -- queries -----------------------------------------------------------

    def capacity(self) -> int:
        return len(self.live)

    def live_count(self) -> int:
        return sum(self.live)

    def is_live(self, row: int) -> bool:
        return self.live[row]

    def row_wear(self, row: int) -> int:
        return self.wear[row]

    # -- mutations ---------------------------------------------------------

    def alloc(self):
        """Take the least-worn free row (ties: lowest index); None if full."""
        if not self.free_entries:
            return None
        entry = min(self.free_entries)
        self.free_entries.remove(entry)
        row = entry[1]
        self.live[row] = True
        return row

    def release(self, row: int) -> None:
        """Mark a live row free again (DELETE)."""
        assert self.live[row], f"double free of row {row}"
        self.live[row] = False
        self.free_entries.add((self.wear[row], row))

    def grow(self, rows: int) -> None:
        """Append ``rows`` fresh free rows (a newly materialized crossbar)."""
        base = len(self.live)
        self.live.extend([False] * rows)
        self.wear.extend([0] * rows)
        for i in range(rows):
            self.free_entries.add((0, base + i))

    def charge_row(self, row: int, writes: int) -> None:
        """Add ``writes`` cell writes to one row (an INSERT row write)."""
        if not self.live[row]:
            self.free_entries.remove((self.wear[row], row))
            self.free_entries.add((self.wear[row] + writes, row))
        self.wear[row] = (self.wear[row] + writes) & MASK64

    def charge_profile(self, totals) -> None:
        """Charge a per-crossbar write profile to every row.

        ``totals[r]`` is the cell writes row ``r`` of *each* crossbar
        received (all crossbars of a relation execute the same
        instruction stream in lockstep).
        """
        changed = False
        for i in range(len(self.wear)):
            add = totals[i % self.rows_per_xbar]
            if add:
                self.wear[i] = (self.wear[i] + add) & MASK64
                changed = True
        if changed:
            # wear of free rows moved: rebuild the ordered entries
            self.free_entries = {
                (self.wear[i], i) for i in range(len(self.live)) if not self.live[i]
            }


def update_runs(value: int, bits: int):
    """Mirror of the UPDATE lowering in ``compile_dml`` (compiler.rs):
    partition the attribute's bit range into maximal runs of equal value
    bits; 1-runs become broadcast ``Or(attr, mask)``, 0-runs broadcast
    ``And(attr, ~mask)``. Returns ``[(lo, length, bit)]``."""
    runs = []
    b = 0
    while b < bits:
        bit = (value >> b) & 1
        e = b + 1
        while e < bits and ((value >> e) & 1) == bit:
            e += 1
        runs.append((b, e - b, bit))
        b = e
    return runs


def apply_update_runs(runs, row_value: int, selected: bool) -> int:
    """Bit-plane semantics of the emitted Or/And stream on one row."""
    out = row_value
    for lo, length, bit in runs:
        m = ((1 << length) - 1) << lo
        if bit == 1:
            if selected:
                out |= m  # Or with the mask column (1 on selected rows)
        else:
            if selected:
                out &= ~m  # And with NOT mask (0 on selected rows)
    return out


def oracle_alloc_choice(live, wear):
    """From-scratch oracle for the allocation policy: the free row
    minimizing ``(wear, row)``, or None."""
    best = None
    for row in range(len(live)):
        if live[row]:
            continue
        key = (wear[row], row)
        if best is None or key < best:
            best = key
    return None if best is None else best[1]


# ---------------------------------------------------------------------------
# golden pin
# ---------------------------------------------------------------------------


def _fnv1a_fold(state: int, value: int) -> int:
    """Fold one little-endian u64 into an FNV-1a state."""
    for byte in value.to_bytes(8, "little"):
        state = ((state ^ byte) * FNV_PRIME) & MASK64
    return state


def golden_alloc_digest() -> int:
    """Scripted alloc/free/charge scenario digested to 64 bits.

    A deterministic LCG drives 200 operations over a 64-row map (4
    crossbars of 16 rows, 40 initially live); every operation and every
    allocator answer is folded into an FNV-1a digest, so the digest pins
    the complete allocation *order* — the wear-leveling policy — not just
    the final state.
    """
    fm = FreeRowMap(capacity=64, initial_live=40, rows_per_xbar=16)
    state = FNV_OFFSET
    x = 42
    for _ in range(200):
        x = (x * 6364136223846793005 + 1442695040888963407) & MASK64
        op = x % 4
        arg = (x >> 8) % 64
        state = _fnv1a_fold(state, op)
        if op == 0:  # alloc
            row = fm.alloc()
            state = _fnv1a_fold(state, 0xFFFF if row is None else row)
        elif op == 1:  # free the first live row at/after arg (wrapping)
            row = None
            for k in range(fm.capacity()):
                cand = (arg + k) % fm.capacity()
                if fm.is_live(cand):
                    row = cand
                    break
            if row is None:
                state = _fnv1a_fold(state, 0xFFFE)
            else:
                fm.release(row)
                state = _fnv1a_fold(state, row)
        elif op == 2:  # point charge (an INSERT-style row write)
            writes = (x >> 16) % 7 + 1
            fm.charge_row(arg, writes)
            state = _fnv1a_fold(state, arg * 1000 + writes)
        else:  # per-crossbar profile charge (a query/DML instruction stream)
            totals = [((x >> 16) + 7 * r + 3) % 5 for r in range(16)]
            fm.charge_profile(totals)
            state = _fnv1a_fold(state, sum(totals))
    # final-state summary: live count and total wear
    state = _fnv1a_fold(state, fm.live_count())
    state = _fnv1a_fold(state, sum(fm.wear) & MASK64)
    return state


if __name__ == "__main__":
    print(hex(golden_alloc_digest()))
