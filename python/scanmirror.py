"""Python mirror of the shared-scan analysis (rust/src/query/opt/sharedscan.rs).

Splits an optimized program into a filter prefix (through the last write
of the mask column) and a suffix, and derives a renaming-normalized byte
key such that byte equality implies the prefixes compute the identical
mask function. The Rust crate's authoring environment has no toolchain,
so the analysis is validated here against the compiler + engine mirrors
in optmirror.py, fuzzed over random queries
(python/tests/test_scanmirror.py). Keep in sync with the Rust source;
the port favours structural similarity over Pythonic style on purpose.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Optional

import optmirror as m

# Canonical ids of compute-area columns start here — far above any
# physical column id, so the two id spaces cannot collide in the key.
CANON_BASE = 1 << 20

# Opcode byte tags, mirroring the Rust enum's discriminant order
# (rust/src/pim/isa.rs::Opcode).
OP_TAG = {
    m.EQ_IMM: 0, m.NE_IMM: 1, m.LT_IMM: 2, m.GT_IMM: 3, m.ADD_IMM: 4,
    m.EQ: 5, m.LT: 6, m.SET: 7, m.RESET: 8, m.NOT: 9, m.AND: 10,
    m.OR: 11, m.ADD: 12, m.MUL: 13, m.RSUM: 14, m.RMIN: 15, m.RMAX: 16,
    m.COLT: 17,
}


@dataclass(frozen=True)
class ScanInfo:
    """Steps [0, prefix_len) are the shared filter prefix; `key` is its
    canonical serialization (equal bytes => identical mask function)."""

    prefix_len: int
    key: bytes


class Canon:
    """Canonical-id assigner: data/VALID columns (below compute_base)
    keep their absolute id; compute-area columns get sequential ids from
    CANON_BASE in order of first appearance."""

    def __init__(self, compute_base: int):
        self.compute_base = compute_base
        self.map: dict[int, int] = {}
        self.next = CANON_BASE

    def id(self, col: int) -> int:
        if col < self.compute_base:
            return col
        got = self.map.get(col)
        if got is None:
            got = self.next
            self.map[col] = got
            self.next += 1
        return got

    def range(self, r: m.ColRange) -> Optional[tuple[int, int]]:
        first = self.id(r.start)
        for k in range(1, r.len):
            if self.id(r.start + k) != first + k:
                return None
        return first, r.len


def _split_point(c) -> Optional[int]:
    last = None
    for i, s in enumerate(c.steps):
        _, write = m.accesses(s.instr)
        if write is not None and write.start <= c.mask_col < write.end:
            last = i
    return None if last is None else last + 1


def _scan_key(c, prefix_len: int) -> Optional[bytes]:
    canon = Canon(c.compute_base)
    buf = bytearray()
    for s in c.steps[:prefix_len]:
        i = s.instr
        buf.append(OP_TAG[i.op])
        if i.op in m.IMM_OPS:
            buf += struct.pack("<Q", i.imm & ((1 << 64) - 1))

        def put(r) -> bool:
            cr = canon.range(r)
            if cr is None:
                return False
            buf.extend(struct.pack("<IH", cr[0], cr[1]))
            return True

        if not put(i.src_a):
            return None
        if i.src_b is not None:
            buf.append(1)
            if not put(i.src_b):
                return None
        else:
            buf.append(0)
        if not put(i.dst):
            return None
    buf += struct.pack("<I", canon.id(c.mask_col))
    return bytes(buf)


def scan_info(c) -> Optional[ScanInfo]:
    """Mirror of sharedscan::scan_info — None when the program has no
    mask write or any safety condition fails (see the Rust docs):
    no side-effect step in the prefix, prefix writes only compute-area
    columns, suffix reads of prefix-written columns are the mask column
    or written-before-read, and every range normalizes contiguously."""
    prefix_len = _split_point(c)
    if prefix_len is None:
        return None
    for s in c.steps[:prefix_len]:
        if s.instr.op in m.SIDE_EFFECT:
            return None
    prefix_written: set[int] = set()
    for s in c.steps[:prefix_len]:
        _, write = m.accesses(s.instr)
        if write is not None:
            if write.start < c.compute_base:
                return None
            prefix_written.update(range(write.start, write.end))
    suffix_written: set[int] = set()
    for s in c.steps[prefix_len:]:
        reads, write = m.accesses(s.instr)
        for r in reads:
            for col in range(r.start, r.end):
                if col != c.mask_col and col in prefix_written \
                        and col not in suffix_written:
                    return None
        if write is not None:
            suffix_written.update(range(write.start, write.end))
    key = _scan_key(c, prefix_len)
    if key is None:
        return None
    return ScanInfo(prefix_len, key)
