"""Python mirror of the epoch bit-plane MVCC scheme in
``rust/src/util/bits.rs`` (``EpochMask``) and ``rust/src/db/freerows.rs``
(``EpochRowMap``).

Same discipline as ``scanmirror.py`` / ``dmlmirror.py``: the authoring
environment has no Rust toolchain, so the visibility rule is written
here first, fuzz-validated against a from-scratch two-version oracle
(``tests/test_epochmirror.py``), and ported line by line to Rust. The
scripted begin/mutate/commit/abort interleaving of
``golden_epoch_digest`` is pinned to the same constant in both languages
(``GOLDEN_EPOCH_DIGEST`` here, asserted in the Rust unit tests of
``freerows.rs``), so a one-sided change to the visibility rule breaks
exactly one of the two suites.

The rule being pinned: a DML batch edits a *shadow* copy of the per-row
liveness plane while the *active* plane — what every reader pinned to
the current epoch sees — stays frozen; commit atomically flips which
plane is active and bumps the epoch; abort discards the shadow and
charges no wear.
"""

from __future__ import annotations

from dmlmirror import FNV_OFFSET, MASK64, FreeRowMap, _fnv1a_fold

WORD_BITS = 64

#: Cross-language pin: ``golden_epoch_digest()`` in both languages.
GOLDEN_EPOCH_DIGEST = 0x6A415BD44B7C485C


class EpochMask:
    """Two-plane per-row visibility mask (mirror of the Rust struct).

    One plane is *active* (committed visibility), the other the *shadow*
    a batch edits; commit flips which index is active. Bits pack
    LSB-first into 64-bit words like every other engine mask.
    """

    def __init__(self, nbits: int):
        words = -(-nbits // WORD_BITS)  # div_ceil
        self.nbits = nbits
        self.active = 0
        self.in_batch_flag = False
        self.planes = [[0] * words, [0] * words]

    @classmethod
    def from_flags(cls, flags, nbits: int) -> "EpochMask":
        assert len(flags) <= nbits, "more flags than rows"
        m = cls(nbits)
        for i, f in enumerate(flags):
            if f:
                m.planes[0][i // WORD_BITS] |= 1 << (i % WORD_BITS)
        return m

    def capacity(self) -> int:
        return self.nbits

    def in_batch(self) -> bool:
        return self.in_batch_flag

    def get(self, row: int) -> bool:
        assert row < self.nbits
        return (self.planes[self.active][row // WORD_BITS] >> (row % WORD_BITS)) & 1 == 1

    def count_ones(self) -> int:
        full = self.nbits // WORD_BITS
        n = sum(bin(w).count("1") for w in self.planes[self.active][:full])
        if self.nbits % WORD_BITS != 0:
            tail = self.planes[self.active][full] & ((1 << (self.nbits % WORD_BITS)) - 1)
            n += bin(tail).count("1")
        return n

    def begin_batch(self) -> None:
        assert not self.in_batch_flag, "nested EpochMask batch"
        self.planes[1 - self.active] = list(self.planes[self.active])
        self.in_batch_flag = True

    def set_pending(self, row: int, v: bool) -> None:
        assert self.in_batch_flag and row < self.nbits
        w = row // WORD_BITS
        if v:
            self.planes[1 - self.active][w] |= 1 << (row % WORD_BITS)
        else:
            self.planes[1 - self.active][w] &= ~(1 << (row % WORD_BITS))

    def pending(self, row: int) -> bool:
        assert self.in_batch_flag and row < self.nbits
        return (self.planes[1 - self.active][row // WORD_BITS] >> (row % WORD_BITS)) & 1 == 1

    def commit_batch(self) -> None:
        assert self.in_batch_flag, "commit_batch outside a batch"
        self.active = 1 - self.active
        self.in_batch_flag = False

    def abort_batch(self) -> None:
        assert self.in_batch_flag, "abort_batch outside a batch"
        self.in_batch_flag = False

    def grow(self, rows: int) -> None:
        self.nbits += rows
        words = -(-self.nbits // WORD_BITS)
        for p in self.planes:
            p.extend([0] * (words - len(p)))


def clone_map(fm: FreeRowMap) -> FreeRowMap:
    """Mirror of ``FreeRowMap::clone`` (``#[derive(Clone)]`` in Rust)."""
    c = FreeRowMap(capacity=0, initial_live=0, rows_per_xbar=fm.rows_per_xbar)
    c.live = list(fm.live)
    c.wear = list(fm.wear)
    c.free_entries = set(fm.free_entries)
    return c


class EpochRowMap:
    """Epoch-versioned row map: committed ``FreeRowMap`` + ``EpochMask``.

    Take-out / put-back batch discipline (mirror of the Rust struct):
    ``begin_batch`` hands the writer an owned clone of the committed map
    to mutate lock-free; ``commit_batch`` takes it back, syncs the
    shadow plane, flips visibility atomically and bumps the epoch;
    ``abort_batch`` discards the shadow and charges no wear.
    """

    def __init__(self, committed: FreeRowMap):
        flags = [committed.is_live(i) for i in range(committed.capacity())]
        self.mask = EpochMask.from_flags(flags, committed.capacity())
        self.committed_map = committed
        self.epoch_ctr = 0
        self.in_batch_flag = False

    def epoch(self) -> int:
        return self.epoch_ctr

    def in_batch(self) -> bool:
        return self.in_batch_flag

    def committed(self) -> FreeRowMap:
        return self.committed_map

    def is_live(self, row: int) -> bool:
        return self.mask.get(row)

    def live_count(self) -> int:
        return self.committed_map.live_count()

    def charge_profile(self, totals) -> None:
        assert not self.in_batch_flag, "charge_profile during a batch"
        self.committed_map.charge_profile(totals)

    def begin_batch(self) -> FreeRowMap:
        assert not self.in_batch_flag, "nested DML batch on one relation"
        self.in_batch_flag = True
        self.mask.begin_batch()
        return clone_map(self.committed_map)

    def commit_batch(self, pending: FreeRowMap) -> None:
        assert self.in_batch_flag, "commit_batch outside a batch"
        if pending.capacity() > self.mask.capacity():
            self.mask.grow(pending.capacity() - self.mask.capacity())
        for row in range(pending.capacity()):
            self.mask.set_pending(row, pending.is_live(row))
        self.mask.commit_batch()
        self.committed_map = pending
        self.epoch_ctr += 1
        self.in_batch_flag = False

    def abort_batch(self) -> None:
        assert self.in_batch_flag, "abort_batch outside a batch"
        self.mask.abort_batch()
        self.in_batch_flag = False


# ---------------------------------------------------------------------------
# golden pin
# ---------------------------------------------------------------------------


def golden_epoch_digest() -> int:
    """Scripted begin/mutate/commit/abort interleaving digested to 64 bits.

    A deterministic LCG drives 300 operations over a 48-row map (3
    crossbars of 16 rows, 24 initially live). Every operation, every
    allocator answer *and* committed-view probes taken mid-batch are
    folded into an FNV-1a digest, so the digest pins the visibility rule
    itself — a committed reader view must never move while a batch is in
    flight.
    """
    em = EpochRowMap(FreeRowMap(capacity=48, initial_live=24, rows_per_xbar=16))
    state = FNV_OFFSET
    x = 7
    pending = None
    for _ in range(300):
        x = (x * 6364136223846793005 + 1442695040888963407) & MASK64
        op = x % 5
        arg = (x >> 8) % 64
        state = _fnv1a_fold(state, op)
        if op == 0:  # begin a batch (no-op fold when one is in flight)
            if pending is not None:
                state = _fnv1a_fold(state, 0)
            else:
                pending = em.begin_batch()
                state = _fnv1a_fold(state, 1)
        elif op == 1:  # mutate the pending clone: alloc+charge / release / grow
            if pending is None:
                state = _fnv1a_fold(state, 2)
            else:
                kind = (x >> 16) % 3
                if kind == 0:
                    row = pending.alloc()
                    state = _fnv1a_fold(state, 0xFFFF if row is None else row)
                    if row is not None:
                        pending.charge_row(row, (x >> 24) % 5 + 1)
                elif kind == 1:
                    row = None
                    for k in range(pending.capacity()):
                        cand = (arg + k) % pending.capacity()
                        if pending.is_live(cand):
                            row = cand
                            break
                    if row is None:
                        state = _fnv1a_fold(state, 0xFFFE)
                    else:
                        pending.release(row)
                        state = _fnv1a_fold(state, row)
                else:
                    pending.grow(16)
                    state = _fnv1a_fold(state, pending.capacity())
        elif op == 2:  # commit: visibility flips, epoch bumps
            if pending is None:
                state = _fnv1a_fold(state, 3)
            else:
                em.commit_batch(pending)
                pending = None
                state = _fnv1a_fold(state, em.epoch())
        elif op == 3:  # abort: committed view and wear untouched
            if pending is None:
                state = _fnv1a_fold(state, 5)
            else:
                em.abort_batch()
                pending = None
                state = _fnv1a_fold(state, 4)
        else:
            # committed-view probe (+ reader wear charge when idle) —
            # mid-batch probes must see the pre-batch state
            if pending is None and (x >> 16) & 1 == 1:
                totals = [((x >> 24) + 3 * r + 1) % 4 for r in range(16)]
                em.charge_profile(totals)
                state = _fnv1a_fold(state, sum(totals))
            r = arg % em.committed().capacity()
            state = _fnv1a_fold(state, int(em.is_live(r)) | (em.live_count() << 1))
    state = _fnv1a_fold(state, em.epoch())
    state = _fnv1a_fold(state, sum(em.committed().wear) & MASK64)
    return state


if __name__ == "__main__":
    print(hex(golden_epoch_digest()))
