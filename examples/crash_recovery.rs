//! Crash recovery: open a durable handle, commit a DML batch, simulate a
//! crash (drop the handle without a checkpoint), then reopen from the
//! data directory and watch the committed state survive.
//!
//!     cargo run --release --example crash_recovery

use pimdb::api::Pimdb;
use pimdb::config::{DurabilityConfig, FsyncPolicy, SystemConfig};
use pimdb::db::schema::RelId;
use pimdb::error::PimdbError;

fn main() -> Result<(), PimdbError> {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        ..SystemConfig::default()
    };
    let dir = std::env::temp_dir().join("pimdb-crash-recovery-example");
    let _ = std::fs::remove_dir_all(&dir);

    // 1. first open initializes the directory: a base image (the dbgen
    //    load image, a pure function of (sim_sf, seed)), an empty
    //    generation-0 checkpoint, and an empty WAL segment
    let mut dcfg = DurabilityConfig::new(&dir);
    dcfg.fsync = FsyncPolicy::GroupCommit; // fdatasync per committed batch
    let db = Pimdb::open_durable(cfg.clone(), dcfg.clone())?;
    let before = db.live_records(RelId::Supplier);

    // 2. committed DML appends one WAL record per batch *before* the
    //    batch's epoch publishes — write-ahead, so a commit the client
    //    observed is always reproducible
    db.execute_dml("delete from supplier where s_suppkey <= 5")?;
    db.execute_dml(
        "insert into supplier (s_suppkey, s_nationkey, s_acctbal) \
         values (20001, 3, 777.00)",
    )?;
    let stats = db.durability_stats().expect("durable handle");
    println!(
        "committed 2 batches: {} wal records, {} bytes, epoch {}",
        stats.wal_records_appended,
        stats.wal_bytes_appended,
        db.relation_epoch(RelId::Supplier),
    );

    // 3. simulate a crash: drop the handle with NO checkpoint — the only
    //    durable artifacts are the base image and the write-ahead log
    drop(db);

    // 4. reopen: recovery loads the (empty) checkpoint and replays the
    //    logged batches through the normal DML execution path
    let db = Pimdb::open_durable(cfg, dcfg)?;
    let stats = db.durability_stats().expect("durable handle");
    println!(
        "recovered: {} records replayed, {} torn tails truncated",
        stats.wal_records_replayed, stats.torn_tails_truncated,
    );

    // the committed mutations survived the crash
    assert_eq!(stats.wal_records_replayed, 2);
    assert_eq!(db.live_records(RelId::Supplier), before - 5 + 1);
    assert_eq!(db.relation_epoch(RelId::Supplier), 2);
    let n = db
        .prepare("from supplier | filter s_suppkey <= 5 | aggregate count() as n")?
        .execute()?;
    assert_eq!(n.rows().row(0).unwrap().get("n").unwrap().as_i64(), Some(0));
    println!(
        "live suppliers after recovery: {} (was {before})",
        db.live_records(RelId::Supplier)
    );

    // 5. a checkpoint bounds future replay work: it captures the crossbar
    //    bit-planes + wear state and rotates the WAL to a fresh segment
    let bytes = db.checkpoint()?;
    println!("checkpoint written: {bytes} bytes");
    Ok(())
}
