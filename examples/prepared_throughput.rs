//! Prepared-statement throughput: the serving-path shape the follow-up
//! papers emphasize (arXiv:2302.01675, 2307.00658) — one resident PIM
//! database copy, repeated query *templates*, many clients.
//!
//! Demonstrates the three things the service API adds over the one-shot
//! harness: (1) `prepare` amortizes parse/compile/optimize across
//! repeated templates via the plan cache, (2) `execute(&self)` lets any
//! number of threads share one `Arc<Pimdb>` without external locking, and
//! (3) results stay bit-identical to the serial path regardless of
//! thread count.
//!
//!     cargo run --release --example prepared_throughput

use std::sync::Arc;

use pimdb::api::Pimdb;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::error::PimdbError;

const TEMPLATES: [&str; 3] = [
    // three templates on three different relations: with per-relation
    // locking these execute fully in parallel
    "from lineitem | filter l_quantity < 24 \
     | aggregate sum(l_extendedprice * l_discount) as revenue_x100",
    "from supplier | filter s_acctbal > 912.00 \
     | aggregate count() as rich, avg(s_acctbal) as avg_bal",
    "from customer | filter c_mktsegment == \"BUILDING\"",
];

fn main() -> Result<(), PimdbError> {
    let cfg = SystemConfig {
        parallelism: 0, // auto-detect host cores for the shard pool
        ..SystemConfig::default()
    };
    let db = Arc::new(Pimdb::open(cfg, Database::generate(0.005, 42))?);

    // -- unprepared: parse + compile + optimize on every request ---------
    let t0 = std::time::Instant::now();
    const REPEATS: usize = 20;
    for _ in 0..REPEATS {
        db.clear_plan_cache(); // force the cold path honestly
        for src in TEMPLATES {
            db.prepare(src)?.execute()?;
        }
    }
    let cold = t0.elapsed();

    // -- prepared: compile once, execute many ----------------------------
    let stmts: Vec<_> = TEMPLATES
        .iter()
        .map(|src| db.prepare(*src))
        .collect::<Result<_, _>>()?;
    let t0 = std::time::Instant::now();
    for _ in 0..REPEATS {
        for stmt in &stmts {
            stmt.execute()?;
        }
    }
    let warm = t0.elapsed();

    let c = db.plan_cache_counters();
    println!(
        "plan cache: {} hits, {} misses over {} prepares",
        c.hits,
        c.misses,
        c.hits + c.misses
    );
    println!(
        "unprepared {:>8.2?} for {REPEATS}x{} queries",
        cold,
        TEMPLATES.len()
    );
    println!(
        "prepared   {:>8.2?} for the same load -> {:.2}x",
        warm,
        cold.as_secs_f64() / warm.as_secs_f64().max(1e-9)
    );

    // -- concurrent clients on one Arc<Pimdb> ----------------------------
    let serial: Vec<_> = stmts
        .iter()
        .map(|s| s.execute().map(|r| r.into_report().output))
        .collect::<Result<_, _>>()?;
    std::thread::scope(|scope| {
        let handles: Vec<_> = stmts
            .iter()
            .map(|stmt| scope.spawn(move || stmt.execute().map(|r| r.into_report().output)))
            .collect();
        for (h, want) in handles.into_iter().zip(&serial) {
            let got = h.join().expect("worker panicked").expect("execute failed");
            assert_eq!(&got, want, "concurrent result drifted from serial");
        }
    });
    println!("3 concurrent clients: outputs bit-identical to the serial run");
    Ok(())
}
