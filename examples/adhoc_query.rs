//! Ad-hoc text queries: parse a PQL pipeline, run it on PIMDB and the
//! column-store baseline, and read the diagnostics when the text is wrong.
//!
//! Like the other files in `examples/`, this is a library-usage sketch —
//! the directory sits outside the `rust/` package, so cargo does not
//! build it as an example target. The same strings work from the shell:
//!
//!     cargo run --release -- run --sql \
//!       'from supplier | filter s_acctbal > 912.00 and s_nationkey in region("AFRICA") | aggregate count() as n, avg(s_acctbal) as avg_bal' \
//!       --baseline

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::pimdb::{EngineKind, PimSession};
use pimdb::exec::baseline;
use pimdb::query::lang::parse_program;

fn main() -> Result<(), String> {
    let cfg = SystemConfig::default();
    let db = Database::generate(0.01, 42);

    // 1. any filter/aggregate the PIM substrate supports is a string now —
    //    this SUPPLIER query is hardcoded nowhere in the crate
    let src = r#"
        query rich_african_suppliers
        from supplier
        | filter s_acctbal > 912.00 and s_nationkey in region("AFRICA")
        | aggregate count() as n, avg(s_acctbal) as avg_bal
    "#;
    let queries = parse_program(src).map_err(|d| d.render(src))?;

    // 2. one resident PIM database copy serves the whole batch
    let mut session = PimSession::new(&cfg, &db)?;
    let reports = session.run_queries(&queries, EngineKind::Native)?;
    for (q, r) in queries.iter().zip(&reports) {
        println!("{}: {} suppliers selected", q.name, r.output.selected[0].1);
        for (label, value) in &r.output.groups[0].values {
            println!("  {label} = {value}");
        }
        // 3. cross-engine equivalence: the baseline computes the same
        //    operations on the host's column store
        let base = baseline::run_query(&cfg, &db, q);
        assert_eq!(r.output, base.output, "engines must agree");
        println!(
            "  PIMDB {:.3} ms vs baseline {:.3} ms (modelled at SF={})",
            r.metrics.exec_time_s * 1e3,
            base.metrics.exec_time_s * 1e3,
            cfg.report_sf,
        );
    }

    // 4. mistakes come back as spanned diagnostics, not panics
    let bad = "from supplier | filter s_acctbal > date(1994-01-01)";
    if let Err(d) = parse_program(bad) {
        println!("\nas expected, a type error renders as:\n{}", d.render(bad));
    }
    Ok(())
}
