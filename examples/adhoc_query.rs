//! Ad-hoc text queries on the service API: prepare a PQL pipeline, read
//! typed rows, watch the plan cache, and see spanned diagnostics when the
//! text is wrong.
//!
//! Like the other files in `examples/`, this is a library-usage sketch —
//! the directory sits outside the `rust/` package, so cargo does not
//! build it as an example target. The same strings work from the shell:
//!
//!     cargo run --release -- run --sql \
//!       'from supplier | filter s_acctbal > 912.00 and s_nationkey in region("AFRICA") | aggregate count() as n, avg(s_acctbal) as avg_bal' \
//!       --baseline

use pimdb::api::Pimdb;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::error::PimdbError;
use pimdb::exec::baseline;

fn main() -> Result<(), PimdbError> {
    // one owned service handle: the PIM database copy is resident, the
    // plan cache amortizes compilation across repeated templates
    let db = Pimdb::open(SystemConfig::default(), Database::generate(0.01, 42))?;

    // 1. any filter/aggregate the PIM substrate supports is a string now —
    //    this SUPPLIER query is hardcoded nowhere in the crate
    let src = r#"
        query rich_african_suppliers
        from supplier
        | filter s_acctbal > 912.00 and s_nationkey in region("AFRICA")
        | aggregate count() as n, avg(s_acctbal) as avg_bal
    "#;
    let stmt = db.prepare(src)?;
    let result = stmt.execute()?;
    println!("{}:", result.query_name());
    for row in result.rows() {
        for (col, value) in row.cells() {
            println!("  {col} = {value}");
        }
    }

    // 2. cross-engine equivalence: the baseline computes the same
    //    operations on the host's column store
    let base = baseline::run_query(db.cfg(), db.database(), stmt.query());
    assert_eq!(result.raw_report().output, base.output, "engines must agree");
    println!(
        "  PIMDB {:.3} ms vs baseline {:.3} ms (modelled at SF={})",
        result.metrics().exec_time_s * 1e3,
        base.metrics.exec_time_s * 1e3,
        db.cfg().report_sf,
    );

    // 3. re-preparing the same template — reformatted, re-aliased — is a
    //    plan-cache hit: compilation ran once
    let again = db.prepare(
        "from supplier | filter s_acctbal > 912.00 \
           and s_nationkey in region(\"AFRICA\") \
         | aggregate count() as how_many, avg(s_acctbal) as mean_bal",
    )?;
    let counters = db.plan_cache_counters();
    println!(
        "plan cache after re-prepare: {} hit(s), {} miss(es)",
        counters.hits, counters.misses
    );
    assert_eq!(counters.hits, 1);
    let _ = again.execute()?;

    // 4. mistakes come back as spanned diagnostics, not panics — the
    //    typed error carries the source and renders the caret listing
    let bad = "from supplier | filter s_acctbal > date(1994-01-01)";
    if let Err(e) = db.prepare(bad) {
        println!("\nas expected, a type error renders as:\n{e}");
    }
    Ok(())
}
