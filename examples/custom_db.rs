//! Ad-hoc analytics on the public API: build your own filter+aggregate
//! AST over any PIM relation — the paper's programming model (§3.1) as a
//! library. Here: "total supply cost of well-stocked cheap part offers"
//! over PARTSUPP, a query TPC-H does not ship.
//!
//!     cargo run --release --example custom_db

use pimdb::api::Pimdb;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::db::schema::RelId;
use pimdb::error::PimdbError;
use pimdb::exec::baseline;
use pimdb::query::ast::*;

fn main() -> Result<(), PimdbError> {
    let db = Pimdb::open(SystemConfig::default(), Database::generate(0.01, 7))?;

    // SELECT SUM(ps_supplycost * ps_availqty), COUNT(*), MAX(ps_availqty)
    // FROM partsupp
    // WHERE ps_availqty >= 5000 AND ps_supplycost < 250.00
    let query = Query {
        name: "custom_partsupp",
        kind: QueryKind::Full,
        rels: vec![RelQuery {
            rel: RelId::Partsupp,
            filter: Pred::And(vec![
                Pred::CmpImm {
                    attr: "ps_availqty",
                    op: CmpOp::Ge,
                    value: 5000,
                },
                Pred::CmpImm {
                    attr: "ps_supplycost",
                    op: CmpOp::Lt,
                    value: 25_000, // cents
                },
            ]),
            group_by: vec![],
            aggregates: vec![
                Aggregate {
                    kind: AggKind::Sum,
                    expr: ValExpr::MulAttrs("ps_supplycost", "ps_availqty"),
                    label: "total_value_cents",
                },
                Aggregate {
                    kind: AggKind::Count,
                    expr: ValExpr::One,
                    label: "offers",
                },
                Aggregate {
                    kind: AggKind::Max,
                    expr: ValExpr::Attr("ps_availqty"),
                    label: "max_qty",
                },
            ],
        }],
    };

    let stmt = db.prepare(&query)?;
    let pim = stmt.execute()?;
    let base = baseline::run_query(db.cfg(), db.database(), &query);
    assert_eq!(pim.raw_report().output, base.output, "PIM must equal the host oracle");

    println!("custom PARTSUPP analytics (SF=0.01):");
    let row = pim.rows().row(0).expect("one ungrouped row");
    for (label, v) in row.cells() {
        println!("  {label} = {v}");
    }
    println!(
        "modelled speedup over in-memory baseline at SF=1000: {:.1}x",
        base.metrics.exec_time_s / pim.metrics().exec_time_s
    );
    Ok(())
}
