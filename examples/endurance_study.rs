//! Endurance study (paper §6.4 extended): for each query, how many years
//! of back-to-back execution fit within a given RRAM endurance budget, and
//! how wear-leveling headroom (unused row cells) stretches it.
//!
//!     cargo run --release --example endurance_study [-- SF]

use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::error::PimdbError;
use pimdb::query::tpch;
use pimdb::util::stats::eng;

fn main() -> Result<(), PimdbError> {
    let sf: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap_or(0.005))
        .unwrap_or(0.005);
    let cfg = SystemConfig {
        sim_sf: sf,
        ..SystemConfig::default()
    };
    let db = Pimdb::open(cfg, Database::generate(sf, 42))?;

    const RRAM_ENDURANCE: f64 = 1e12; // [44]
    println!(
        "{:<8} {:>13} {:>14} {:>14} {:>12}",
        "Query", "ops/cell/exec", "10yr required", "years @1e12", "status"
    );
    for q in tpch::all_queries() {
        let r = db.prepare(QuerySource::Ast(&q))?.execute()?;
        let m = r.metrics();
        // executions until the budget is spent, at 100% duty cycle
        let execs = RRAM_ENDURANCE / m.ops_per_cell.max(1e-12);
        let years = execs * m.exec_time_s / (365.25 * 24.0 * 3600.0);
        println!(
            "{:<8} {:>13.3} {:>14} {:>13.1}y {:>12}",
            q.name,
            m.ops_per_cell,
            eng(m.required_endurance_10yr),
            years,
            if m.required_endurance_10yr <= RRAM_ENDURANCE {
                "ok"
            } else {
                "EXCEEDS"
            }
        );
    }
    println!("\npaper finding: ten-year lifetime holds for all but Q22_sub");
    println!("(small CUSTOMER relation -> the same cells recycle fastest)");
    Ok(())
}
