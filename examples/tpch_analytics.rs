//! End-to-end driver: the full paper workload on a real (small) dataset.
//!
//! Opens one PIMDB service handle, prepares and runs all 19 evaluated
//! queries on PIMDB and on the in-memory baseline, verifies the
//! functional outputs agree, and prints the headline table (speedup /
//! LLC-miss reduction / energy saving) plus the paper-shape checks. This
//! is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example tpch_analytics [-- SF [native|pjrt]]

use pimdb::api::{EngineKind, Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::error::PimdbError;
use pimdb::exec::baseline;
use pimdb::query::ast::QueryKind;
use pimdb::query::tpch;
use pimdb::util::stats::eng;

fn main() -> Result<(), PimdbError> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sf: f64 = args.first().map(|s| s.parse().unwrap_or(0.01)).unwrap_or(0.01);
    let engine_kind = match args.get(1).map(|s| s.as_str()) {
        Some("pjrt") => EngineKind::Pjrt,
        _ => EngineKind::Native,
    };

    let cfg = SystemConfig {
        sim_sf: sf,
        ..SystemConfig::default()
    };
    println!("generating TPC-H data at SF={sf} ...");
    let t0 = std::time::Instant::now();
    let db = Pimdb::open(cfg, Database::generate(sf, 42))?; // PIM copy loads once
    println!("generated in {:.2?}", t0.elapsed());

    println!(
        "\n{:<8} {:>11} {:>11} {:>9} {:>9} {:>9}  {}",
        "Query", "PIMDB", "Baseline", "Speedup", "LLC-red", "E-saving", "functional"
    );
    let mut mismatches = 0;
    let mut filter_speedups = Vec::new();
    let mut full_speedups = Vec::new();
    let wall = std::time::Instant::now();
    for q in tpch::all_queries() {
        let pim = db.prepare(QuerySource::Ast(&q))?.execute_on(engine_kind)?;
        let base = baseline::run_query(db.cfg(), db.database(), &q);
        let ok = pim.raw_report().output == base.output;
        if !ok {
            mismatches += 1;
        }
        let m = pim.metrics();
        let speedup = base.metrics.exec_time_s / m.exec_time_s;
        match q.kind {
            QueryKind::Full => full_speedups.push(speedup),
            QueryKind::FilterOnly => filter_speedups.push(speedup),
        }
        println!(
            "{:<8} {:>10}s {:>10}s {:>8.1}x {:>8.1}x {:>8.2}x  {}",
            q.name,
            eng(m.exec_time_s),
            eng(base.metrics.exec_time_s),
            speedup,
            base.metrics.llc_misses as f64 / m.llc_misses.max(1) as f64,
            base.metrics.total_energy_pj() / m.total_energy_pj(),
            if ok { "match" } else { "MISMATCH" }
        );
    }
    println!("\nsimulation wall-clock: {:.2?} ({:?} engine)", wall.elapsed(), engine_kind);

    // paper-shape summary
    let fmin = filter_speedups.iter().cloned().fold(f64::MAX, f64::min);
    let fmax = filter_speedups.iter().cloned().fold(0.0, f64::max);
    let gmin = full_speedups.iter().cloned().fold(f64::MAX, f64::min);
    let gmax = full_speedups.iter().cloned().fold(0.0, f64::max);
    println!("filter-only speedups: {fmin:.1}x - {fmax:.1}x   (paper: 1.6x - 18x, Q11 lowest)");
    println!("full-query  speedups: {gmin:.1}x - {gmax:.1}x   (paper: 62x - 787x)");
    if mismatches > 0 {
        eprintln!("error: {mismatches} functional mismatches");
        std::process::exit(1);
    }
    println!("all functional outputs match the baseline oracle");
    Ok(())
}
