//! Quickstart: generate a tiny TPC-H database, run one query on PIMDB,
//! compare with the in-memory baseline.
//!
//!     cargo run --release --example quickstart

use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::tpch;

fn main() -> Result<(), String> {
    // 1. system configuration (paper Table 3 defaults; everything is a
    //    `--set`-able knob, see SystemConfig)
    let cfg = SystemConfig::default();

    // 2. deterministic TPC-H data at a laptop-friendly scale factor
    let db = Database::generate(0.002, 42);

    // 3. one of the paper's 19 queries (Q6: filter + in-PIM aggregation)
    let q = tpch::query("Q6").ok_or("query not found")?;

    // 4. PIMDB: compiles the query to PIM requests, executes the
    //    bulk-bitwise program, and models timing/energy at SF=1000
    let pim = engine::run_query(&cfg, &db, &q, engine::EngineKind::Native)?;

    // 5. the same operations on the host's column store
    let base = baseline::run_query(&cfg, &db, &q);

    println!("Q6 revenue (x100 scaling): {}", pim.output.groups[0].values[0].1);
    println!("selected records (sim): {}", pim.output.selected[0].1);
    assert_eq!(pim.output, base.output, "engines must agree");

    println!(
        "PIMDB {:.3} ms vs baseline {:.1} ms -> speedup {:.1}x, energy saving {:.1}x",
        pim.metrics.exec_time_s * 1e3,
        base.metrics.exec_time_s * 1e3,
        base.metrics.exec_time_s / pim.metrics.exec_time_s,
        base.metrics.total_energy_pj() / pim.metrics.total_energy_pj()
    );
    Ok(())
}
