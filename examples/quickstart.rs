//! Quickstart: generate a tiny TPC-H database, open a PIMDB service
//! handle, run one prepared query, compare with the in-memory baseline.
//!
//!     cargo run --release --example quickstart

use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::error::PimdbError;
use pimdb::exec::baseline;

fn main() -> Result<(), PimdbError> {
    // 1. system configuration (paper Table 3 defaults; everything is a
    //    `--set`-able knob, see SystemConfig)
    let cfg = SystemConfig::default();

    // 2. the service handle owns a deterministic TPC-H database at a
    //    laptop-friendly scale factor (the PIM copy loads lazily, once)
    let db = Pimdb::open(cfg, Database::generate(0.002, 42))?;

    // 3. one of the paper's 19 queries (Q6: filter + in-PIM aggregation),
    //    prepared once: parse -> compile -> optimize, cached by AST hash
    let q6 = db.prepare(QuerySource::Tpch("Q6"))?;

    // 4. execute from &db: runs the bulk-bitwise program over the shard
    //    pool and models timing/energy at SF=1000
    let pim = q6.execute()?;

    // 5. the same operations on the host's column store
    let base = baseline::run_query(db.cfg(), db.database(), q6.query());

    // typed rows decode the schema encodings; raw_report() keeps the
    // engine-level view for cross-engine equivalence checks
    let row = pim.rows().row(0).expect("Q6 has one group");
    println!("Q6 {} = {}", row.cells()[0].0, row.cells()[0].1);
    println!("selected records (sim): {}", pim.raw_report().output.selected[0].1);
    assert_eq!(pim.raw_report().output, base.output, "engines must agree");

    let m = pim.metrics();
    println!(
        "PIMDB {:.3} ms vs baseline {:.1} ms -> speedup {:.1}x, energy saving {:.1}x",
        m.exec_time_s * 1e3,
        base.metrics.exec_time_s * 1e3,
        base.metrics.exec_time_s / m.exec_time_s,
        base.metrics.total_energy_pj() / m.total_energy_pj()
    );
    Ok(())
}
