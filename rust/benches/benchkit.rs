//! Minimal bench harness shared by the `rust/benches/*` targets
//! (criterion is not in the offline vendor set). Prints
//! criterion-compatible-ish lines: name, mean time per iteration, and a
//! derived throughput figure when given.

use std::time::Instant;

/// Run `f` until ~`budget_ms` of wall time is spent (after one warmup),
/// then report mean iteration time. Returns seconds per iteration.
#[allow(dead_code)] // each bench target uses its own subset of the kit
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.3} ms/iter ({iters} iters)", per * 1e3);
    per
}

/// Like [`bench`] but also prints a throughput in `unit`s per second.
#[allow(dead_code)] // each bench target uses its own subset of the kit
pub fn bench_throughput(
    name: &str,
    budget_ms: u64,
    units_per_iter: f64,
    unit: &str,
    f: impl FnMut(),
) -> f64 {
    let per = bench(name, budget_ms, f);
    let rate = units_per_iter / per;
    println!(
        "{:<44} {:>12.3e} {unit}/s",
        format!("{name} [throughput]"),
        rate
    );
    rate
}
