//! L3 hot-path microbenchmarks: the native bit-plane engine's
//! instruction throughput (the functional core of every query run).
//!
//! Perf target (DESIGN.md §7): >= 1 Gcell-op/s sustained on compare ops.

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::{bench, bench_throughput};
use pimdb::exec::engine::{exec_instr, Scratch, XbarState};
use pimdb::exec::pimdb::EngineKind;
use pimdb::exec::plan::{exec_steps_sharded, ExecPlan};
use pimdb::pim::endurance::OpCategory;
use pimdb::pim::isa::{ColRange, Opcode, PimInstruction};
use pimdb::query::compiler::Step;
use pimdb::util::bits::WORDS;
use pimdb::util::rng::Rng;

const XBARS: usize = 64;
const ROWS: f64 = 1024.0;

fn states() -> Vec<XbarState> {
    let mut rng = Rng::new(1);
    let mut sts = Vec::new();
    for _ in 0..XBARS {
        let mut st = XbarState::new(512);
        for c in 0..128 {
            for w in 0..WORDS {
                st.planes[c][w] = rng.next_u64();
            }
        }
        sts.push(st);
    }
    sts
}

fn run_all(sts: &mut [XbarState], instr: &PimInstruction) {
    let mut out = Vec::new();
    let mut scratch = Scratch::new();
    for st in sts.iter_mut() {
        exec_instr(st, instr, &mut out, &mut scratch);
    }
}

fn main() {
    let mut sts = states();
    let a = ColRange::new(0, 32);
    let b = ColRange::new(40, 32);
    let d = ColRange::new(200, 1);
    let cells = XBARS as f64 * ROWS * 32.0; // rows x bits touched

    let i = PimInstruction::with_imm(Opcode::LtImm, a, d, 0x9E3779B9);
    bench_throughput("engine/cmp_imm 32b x 64 xbars", 400, cells, "cell-op", || {
        run_all(&mut sts, &i)
    });

    let i = PimInstruction::binary(Opcode::Lt, a, b, d);
    bench_throughput("engine/cmp_cols 32b x 64 xbars", 400, cells, "cell-op", || {
        run_all(&mut sts, &i)
    });

    let i = PimInstruction::binary(Opcode::Add, a, b, ColRange::new(80, 33));
    bench_throughput("engine/add 32b x 64 xbars", 400, cells, "cell-op", || {
        run_all(&mut sts, &i)
    });

    let i = PimInstruction::binary(Opcode::Mul, ColRange::new(0, 16), ColRange::new(40, 16), ColRange::new(80, 32));
    bench_throughput(
        "engine/mul 16x16 x 64 xbars",
        400,
        XBARS as f64 * ROWS * 256.0,
        "cell-op",
        || run_all(&mut sts, &i),
    );

    let i = PimInstruction::unary(Opcode::ReduceSum, ColRange::new(0, 40), ColRange::new(0, 40));
    bench_throughput(
        "engine/reduce_sum 40b x 64 xbars",
        400,
        XBARS as f64 * ROWS * 40.0,
        "cell-op",
        || run_all(&mut sts, &i),
    );

    let i = PimInstruction::binary(Opcode::And, a, d, ColRange::new(120, 32));
    bench_throughput(
        "engine/mask-broadcast-and 32b x 64 xbars",
        400,
        cells,
        "cell-op",
        || run_all(&mut sts, &i),
    );

    // --- sharded parallel execution (exec/plan.rs) --------------------------
    // A representative mixed program (filter -> mask -> arith -> reduce),
    // serial vs sharded over host worker threads. Outputs are bit-identical
    // at every parallelism (integration-tested); this measures wall-clock.
    let step = |instr| Step {
        instr,
        category: OpCategory::Filter,
    };
    let steps: Vec<Step> = vec![
        step(PimInstruction::with_imm(
            Opcode::LtImm,
            a,
            ColRange::new(200, 1),
            0x9E3779B9,
        )),
        step(PimInstruction::binary(
            Opcode::And,
            a,
            ColRange::new(200, 1),
            ColRange::new(210, 32),
        )),
        step(PimInstruction::binary(
            Opcode::Mul,
            ColRange::new(210, 16),
            ColRange::new(40, 16),
            ColRange::new(250, 32),
        )),
        step(PimInstruction::unary(
            Opcode::ReduceSum,
            ColRange::new(250, 32),
            ColRange::new(250, 32),
        )),
    ];
    let mut results: Vec<(usize, f64)> = Vec::new();
    for p in [1usize, 2, 4, 8] {
        let plan = ExecPlan::with_parallelism(p);
        let per = bench(
            &format!("engine/sharded mixed program x{XBARS} xbars, parallelism={p}"),
            600,
            || {
                let out =
                    exec_steps_sharded(&mut sts, &steps, 200, EngineKind::Native, &plan).unwrap();
                std::hint::black_box(out.total_selected());
            },
        );
        results.push((p, per));
    }
    let serial = results[0].1;
    for &(p, per) in &results[1..] {
        println!(
            "engine/sharded speedup @{p} workers: {:.2}x over serial",
            serial / per
        );
    }
}
