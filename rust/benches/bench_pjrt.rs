//! PJRT dispatch benchmarks: per-kernel invocation latency and the
//! native-vs-PJRT functional engine comparison (L1/L2 perf signal; with
//! interpret=True lowering on CPU, wallclock is the dispatch+emulation
//! cost, not a TPU proxy — see DESIGN.md §Hardware-Adaptation).

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench;
use pimdb::exec::engine::{exec_steps_native, XbarState};
use pimdb::pim::endurance::OpCategory;
use pimdb::pim::isa::{ColRange, Opcode, PimInstruction};
use pimdb::query::compiler::Step;
use pimdb::runtime;
use pimdb::util::rng::Rng;

fn main() {
    if !runtime::runtime_available() {
        println!("bench_pjrt: PJRT runtime/artifacts unavailable — skipping");
        return;
    }
    let mut rng = Rng::new(5);
    let mut mk_states = |n: usize| {
        let mut sts = Vec::new();
        for _ in 0..n {
            let mut st = XbarState::new(256);
            for c in 0..64 {
                for w in 0..32 {
                    st.planes[c][w] = rng.next_u64();
                }
            }
            sts.push(st);
        }
        sts
    };
    let steps: Vec<Step> = vec![
        Step {
            instr: PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 24),
                ColRange::new(100, 1),
                0xABCDE,
            ),
            category: OpCategory::Filter,
        },
        Step {
            instr: PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 24),
                ColRange::new(100, 1),
                ColRange::new(110, 24),
            ),
            category: OpCategory::Arith,
        },
        Step {
            instr: PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(110, 24),
                ColRange::new(110, 24),
            ),
            category: OpCategory::AggCol,
        },
    ];

    for n in [16usize, 64] {
        let base = mk_states(n);
        bench(&format!("pjrt/filter+mask+reduce x{n} xbars"), 1500, || {
            let mut sts = base.clone();
            let out = runtime::exec_steps_pjrt(&mut sts, &steps, 100).unwrap();
            std::hint::black_box(out.mask_counts.len());
        });
        bench(&format!("native/filter+mask+reduce x{n} xbars"), 400, || {
            let mut sts = base.clone();
            let out = exec_steps_native(&mut sts, &steps, 100);
            std::hint::black_box(out.mask_counts.len());
        });
    }
}
