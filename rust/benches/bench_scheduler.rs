//! Media-controller scheduler throughput: requests scheduled per second
//! (the timing simulation's inner loop; Q1 issues ~100k requests).

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench_throughput;
use pimdb::config::SystemConfig;
use pimdb::pim::module::{MediaScheduler, PageLoc, ReqKind, Request};

fn main() {
    let cfg = SystemConfig::default();
    const N: usize = 100_000;

    bench_throughput("scheduler/pim-requests", 500, N as f64, "req", || {
        let mut s = MediaScheduler::new(&cfg);
        for i in 0..N {
            s.schedule(&Request {
                loc: PageLoc {
                    module: i % 8,
                    bank: (i / 8) % 64,
                    page: i % 518,
                },
                kind: ReqKind::Pim { cycles: 100 },
                issue_ps: (i as u64) * 2_500,
            });
        }
    });

    bench_throughput("scheduler/read-bursts", 500, N as f64, "req", || {
        let mut s = MediaScheduler::new(&cfg);
        for i in 0..N {
            s.schedule(&Request {
                loc: PageLoc {
                    module: i % 8,
                    bank: (i / 8) % 64,
                    page: i % 518,
                },
                kind: ReqKind::ReadBurst { bytes: 1 << 20 },
                issue_ps: (i as u64) * 2_500,
            });
        }
    });
}
