//! Cache-model throughput: accesses per second (the baseline executor
//! pushes one access per attribute per record through this).

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench_throughput;
use pimdb::config::SystemConfig;
use pimdb::mem::cache::CacheSim;
use pimdb::util::rng::Rng;

fn main() {
    let cfg = SystemConfig::default();
    const N: usize = 1_000_000;

    // streaming scan (the baseline's dominant pattern)
    bench_throughput("cache/streaming-scan", 500, N as f64, "access", || {
        let mut c = CacheSim::new(&cfg);
        for i in 0..N as u64 {
            c.access(0x1000_0000 + i * 4, false);
        }
        std::hint::black_box(c.stats.llc_misses);
    });

    // random accesses (worst case)
    bench_throughput("cache/random", 500, N as f64, "access", || {
        let mut c = CacheSim::new(&cfg);
        let mut rng = Rng::new(3);
        for _ in 0..N {
            c.access(rng.range_u64(0, 1 << 30) & !3, false);
        }
        std::hint::black_box(c.stats.llc_misses);
    });
}
