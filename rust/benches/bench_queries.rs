//! End-to-end per-query simulation benchmarks — the harness that backs
//! every run-based table/figure (Tables 5–6, Figs 8–9, 11–15). Each
//! iteration runs the complete PIMDB pipeline (compile -> functional
//! execution -> timing/energy/power/endurance simulation) plus the
//! baseline for the speedup pair, at a small SF.

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::opt::OptLevel;
use pimdb::query::tpch;

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.002;
    let db = Database::generate(cfg.sim_sf, 42);

    // optimizer win tracking: -O0 vs -O2 simulated PIM cycles per query,
    // so the perf trajectory records the pass pipeline's effect alongside
    // wall-clock (these are model cycles — deterministic, not timed)
    {
        let mut cfg_o0 = cfg.clone();
        cfg_o0.opt_level = OptLevel::O0;
        let mut s0 = engine::PimSession::new(&cfg_o0, &db).unwrap();
        let mut s2 = engine::PimSession::new(&cfg, &db).unwrap();
        println!("# optimizer cycles/xbar: query O0 O2 saved%");
        let (mut tot0, mut tot2) = (0u64, 0u64);
        for q in tpch::all_queries() {
            let a = s0.run_query(&q, engine::EngineKind::Native).unwrap();
            let b = s2.run_query(&q, engine::EngineKind::Native).unwrap();
            let (c0, c2) = (a.metrics.cycles.total(), b.metrics.cycles.total());
            tot0 += c0;
            tot2 += c2;
            println!(
                "# opt-cycles/{:<8} {:>10} {:>10} {:>6.1}%",
                q.name,
                c0,
                c2,
                100.0 * (c0 - c2) as f64 / c0.max(1) as f64
            );
        }
        println!(
            "# opt-cycles/total    {:>10} {:>10} {:>6.1}%",
            tot0,
            tot2,
            100.0 * (tot0 - tot2) as f64 / tot0.max(1) as f64
        );
    }

    // end-to-end simulation wall-clock at both opt levels (the optimizer
    // itself runs inside the session's compile step)
    for level in [OptLevel::O0, OptLevel::O2] {
        let mut c = cfg.clone();
        c.opt_level = level;
        let mut session = engine::PimSession::new(&c, &db).unwrap();
        let q = tpch::query("Q1").unwrap();
        bench(&format!("pimdb/Q1 at -{level} (sim SF=0.002)"), 800, || {
            let r = session.run_query(&q, engine::EngineKind::Native).unwrap();
            std::hint::black_box(r.metrics.exec_time_s);
        });
    }

    // representative of each class: biggest full query, biggest
    // filter-only, smallest (overhead-bound), multi-relation
    let mut session = engine::PimSession::new(&cfg, &db).unwrap();
    for name in ["Q1", "Q6", "Q14", "Q11", "Q3", "Q22_sub"] {
        let q = tpch::query(name).unwrap();
        bench(&format!("pimdb/{name} (sim SF=0.002)"), 800, || {
            let r = session.run_query(&q, engine::EngineKind::Native).unwrap();
            std::hint::black_box(r.metrics.exec_time_s);
        });
        bench(&format!("baseline/{name} (sim SF=0.002)"), 800, || {
            let r = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(r.metrics.exec_time_s);
        });
    }

    // the full 19-query suite (what `pimdb report --exp all` runs)
    bench("suite/all-19-queries pimdb+baseline", 3000, || {
        for q in tpch::all_queries() {
            let r = session.run_query(&q, engine::EngineKind::Native).unwrap();
            std::hint::black_box(r.metrics.exec_time_s);
            let b = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(b.metrics.exec_time_s);
        }
    });

    // batched multi-query serving path: the 19-query suite pipelined
    // through PimSession::run_queries over the shard pool (results are
    // bit-identical to the serial loop above; this measures wall-clock)
    let queries = tpch::all_queries();
    for p in [1usize, 4] {
        let mut cfg_par = cfg.clone();
        cfg_par.parallelism = p;
        let mut batch_session = engine::PimSession::new(&cfg_par, &db).unwrap();
        bench(
            &format!("suite/run_queries batched x19, parallelism={p}"),
            3000,
            || {
                let rs = batch_session
                    .run_queries(&queries, engine::EngineKind::Native)
                    .unwrap();
                std::hint::black_box(rs.len());
            },
        );
    }
}
