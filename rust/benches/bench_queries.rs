//! End-to-end per-query simulation benchmarks — the harness that backs
//! every run-based table/figure (Tables 5–6, Figs 8–9, 11–15). Each
//! iteration runs the complete PIMDB pipeline (compile -> functional
//! execution -> timing/energy/power/endurance simulation) plus the
//! baseline for the speedup pair, at a small SF.

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench;
use pimdb::config::SystemConfig;
use pimdb::db::dbgen::Database;
use pimdb::exec::{baseline, pimdb as engine};
use pimdb::query::tpch;

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.sim_sf = 0.002;
    let db = Database::generate(cfg.sim_sf, 42);

    // representative of each class: biggest full query, biggest
    // filter-only, smallest (overhead-bound), multi-relation
    let mut session = engine::PimSession::new(&cfg, &db).unwrap();
    for name in ["Q1", "Q6", "Q14", "Q11", "Q3", "Q22_sub"] {
        let q = tpch::query(name).unwrap();
        bench(&format!("pimdb/{name} (sim SF=0.002)"), 800, || {
            let r = session.run_query(&q, engine::EngineKind::Native).unwrap();
            std::hint::black_box(r.metrics.exec_time_s);
        });
        bench(&format!("baseline/{name} (sim SF=0.002)"), 800, || {
            let r = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(r.metrics.exec_time_s);
        });
    }

    // the full 19-query suite (what `pimdb report --exp all` runs)
    bench("suite/all-19-queries pimdb+baseline", 3000, || {
        for q in tpch::all_queries() {
            let r = session.run_query(&q, engine::EngineKind::Native).unwrap();
            std::hint::black_box(r.metrics.exec_time_s);
            let b = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(b.metrics.exec_time_s);
        }
    });

    // batched multi-query serving path: the 19-query suite pipelined
    // through PimSession::run_queries over the shard pool (results are
    // bit-identical to the serial loop above; this measures wall-clock)
    let queries = tpch::all_queries();
    for p in [1usize, 4] {
        let mut cfg_par = cfg.clone();
        cfg_par.parallelism = p;
        let mut batch_session = engine::PimSession::new(&cfg_par, &db).unwrap();
        bench(
            &format!("suite/run_queries batched x19, parallelism={p}"),
            3000,
            || {
                let rs = batch_session
                    .run_queries(&queries, engine::EngineKind::Native)
                    .unwrap();
                std::hint::black_box(rs.len());
            },
        );
    }
}
