//! End-to-end per-query simulation benchmarks — the harness that backs
//! every run-based table/figure (Tables 5–6, Figs 8–9, 11–15). Each
//! iteration runs the complete PIMDB pipeline (compile -> functional
//! execution -> timing/energy/power/endurance simulation) plus the
//! baseline for the speedup pair, at a small SF, through the `api::Pimdb`
//! service handle. A dedicated section records the prepared-vs-unprepared
//! serving-path ratio (plan cache on vs. cleared every iteration), a
//! mixed 90/10 query/DML round measures the HTAP serving rate, and an
//! open-loop 90/10 section measures p50/p99 serving tail latency under
//! concurrent DML against a lock-per-relation baseline and against a
//! durable (write-ahead-logged, group-commit fsync) twin, with the
//! recovery replay and checkpoint of the directory that run leaves
//! behind priced as `durability/*` entries (emitted as `BENCH {...}`
//! json lines).

#[path = "benchkit.rs"]
mod benchkit;

use benchkit::bench;
use pimdb::api::{Pimdb, QuerySource};
use pimdb::config::{DurabilityConfig, FsyncPolicy, SystemConfig};
use pimdb::db::dbgen::Database;
use pimdb::exec::baseline;
use pimdb::query::opt::OptLevel;
use pimdb::query::tpch;

fn main() {
    let cfg = SystemConfig {
        sim_sf: 0.002,
        ..SystemConfig::default()
    };
    let db = Database::generate(cfg.sim_sf, 42);

    // optimizer win tracking: -O0 vs -O2 simulated PIM cycles per query,
    // so the perf trajectory records the pass pipeline's effect alongside
    // wall-clock (these are model cycles — deterministic, not timed)
    {
        let cfg_o0 = SystemConfig {
            opt_level: OptLevel::O0,
            ..cfg.clone()
        };
        let h0 = Pimdb::open(cfg_o0, db.clone()).unwrap();
        let h2 = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        println!("# optimizer cycles/xbar: query O0 O2 saved%");
        let (mut tot0, mut tot2) = (0u64, 0u64);
        for q in tpch::all_queries() {
            let a = h0.prepare(QuerySource::Ast(&q)).unwrap().execute().unwrap();
            let b = h2.prepare(QuerySource::Ast(&q)).unwrap().execute().unwrap();
            let (c0, c2) = (a.metrics().cycles.total(), b.metrics().cycles.total());
            tot0 += c0;
            tot2 += c2;
            println!(
                "# opt-cycles/{:<8} {:>10} {:>10} {:>6.1}%",
                q.name,
                c0,
                c2,
                100.0 * (c0 - c2) as f64 / c0.max(1) as f64
            );
        }
        println!(
            "# opt-cycles/total    {:>10} {:>10} {:>6.1}%",
            tot0,
            tot2,
            100.0 * (tot0 - tot2) as f64 / tot0.max(1) as f64
        );
    }

    // end-to-end simulation wall-clock at both opt levels (the optimizer
    // itself runs inside the prepare step; prepare is re-done per
    // iteration with a cleared cache so the full pipeline is timed)
    for level in [OptLevel::O0, OptLevel::O2] {
        let c = SystemConfig {
            opt_level: level,
            ..cfg.clone()
        };
        let handle = Pimdb::open(c, db.clone()).unwrap();
        bench(&format!("pimdb/Q1 at -{level} (sim SF=0.002)"), 800, || {
            handle.clear_plan_cache();
            let stmt = handle.prepare(QuerySource::Tpch("Q1")).unwrap();
            let r = stmt.execute().unwrap();
            std::hint::black_box(r.metrics().exec_time_s);
        });
    }

    // per-query trajectory: wall-clock plus deterministic model cycles
    // for every query, as BENCH json lines (tools/bench_capture.sh
    // persists them into the committed BENCH_<n>.json trajectory)
    {
        let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        for q in tpch::all_queries() {
            let stmt = handle.prepare(QuerySource::Ast(&q)).unwrap();
            let cycles = stmt.execute().unwrap().metrics().cycles.total();
            let per = bench(&format!("query/{} (sim SF=0.002)", q.name), 250, || {
                let r = stmt.execute().unwrap();
                std::hint::black_box(r.metrics().exec_time_s);
            });
            println!(
                "BENCH {{\"name\":\"query/{}\",\"ms_per_iter\":{:.3},\
                 \"cycles\":{},\"sim_sf\":{}}}",
                q.name,
                per * 1e3,
                cycles,
                cfg.sim_sf
            );
        }
    }

    // representative of each class: biggest full query, biggest
    // filter-only, smallest (overhead-bound), multi-relation
    let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
    for name in ["Q1", "Q6", "Q14", "Q11", "Q3", "Q22_sub"] {
        let q = tpch::query(name).unwrap();
        let stmt = handle.prepare(QuerySource::Ast(&q)).unwrap();
        bench(&format!("pimdb/{name} (sim SF=0.002)"), 800, || {
            let r = stmt.execute().unwrap();
            std::hint::black_box(r.metrics().exec_time_s);
        });
        bench(&format!("baseline/{name} (sim SF=0.002)"), 800, || {
            let r = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(r.metrics.exec_time_s);
        });
    }

    // the full 19-query suite (what `pimdb report --exp all` runs);
    // repeated iterations serve from the plan cache *and* the per-
    // relation shared-scan mask cache, so this measures the steady-state
    // serving sweep
    let sweep_cycles: u64 = tpch::all_queries()
        .iter()
        .map(|q| {
            let r = handle.prepare(QuerySource::Ast(q)).unwrap().execute().unwrap();
            r.metrics().cycles.total()
        })
        .sum();
    let per = bench("suite/all-19-queries pimdb+baseline", 3000, || {
        for q in tpch::all_queries() {
            let r = handle
                .prepare(QuerySource::Ast(&q))
                .unwrap()
                .execute()
                .unwrap();
            std::hint::black_box(r.metrics().exec_time_s);
            let b = baseline::run_query(&cfg, &db, &q);
            std::hint::black_box(b.metrics.exec_time_s);
        }
    });
    println!(
        "BENCH {{\"name\":\"suite/all-19-sweep\",\"ms_per_iter\":{:.3},\
         \"cycles_total\":{},\"sim_sf\":{}}}",
        per * 1e3,
        sweep_cycles,
        cfg.sim_sf
    );

    // shared-scan serving: prepared aggregates over one relation whose
    // filters agree — the first execution per relation runs the full
    // program and caches the mask planes, the rest replay them and run
    // only their suffixes (see query::opt::sharedscan)
    {
        let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        let sources = [
            "from lineitem | filter l_quantity < 24 | aggregate count() as n",
            "from lineitem | filter l_quantity < 24 | aggregate sum(l_extendedprice) as s",
            "from lineitem | filter l_quantity < 24 | aggregate sum(l_quantity) as q",
        ];
        let stmts: Vec<_> = sources.iter().map(|s| handle.prepare(*s).unwrap()).collect();
        let per = bench("serving/shared-scan x3 (one relation)", 800, || {
            for st in &stmts {
                std::hint::black_box(st.execute().unwrap().metrics().exec_time_s);
            }
        });
        let c = handle.shared_scan_counters();
        println!(
            "BENCH {{\"name\":\"serving/shared-scan\",\"stmts_per_s\":{:.1},\
             \"hits\":{},\"misses\":{},\"sim_sf\":{}}}",
            sources.len() as f64 / per,
            c.hits,
            c.misses,
            cfg.sim_sf
        );
    }

    // prepared-vs-unprepared serving path: the same PQL template either
    // re-prepared cold (cache cleared -> parse+compile+optimize every
    // time) or executed from one prepared statement. The ratio is the
    // plan cache's amortization win (queries/sec both ways).
    const TEMPLATE: &str = "from lineitem \
        | filter (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01)) \
            and l_discount between 0.05..0.07 and l_quantity < 24 \
        | aggregate sum(l_extendedprice * l_discount) as revenue_x100";
    bench("serving/unprepared (parse+compile+execute)", 800, || {
        handle.clear_plan_cache();
        let r = handle.prepare(TEMPLATE).unwrap().execute().unwrap();
        std::hint::black_box(r.metrics().exec_time_s);
    });
    let stmt = handle.prepare(TEMPLATE).unwrap();
    bench("serving/prepared (execute only)", 800, || {
        let r = stmt.execute().unwrap();
        std::hint::black_box(r.metrics().exec_time_s);
    });

    // mixed ingest+analytics serving (the HTAP shape the DML subsystem
    // opens): one resident handle served a 90/10 query/DML statement mix
    // — 9 prepared Q6-template executions + 1 DML (alternating UPDATE and
    // INSERT) per round. Emits a BENCH json line so the perf trajectory
    // tracks the mixed serving rate explicitly.
    {
        let handle = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        let q = handle.prepare(TEMPLATE).unwrap();
        let upd = handle
            .prepare_dml("update lineitem set l_discount = 4 where l_quantity == 25")
            .unwrap();
        let ins = handle
            .prepare_dml(
                "insert into lineitem (l_orderkey, l_quantity, l_extendedprice, \
                 l_shipdate) values (1, 10, 100.00, date(1994-06-01))",
            )
            .unwrap();
        let mut round = 0u64;
        let per = bench("serving/mixed 90% query + 10% dml (x10 stmts)", 1500, || {
            round += 1;
            for _ in 0..9 {
                std::hint::black_box(q.execute().unwrap().metrics().exec_time_s);
            }
            let dml = if round % 2 == 0 { &ins } else { &upd };
            std::hint::black_box(dml.execute().unwrap().rows_affected);
        });
        println!(
            "BENCH {{\"name\":\"serving/mixed-90-10\",\"stmts_per_s\":{:.1},\
             \"dml_share\":0.1,\"sim_sf\":{}}}",
            10.0 / per,
            cfg.sim_sf
        );
    }

    // open-loop 90/10 serving with tail latency: requests arrive on a
    // seeded randomized schedule — independent of completions, so
    // queueing delay is part of the measured latency, not hidden by
    // back-pressure. Four reader threads execute the Q6 template at
    // ~0.7 utilization each, every arrival jittered uniformly within
    // its slot; one writer issues DML (a seeded UPDATE/INSERT mix on
    // the same relation) at one-ninth the aggregate query rate, i.e. a
    // 90/10 statement mix. The seed comes from PIMDB_BENCH_SEED
    // (default 42) and is printed with the results, so a tail-latency
    // report is reproducible: the same seed replays the exact arrival
    // offsets and DML sequence. Reported latency is completion minus
    // *scheduled* arrival. The identical workload (same seed) then runs
    // with every statement serialized behind one relation-wide mutex —
    // the lock-per-relation serving model the snapshot facade replaced —
    // as the baseline pair, so the trajectory records the
    // readers-under-writes win explicitly.
    {
        use pimdb::util::rng::Rng;
        use std::sync::{Barrier, Mutex};
        use std::time::{Duration, Instant};

        let seed: u64 = std::env::var("PIMDB_BENCH_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(42);
        println!("# open-loop seed {seed} (override with PIMDB_BENCH_SEED=<u64>)");

        fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
            if sorted_ms.is_empty() {
                return 0.0;
            }
            let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
            sorted_ms[idx]
        }

        let cfg_srv = SystemConfig {
            parallelism: 4,
            ..cfg.clone()
        };
        const N_READERS: usize = 4;
        const PER_READER: usize = 120;

        let run = |locked: bool, data_dir: Option<&std::path::Path>| -> (f64, f64, f64) {
            let handle = match data_dir {
                // durable twin: same workload, every committed batch
                // write-ahead logged with one fdatasync (GroupCommit)
                Some(dir) => {
                    let mut dcfg = DurabilityConfig::new(dir);
                    dcfg.fsync = FsyncPolicy::GroupCommit;
                    Pimdb::open_durable(cfg_srv.clone(), dcfg).unwrap()
                }
                None => Pimdb::open(cfg_srv.clone(), db.clone()).unwrap(),
            };
            let q = handle.prepare(TEMPLATE).unwrap();
            let upd = handle
                .prepare_dml("update lineitem set l_discount = 4 where l_quantity == 25")
                .unwrap();
            let ins = handle
                .prepare_dml(
                    "insert into lineitem (l_orderkey, l_quantity, l_extendedprice, \
                     l_shipdate) values (1, 10, 100.00, date(1994-06-01))",
                )
                .unwrap();
            // calibrate the mean closed-loop service time of one query
            let t0 = Instant::now();
            for _ in 0..32 {
                std::hint::black_box(q.execute().unwrap().metrics().exec_time_s);
            }
            let mean = t0.elapsed().as_secs_f64() / 32.0;
            let interval = Duration::from_secs_f64(mean / 0.7);
            let writer_interval =
                Duration::from_secs_f64(mean / 0.7 * 9.0 / N_READERS as f64);
            let writer_rounds = N_READERS * PER_READER / 9;

            let gate = Mutex::new(());
            let start = Barrier::new(N_READERS + 1);
            let bench_t0 = Instant::now();
            let mut lat_ms: Vec<f64> = Vec::new();
            std::thread::scope(|s| {
                let mut handles = Vec::new();
                for r in 0..N_READERS {
                    let (q, gate, start) = (&q, &gate, &start);
                    handles.push(s.spawn(move || {
                        // stagger the threads across one interval so the
                        // aggregate arrival process is evenly spaced,
                        // then jitter each arrival inside its slot from
                        // this reader's seeded stream
                        let offset = interval * r as u32 / N_READERS as u32;
                        let mut rng = Rng::new(seed).stream(1 + r as u64);
                        let mut lats = Vec::with_capacity(PER_READER);
                        start.wait();
                        let t0 = Instant::now();
                        for i in 0..PER_READER {
                            let jitter =
                                Duration::from_secs_f64(interval.as_secs_f64() * rng.f64());
                            let due = interval * i as u32 + offset + jitter;
                            let now = t0.elapsed();
                            if now < due {
                                std::thread::sleep(due - now);
                            }
                            let g = locked.then(|| gate.lock().unwrap());
                            std::hint::black_box(
                                q.execute().unwrap().metrics().exec_time_s,
                            );
                            drop(g);
                            lats.push((t0.elapsed() - due).as_secs_f64() * 1e3);
                        }
                        lats
                    }));
                }
                start.wait();
                // stream 0: the writer's arrival jitter and statement mix
                let mut rng = Rng::new(seed).stream(0);
                let t0 = Instant::now();
                for i in 0..writer_rounds {
                    let jitter = Duration::from_secs_f64(
                        writer_interval.as_secs_f64() * rng.f64(),
                    );
                    let due = writer_interval * i as u32 + jitter;
                    let now = t0.elapsed();
                    if now < due {
                        std::thread::sleep(due - now);
                    }
                    let g = locked.then(|| gate.lock().unwrap());
                    let dml = if rng.next_u64() % 2 == 0 { &upd } else { &ins };
                    std::hint::black_box(dml.execute().unwrap().rows_affected);
                    drop(g);
                }
                for h in handles {
                    lat_ms.extend(h.join().unwrap());
                }
            });
            let elapsed = bench_t0.elapsed().as_secs_f64();
            lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (
                percentile(&lat_ms, 0.50),
                percentile(&lat_ms, 0.99),
                lat_ms.len() as f64 / elapsed,
            )
        };

        let (p50, p99, qps) = run(false, None);
        println!(
            "BENCH {{\"name\":\"serving/open-loop-90-10\",\"p50_ms\":{p50:.3},\
             \"p99_ms\":{p99:.3},\"qps\":{qps:.1},\"dml_share\":0.1,\
             \"seed\":{seed},\"sim_sf\":{}}}",
            cfg.sim_sf
        );
        let (p50, p99, qps) = run(true, None);
        println!(
            "BENCH {{\"name\":\"serving/open-loop-90-10-locked\",\"p50_ms\":{p50:.3},\
             \"p99_ms\":{p99:.3},\"qps\":{qps:.1},\"dml_share\":0.1,\
             \"seed\":{seed},\"sim_sf\":{}}}",
            cfg.sim_sf
        );

        // durable twin of the open-loop pair: identical schedule through
        // `open_durable`, so the trajectory records what write-ahead
        // logging costs the serving tail. The directory the run leaves
        // behind then prices recovery itself: a reopen replays every
        // logged batch through the normal DML path, and a checkpoint of
        // the recovered state bounds future replay.
        let dir = std::env::temp_dir()
            .join(format!("pimdb-bench-durable-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (p50, p99, qps) = run(false, Some(&dir));
        println!(
            "BENCH {{\"name\":\"serving/open-loop-90-10-durable\",\
             \"p50_ms\":{p50:.3},\"p99_ms\":{p99:.3},\"qps\":{qps:.1},\
             \"dml_share\":0.1,\"fsync\":\"group-commit\",\"seed\":{seed},\
             \"sim_sf\":{}}}",
            cfg.sim_sf
        );
        {
            let mut dcfg = DurabilityConfig::new(&dir);
            dcfg.fsync = FsyncPolicy::GroupCommit;
            let t0 = Instant::now();
            let handle = Pimdb::open_durable(cfg_srv.clone(), dcfg).unwrap();
            let wall = t0.elapsed().as_secs_f64() * 1e3;
            let stats = handle.durability_stats().unwrap();
            println!(
                "BENCH {{\"name\":\"durability/recovery\",\"wall_ms\":{wall:.1},\
                 \"wal_records_replayed\":{},\"sim_sf\":{}}}",
                stats.wal_records_replayed, cfg.sim_sf
            );
            let t0 = Instant::now();
            let bytes = handle.checkpoint().unwrap();
            let wall = t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "BENCH {{\"name\":\"durability/checkpoint\",\"wall_ms\":{wall:.2},\
                 \"checkpoint_bytes\":{bytes},\"sim_sf\":{}}}",
                cfg.sim_sf
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // batched multi-query serving path: the 19-query suite as prepared
    // statements executed *concurrently* from &Pimdb (each query pins
    // its relation's epoch snapshot and runs over the shard pool);
    // results are bit-identical to the serial loop above — this measures
    // wall-clock only
    let queries = tpch::all_queries();
    for p in [1usize, 4] {
        let cfg_par = SystemConfig {
            parallelism: p,
            ..cfg.clone()
        };
        let batch = Pimdb::open(cfg_par, db.clone()).unwrap();
        let stmts: Vec<_> = queries
            .iter()
            .map(|q| batch.prepare(QuerySource::Ast(q)).unwrap())
            .collect();
        bench(
            &format!("suite/prepared concurrent x19, parallelism={p}"),
            3000,
            || {
                std::thread::scope(|s| {
                    let workers: Vec<_> = stmts
                        .iter()
                        .map(|st| s.spawn(move || st.execute().unwrap()))
                        .collect();
                    for w in workers {
                        std::hint::black_box(w.join().unwrap().metrics().exec_time_s);
                    }
                });
            },
        );
    }

    // fused batch serving: the same 19 prepared statements through one
    // `execute_batch` call — the multi-query fusion pass
    // (query::opt::fusion) merges the shareable filter prefixes per
    // relation into one scan program computing every member's mask in a
    // single pass, then the suffixes run concurrently. Outputs and
    // metrics are bit-identical to the serial sweep (rust/tests/
    // batch_equivalence.rs); the scan counters record how much prefix
    // work one batch shares vs PR 6's replay-only path (a replay needs a
    // prior byte-identical *execution*; fusion shares within the batch).
    {
        let batch = Pimdb::open(cfg.clone(), db.clone()).unwrap();
        let stmts: Vec<_> = queries
            .iter()
            .map(|q| batch.prepare(QuerySource::Ast(q)).unwrap())
            .collect();
        let refs: Vec<_> = stmts.iter().collect();
        let first = batch.execute_batch(&refs).unwrap();
        let cycles_total: u64 = first.iter().map(|r| r.metrics().cycles.total()).sum();
        let cold = batch.shared_scan_counters();
        let per = bench("suite/all-19-batched-sweep (execute_batch)", 3000, || {
            let rs = batch.execute_batch(&refs).unwrap();
            for r in &rs {
                std::hint::black_box(r.metrics().exec_time_s);
            }
        });
        println!(
            "BENCH {{\"name\":\"suite/all-19-batched-sweep\",\"ms_per_iter\":{:.3},\
             \"cycles_total\":{},\"cold_scan_hits\":{},\"cold_scan_misses\":{},\
             \"sim_sf\":{}}}",
            per * 1e3,
            cycles_total,
            cold.hits,
            cold.misses,
            cfg.sim_sf
        );
    }
}
