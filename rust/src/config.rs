//! System configuration (paper Table 3) with file/CLI overrides.
//!
//! Everything the simulators consume is centralized here so experiments can
//! sweep parameters without touching model code. The config file format is
//! `key = value` lines (no serde in the offline vendor set); the same keys
//! are accepted as `--set key=value` CLI overrides.

use std::collections::BTreeMap;

use crate::query::opt::OptLevel;

/// Full system configuration. Defaults reproduce paper Table 3.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    // --- PIM module geometry ---
    /// PIM modules (memory ranks) in the system; one OpenCAPI channel each.
    pub pim_modules: usize,
    /// Capacity of a single PIM module in bytes (128 GB).
    pub module_capacity: u64,
    /// Banks per PIM module.
    pub banks_per_module: usize,
    /// Memory chips per module (bank is distributed across chips).
    pub chips_per_module: usize,
    /// Subarrays controlled by one PIM controller.
    pub subarrays_per_pim_ctrl: usize,
    /// Crossbars per subarray.
    pub xbars_per_subarray: usize,
    /// Crossbar rows.
    pub xbar_rows: usize,
    /// Crossbar columns.
    pub xbar_cols: usize,
    /// Bits per crossbar read.
    pub xbar_read_bits: usize,
    /// Huge-page size in bytes (1 GB).
    pub page_bytes: u64,

    // --- PIM timing / energy ---
    /// Stateful-logic (MAGIC NOR) cycle time in picoseconds (30 ns).
    pub logic_cycle_ps: u64,
    /// Energy of a single stateful logic op, per participating cell (fJ).
    pub logic_energy_fj_per_bit: f64,
    /// Crossbar write energy per bit (pJ).
    pub write_energy_pj_per_bit: f64,
    /// Crossbar read energy per bit (pJ).
    pub read_energy_pj_per_bit: f64,
    /// Single PIM controller power (uW).
    pub pim_ctrl_power_uw: f64,
    /// RRAM array read latency (ns), R-DDR row read [37].
    pub rram_read_ns: u64,
    /// RRAM array write latency (ns).
    pub rram_write_ns: u64,

    // --- OpenCAPI channel ---
    /// Bandwidth per channel (bytes/s). 25 GB/s.
    pub opencapi_bw_bps: f64,
    /// Per-packet protocol header bytes.
    pub opencapi_header_bytes: u64,
    /// One-way channel latency (ns).
    pub opencapi_latency_ns: u64,

    // --- host ---
    /// Host cores used by query execution threads.
    pub exec_threads: usize,
    /// Host worker threads for the *functional* execution of PIM programs
    /// (sharded crossbar interpretation, [`crate::exec::plan`]). Changes
    /// wall-clock only: outputs and all simulated timing/energy/endurance
    /// metrics are bit-identical for every value. 0 = auto-detect cores.
    pub parallelism: usize,
    /// Admission cap of the always-on shard executor serving concurrent
    /// readers ([`crate::exec::pool`]): at most this many shard jobs may
    /// be queued or running; further submissions block their reader
    /// thread (back-pressure). 0 = auto (`4 * parallelism`). Wall-clock
    /// only — outputs and simulated metrics are identical for every
    /// value. Explicit caps below `parallelism` are rejected at
    /// [`crate::api::Pimdb::open`] with a typed
    /// [`Config`](crate::error::PimdbError::Config) error: they would
    /// leave shard workers permanently idle behind the admission gate.
    pub admission: usize,
    /// Host core frequency (Hz).
    pub core_freq_hz: f64,
    /// L1 data cache size (bytes).
    pub l1_bytes: usize,
    /// L1 associativity (ways).
    pub l1_ways: usize,
    /// L2 (LLC) size (bytes).
    pub l2_bytes: usize,
    /// L2 associativity (ways).
    pub l2_ways: usize,
    /// Cache block (line) size in bytes, shared by both levels.
    pub cache_block: usize,
    /// L1 hit latency (core cycles).
    pub l1_hit_cycles: u64,
    /// L2 hit latency (core cycles).
    pub l2_hit_cycles: u64,

    // --- DRAM main memory ---
    /// DDR4-2400, 2 channels: peak bandwidth (bytes/s).
    pub dram_bw_bps: f64,
    /// Idle (row-miss) access latency (ns).
    pub dram_latency_ns: u64,
    /// DRAM energy per byte transferred (pJ/B), activate+IO averaged.
    pub dram_energy_pj_per_byte: f64,
    /// DRAM standby/background power for the whole 64 GB pool (W);
    /// ~0.18 W/GB background at DDR4-2400 (gem5 DRAMPower-class figure).
    pub dram_standby_w: f64,
    /// Memory-level parallelism the OoO core sustains on streaming misses.
    pub host_mlp: f64,

    // --- host power (McPAT substitute) ---
    /// Active power per busy core (W).
    pub core_active_w: f64,
    /// Host uncore + idle power (W).
    pub host_idle_w: f64,

    // --- workload ---
    /// TPC-H scale factor actually materialized in the simulation.
    pub sim_sf: f64,
    /// Scale factor the timing/energy models report (paper: 1000).
    pub report_sf: f64,
    /// PIM-program optimization level (`-O0`..`-O2`). `-O0` executes the
    /// compiler's naive stream (the golden reference); `-O2` (default)
    /// runs the full pass pipeline of [`crate::query::opt`]. Outputs are
    /// bit-identical at every level; only cycles/energy/endurance and
    /// `peak_inter_cells` change.
    pub opt_level: OptLevel,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            pim_modules: 8,
            module_capacity: 128 << 30,
            banks_per_module: 64,
            chips_per_module: 8,
            subarrays_per_pim_ctrl: 64,
            xbars_per_subarray: 4,
            xbar_rows: 1024,
            xbar_cols: 512,
            xbar_read_bits: 16,
            page_bytes: 1 << 30,

            logic_cycle_ps: 30_000,
            logic_energy_fj_per_bit: 81.6,
            write_energy_pj_per_bit: 6.9,
            read_energy_pj_per_bit: 0.84,
            pim_ctrl_power_uw: 126.0,
            rram_read_ns: 100,
            rram_write_ns: 300,

            opencapi_bw_bps: 25e9,
            opencapi_header_bytes: 18,
            opencapi_latency_ns: 80,

            exec_threads: 4,
            parallelism: 1,
            admission: 0,
            core_freq_hz: 3.6e9,
            l1_bytes: 64 << 10,
            l1_ways: 4,
            l2_bytes: 8 << 20,
            l2_ways: 16,
            cache_block: 64,
            l1_hit_cycles: 4,
            l2_hit_cycles: 30,

            dram_bw_bps: 2.0 * 19.2e9,
            dram_latency_ns: 80,
            dram_energy_pj_per_byte: 20.0,
            dram_standby_w: 12.0,
            host_mlp: 10.0,

            core_active_w: 6.0,
            host_idle_w: 4.0,

            sim_sf: 0.01,
            report_sf: 1000.0,
            opt_level: OptLevel::default(),
        }
    }
}

impl SystemConfig {
    /// Crossbars per huge-page (16384 for the default geometry).
    pub fn xbars_per_page(&self) -> u64 {
        let xbar_bits = (self.xbar_rows * self.xbar_cols) as u64;
        self.page_bytes * 8 / xbar_bits
    }

    /// Records a page can host: one record per crossbar row.
    pub fn records_per_page(&self) -> u64 {
        self.xbars_per_page() * self.xbar_rows as u64
    }

    /// PIM controllers per page (each controls subarrays_per_pim_ctrl *
    /// xbars_per_subarray crossbars).
    pub fn pim_ctrls_per_page(&self) -> u64 {
        let per_ctrl = (self.subarrays_per_pim_ctrl * self.xbars_per_subarray) as u64;
        self.xbars_per_page().div_ceil(per_ctrl)
    }

    /// Total PIM memory bytes.
    pub fn pim_capacity(&self) -> u64 {
        self.module_capacity * self.pim_modules as u64
    }

    /// Apply one `key=value` override. Unknown keys are an error.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        macro_rules! parse {
            ($field:ident) => {
                self.$field = value
                    .parse()
                    .map_err(|e| format!("bad value for {key}: {e}"))?
            };
        }
        match key {
            "pim_modules" => parse!(pim_modules),
            "module_capacity" => parse!(module_capacity),
            "banks_per_module" => parse!(banks_per_module),
            "chips_per_module" => parse!(chips_per_module),
            "subarrays_per_pim_ctrl" => parse!(subarrays_per_pim_ctrl),
            "xbars_per_subarray" => parse!(xbars_per_subarray),
            "xbar_rows" => parse!(xbar_rows),
            "xbar_cols" => parse!(xbar_cols),
            "xbar_read_bits" => parse!(xbar_read_bits),
            "page_bytes" => parse!(page_bytes),
            "logic_cycle_ps" => parse!(logic_cycle_ps),
            "logic_energy_fj_per_bit" => parse!(logic_energy_fj_per_bit),
            "write_energy_pj_per_bit" => parse!(write_energy_pj_per_bit),
            "read_energy_pj_per_bit" => parse!(read_energy_pj_per_bit),
            "pim_ctrl_power_uw" => parse!(pim_ctrl_power_uw),
            "rram_read_ns" => parse!(rram_read_ns),
            "rram_write_ns" => parse!(rram_write_ns),
            "opencapi_bw_bps" => parse!(opencapi_bw_bps),
            "opencapi_header_bytes" => parse!(opencapi_header_bytes),
            "opencapi_latency_ns" => parse!(opencapi_latency_ns),
            "exec_threads" => parse!(exec_threads),
            "parallelism" => parse!(parallelism),
            "admission" => parse!(admission),
            "core_freq_hz" => parse!(core_freq_hz),
            "l1_bytes" => parse!(l1_bytes),
            "l1_ways" => parse!(l1_ways),
            "l2_bytes" => parse!(l2_bytes),
            "l2_ways" => parse!(l2_ways),
            "cache_block" => parse!(cache_block),
            "l1_hit_cycles" => parse!(l1_hit_cycles),
            "l2_hit_cycles" => parse!(l2_hit_cycles),
            "dram_bw_bps" => parse!(dram_bw_bps),
            "dram_latency_ns" => parse!(dram_latency_ns),
            "dram_energy_pj_per_byte" => parse!(dram_energy_pj_per_byte),
            "dram_standby_w" => parse!(dram_standby_w),
            "host_mlp" => parse!(host_mlp),
            "core_active_w" => parse!(core_active_w),
            "host_idle_w" => parse!(host_idle_w),
            "sim_sf" => parse!(sim_sf),
            "report_sf" => parse!(report_sf),
            "opt_level" => parse!(opt_level),
            _ => return Err(format!("unknown config key: {key}")),
        }
        Ok(())
    }

    /// Parse a `key = value` config file body (# comments allowed).
    pub fn apply_file(&mut self, body: &str) -> Result<(), String> {
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// All keys and current values (for `pimdb report --exp table3`).
    pub fn entries(&self) -> BTreeMap<&'static str, String> {
        let mut m = BTreeMap::new();
        m.insert("pim_modules", self.pim_modules.to_string());
        m.insert("module_capacity", self.module_capacity.to_string());
        m.insert("banks_per_module", self.banks_per_module.to_string());
        m.insert("chips_per_module", self.chips_per_module.to_string());
        m.insert(
            "subarrays_per_pim_ctrl",
            self.subarrays_per_pim_ctrl.to_string(),
        );
        m.insert("xbars_per_subarray", self.xbars_per_subarray.to_string());
        m.insert("xbar_rows", self.xbar_rows.to_string());
        m.insert("xbar_cols", self.xbar_cols.to_string());
        m.insert("xbar_read_bits", self.xbar_read_bits.to_string());
        m.insert("page_bytes", self.page_bytes.to_string());
        m.insert("logic_cycle_ps", self.logic_cycle_ps.to_string());
        m.insert(
            "logic_energy_fj_per_bit",
            self.logic_energy_fj_per_bit.to_string(),
        );
        m.insert(
            "write_energy_pj_per_bit",
            self.write_energy_pj_per_bit.to_string(),
        );
        m.insert(
            "read_energy_pj_per_bit",
            self.read_energy_pj_per_bit.to_string(),
        );
        m.insert("pim_ctrl_power_uw", self.pim_ctrl_power_uw.to_string());
        m.insert("opencapi_bw_bps", self.opencapi_bw_bps.to_string());
        m.insert("exec_threads", self.exec_threads.to_string());
        m.insert("parallelism", self.parallelism.to_string());
        m.insert("admission", self.admission.to_string());
        m.insert("core_freq_hz", self.core_freq_hz.to_string());
        m.insert("l1_bytes", self.l1_bytes.to_string());
        m.insert("l2_bytes", self.l2_bytes.to_string());
        m.insert("dram_bw_bps", self.dram_bw_bps.to_string());
        m.insert("sim_sf", self.sim_sf.to_string());
        m.insert("report_sf", self.report_sf.to_string());
        m.insert("opt_level", self.opt_level.to_string());
        m
    }
}

/// When the write-ahead log forces data to stable storage
/// ([`crate::api::Pimdb::open_durable`]; see ARCHITECTURE.md §Durability
/// for the tradeoff discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// `fsync` (data + file metadata) after every appended record: a
    /// committed batch survives power loss, at the cost of one full
    /// sync per group commit.
    Always,
    /// `fdatasync` after every appended record — one data sync per
    /// group-committed *batch* (the leader appends exactly one record
    /// per batch, so this is the paper-shaped group-commit discipline).
    /// File metadata may lag; a torn tail is truncated at recovery.
    #[default]
    GroupCommit,
    /// No explicit sync: the OS page cache decides. Recently committed
    /// batches may be lost on power failure, but the log remains
    /// prefix-consistent — recovery still lands on a batch boundary.
    Off,
}

impl std::str::FromStr for FsyncPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<FsyncPolicy, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "group-commit" | "group_commit" => Ok(FsyncPolicy::GroupCommit),
            "off" => Ok(FsyncPolicy::Off),
            other => Err(format!(
                "bad fsync policy '{other}' (expected always | group-commit | off)"
            )),
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::GroupCommit => "group-commit",
            FsyncPolicy::Off => "off",
        })
    }
}

/// Durability knobs for [`crate::api::Pimdb::open_durable`]: where the
/// data directory lives and how eagerly the WAL syncs. Kept separate from
/// [`SystemConfig`] (which fingerprints the *simulated machine*) so the
/// plan-cache fingerprint is independent of host storage choices.
#[derive(Clone, Debug, PartialEq)]
pub struct DurabilityConfig {
    /// Data directory holding `base.img`, checkpoints and WAL segments.
    /// Created on first open.
    pub data_dir: std::path::PathBuf,
    /// WAL sync discipline.
    pub fsync: FsyncPolicy,
    /// dbgen seed used when the directory is initialized (ignored on a
    /// reopen: the persisted base image wins).
    pub seed: u64,
}

impl DurabilityConfig {
    /// Durability config with the default [`FsyncPolicy::GroupCommit`]
    /// discipline and the CLI's default dbgen seed.
    pub fn new(data_dir: impl Into<std::path::PathBuf>) -> DurabilityConfig {
        DurabilityConfig {
            data_dir: data_dir.into(),
            fsync: FsyncPolicy::default(),
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_geometry_matches_paper() {
        let c = SystemConfig::default();
        // 1 GB page / 64 Kb crossbar = 16384 crossbars, 16.7M records
        assert_eq!(c.xbars_per_page(), 16384);
        assert_eq!(c.records_per_page(), 16384 * 1024);
        // 64 subarrays * 4 xbars = 256 xbars/ctrl -> 64 ctrls/page
        assert_eq!(c.pim_ctrls_per_page(), 64);
        // 8 modules x 128 GB = 1 TB
        assert_eq!(c.pim_capacity(), 1 << 40);
    }

    #[test]
    fn overrides_apply() {
        let mut c = SystemConfig::default();
        c.set("pim_modules", "4").unwrap();
        assert_eq!(c.pim_modules, 4);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("pim_modules", "x").is_err());
    }

    #[test]
    fn parallelism_knob_parses() {
        let mut c = SystemConfig::default();
        assert_eq!(c.parallelism, 1);
        c.set("parallelism", "8").unwrap();
        assert_eq!(c.parallelism, 8);
        c.set("parallelism", "0").unwrap(); // 0 = auto
        assert_eq!(c.parallelism, 0);
        assert!(c.set("parallelism", "-1").is_err());
    }

    #[test]
    fn admission_knob_parses() {
        let mut c = SystemConfig::default();
        assert_eq!(c.admission, 0); // 0 = auto (4 * parallelism)
        c.set("admission", "32").unwrap();
        assert_eq!(c.admission, 32);
        assert!(c.set("admission", "-3").is_err());
        assert_eq!(c.entries()["admission"], "32");
    }

    #[test]
    fn opt_level_knob_parses() {
        let mut c = SystemConfig::default();
        assert_eq!(c.opt_level, OptLevel::O2); // -O2 is the default
        c.set("opt_level", "0").unwrap();
        assert_eq!(c.opt_level, OptLevel::O0);
        c.set("opt_level", "O1").unwrap();
        assert_eq!(c.opt_level, OptLevel::O1);
        assert!(c.set("opt_level", "turbo").is_err());
        // entries() renders a re-parseable value
        let shown = c.entries()["opt_level"].clone();
        assert_eq!(shown.parse::<OptLevel>().unwrap(), OptLevel::O1);
    }

    #[test]
    fn fsync_policy_parses_and_round_trips() {
        for (text, want) in [
            ("always", FsyncPolicy::Always),
            ("group-commit", FsyncPolicy::GroupCommit),
            ("group_commit", FsyncPolicy::GroupCommit),
            ("off", FsyncPolicy::Off),
        ] {
            let got: FsyncPolicy = text.parse().unwrap();
            assert_eq!(got, want);
            assert_eq!(got.to_string().parse::<FsyncPolicy>().unwrap(), got);
        }
        assert!("everysooften".parse::<FsyncPolicy>().is_err());
        assert_eq!(FsyncPolicy::default(), FsyncPolicy::GroupCommit);
        let d = DurabilityConfig::new("/tmp/pimdb-data");
        assert_eq!(d.fsync, FsyncPolicy::GroupCommit);
        assert_eq!(d.seed, 42);
    }

    #[test]
    fn config_file_roundtrip() {
        let mut c = SystemConfig::default();
        c.apply_file("# comment\n exec_threads = 8 \n sim_sf = 0.1 # inline\n")
            .unwrap();
        assert_eq!(c.exec_threads, 8);
        assert_eq!(c.sim_sf, 0.1);
        assert!(c.apply_file("exec_threads 8").is_err());
    }
}
