//! Query compiler: per-relation AST → PIM instruction program (paper
//! §5.4).
//!
//! The compiler resolves attributes to crossbar column ranges via the
//! relation layout, allocates crossbar compute area for intermediate
//! results (the software-managed "additional computation area" of §3.1),
//! lowers predicates and aggregate arithmetic into Table 4 instructions,
//! and tags each instruction with its reporting category (filter / arith /
//! column-transform / aggregation, Tables 5–6).
//!
//! Program structure mirrors §5.4: a computation phase emitting PIM
//! requests followed by a read phase fetching either the transformed
//! filter column (filter-only relations) or the per-crossbar aggregate
//! values (full queries).

use std::fmt;

use crate::db::layout::RelationLayout;
use crate::db::schema::{self, RelId};
use crate::pim::endurance::OpCategory;
use crate::pim::isa::{ColRange, Opcode, PimInstruction};

use super::ast::*;

/// One compiled instruction with its reporting category.
#[derive(Clone, Debug)]
pub struct Step {
    /// The PIM instruction to execute.
    pub instr: PimInstruction,
    /// Reporting category (Tables 5–6 bucket).
    pub category: OpCategory,
}

impl fmt::Display for Step {
    /// Disassembly line: the instruction plus its reporting category
    /// (`pimdb run --explain`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let instr = self.instr.to_string();
        write!(f, "{instr:<44} ; {}", self.category.name())
    }
}

/// Which compute-area allocation failed (see [`CompileError`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    /// Program-lifetime column (filter mask).
    Persistent,
    /// Expression-lifetime column, LIFO-freed at -O0.
    Scratch,
}

impl AllocKind {
    fn name(&self) -> &'static str {
        match self {
            AllocKind::Persistent => "persistent",
            AllocKind::Scratch => "scratch",
        }
    }
}

/// Why compiling one relation's program failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CompileError {
    /// The crossbar compute area cannot hold the required columns.
    ComputeAreaExhausted {
        /// Which allocation class ran out.
        kind: AllocKind,
        /// Columns the failing allocation asked for.
        needed: usize,
        /// Column the allocation would have started at.
        at: usize,
        /// One past the last usable crossbar column.
        limit: usize,
    },
    /// Internal allocator discipline violation: persistent columns must
    /// all be allocated before the first scratch column.
    PersistentAfterScratch,
    /// The relation's PIM copy has no attribute with this name.
    NoSuchAttribute {
        /// The relation searched.
        rel: RelId,
        /// The missing attribute name.
        attr: String,
    },
    /// Column-column compare between attributes of different widths.
    CmpWidthMismatch {
        /// Left attribute name.
        a: String,
        /// Left attribute width in bits.
        a_bits: usize,
        /// Right attribute name.
        b: String,
        /// Right attribute width in bits.
        b_bits: usize,
    },
    /// A DML value does not fit the attribute's encoded width.
    ValueTooWide {
        /// The attribute being written.
        attr: String,
        /// Its encoded width in bits.
        bits: usize,
        /// The out-of-range encoded value.
        value: u64,
    },
    /// A DML statement lists the same attribute twice.
    DuplicateAttr {
        /// The relation being mutated.
        rel: RelId,
        /// The repeated attribute name.
        attr: String,
    },
    /// A DML statement targets a DRAM-resident relation (NATION/REGION
    /// have no PIM copy to mutate).
    NotPimResident {
        /// The relation the statement named.
        rel: RelId,
    },
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::ComputeAreaExhausted {
                kind,
                needed,
                at,
                limit,
            } => write!(
                f,
                "compute area exhausted ({needed} {} cols at {at}/{limit})",
                kind.name()
            ),
            CompileError::PersistentAfterScratch => {
                write!(f, "persistent alloc after scratch allocs")
            }
            CompileError::NoSuchAttribute { rel, attr } => {
                write!(f, "{rel:?} has no attribute {attr}")
            }
            CompileError::CmpWidthMismatch {
                a,
                a_bits,
                b,
                b_bits,
            } => write!(
                f,
                "column compare widths differ: {a}({a_bits}) vs {b}({b_bits})"
            ),
            CompileError::ValueTooWide { attr, bits, value } => write!(
                f,
                "value {value} does not fit {attr} ({bits} bits)"
            ),
            CompileError::DuplicateAttr { rel, attr } => {
                write!(f, "{rel:?} attribute {attr} listed twice")
            }
            CompileError::NotPimResident { rel } => {
                write!(f, "{rel:?} is DRAM-resident; DML mutates PIM relations only")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// One contiguous compute-area allocation — the def/use metadata the
/// optimizer passes ([`crate::query::opt`]) use to reason about column
/// lifetimes. `born_step` is the index into [`CompiledRelQuery::steps`]
/// current when the columns were handed out; every write to the span's
/// columns at or after that index belongs to this span (the -O0 LIFO
/// discipline may later reuse the same columns for a younger span, which
/// then has a larger `born_step`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSpan {
    /// First column of the span.
    pub start: usize,
    /// Columns allocated.
    pub width: usize,
    /// `steps.len()` at allocation time.
    pub born_step: usize,
}

/// What the read phase fetches per page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadKind {
    /// The transformed filter column: 1 bit per record.
    FilterMask,
    /// `values` aggregate results of `bits` each per crossbar.
    Aggregates { values: usize, bits: usize },
}

/// Where one aggregate output comes from.
#[derive(Clone, Debug)]
pub struct OutputSpec {
    /// Index into [`CompiledRelQuery::groups`].
    pub group: usize,
    /// Output column label.
    pub label: &'static str,
    /// The aggregate function.
    pub kind: AggKind,
    /// Index of this output's reduce step among all reduce steps.
    pub reduce_index: usize,
    /// For Avg: the paired count's reduce index (host division).
    pub count_index: Option<usize>,
}

/// A group's identifying values (group_by attr, dict id).
pub type GroupKey = Vec<(&'static str, u64)>;

/// Compiled program for one relation of one query.
#[derive(Clone, Debug)]
pub struct CompiledRelQuery {
    /// The relation the program runs on.
    pub rel: RelId,
    /// The instruction stream (identical on every crossbar/page).
    pub steps: Vec<Step>,
    /// What the read phase fetches.
    pub read: ReadKind,
    /// Group keys in output order (one empty key when ungrouped).
    pub groups: Vec<GroupKey>,
    /// Where each aggregate output comes from.
    pub outputs: Vec<OutputSpec>,
    /// Total reduce steps emitted (values read per crossbar).
    pub n_reduces: usize,
    /// Column holding the final filter mask (post valid-AND).
    pub mask_col: usize,
    /// Peak compute-area columns used (Table 5 "Inter. cells").
    pub peak_inter_cells: usize,
    /// Compute-area allocations in allocation order (pass metadata).
    pub spans: Vec<AllocSpan>,
    /// First compute-area column (columns below hold data + valid bits).
    pub compute_base: usize,
    /// The relation's VALID column (read-only input to the program).
    pub valid_col: usize,
}

/// Crossbar compute-area allocator: persistent columns grow from the base,
/// scratch columns stack above them and are freed in LIFO batches. Every
/// allocation is also recorded as an [`AllocSpan`] so the optimizer can
/// reconstruct column lifetimes (`-O2` replaces this LIFO discipline with
/// lifetime-based reallocation).
struct ColAlloc {
    base: usize,
    limit: usize,
    persistent_top: usize,
    scratch_top: usize,
    peak: usize,
    spans: Vec<AllocSpan>,
}

impl ColAlloc {
    fn new(base: usize, limit: usize) -> Self {
        ColAlloc {
            base,
            limit,
            persistent_top: base,
            scratch_top: base,
            peak: 0,
            spans: Vec::new(),
        }
    }

    fn persistent(&mut self, n: usize, at_step: usize) -> Result<usize, CompileError> {
        if self.persistent_top != self.scratch_top {
            return Err(CompileError::PersistentAfterScratch);
        }
        let at = self.persistent_top;
        if at + n > self.limit {
            return Err(CompileError::ComputeAreaExhausted {
                kind: AllocKind::Persistent,
                needed: n,
                at,
                limit: self.limit,
            });
        }
        self.persistent_top += n;
        self.scratch_top = self.persistent_top;
        self.note_alloc(at, n, at_step);
        Ok(at)
    }

    fn scratch(&mut self, n: usize, at_step: usize) -> Result<usize, CompileError> {
        let at = self.scratch_top;
        if at + n > self.limit {
            return Err(CompileError::ComputeAreaExhausted {
                kind: AllocKind::Scratch,
                needed: n,
                at,
                limit: self.limit,
            });
        }
        self.scratch_top += n;
        self.note_alloc(at, n, at_step);
        Ok(at)
    }

    /// Free all scratch above `mark` (LIFO batch free).
    fn release_to(&mut self, mark: usize) {
        debug_assert!(mark >= self.persistent_top);
        self.scratch_top = mark;
    }

    fn mark(&self) -> usize {
        self.scratch_top
    }

    fn note_alloc(&mut self, at: usize, n: usize, at_step: usize) {
        self.spans.push(AllocSpan {
            start: at,
            width: n,
            born_step: at_step,
        });
        self.peak = self.peak.max(self.scratch_top - self.base);
    }
}

/// AST → PIM program compiler for one relation (see module docs).
pub struct Compiler<'a> {
    layout: &'a RelationLayout,
    alloc: ColAlloc,
    steps: Vec<Step>,
    n_reduces: usize,
}

impl<'a> Compiler<'a> {
    /// Compile one relation's query against its crossbar layout.
    pub fn compile(
        rq: &RelQuery,
        layout: &'a RelationLayout,
        xbar_cols: usize,
    ) -> Result<CompiledRelQuery, CompileError> {
        let mut c = Compiler {
            layout,
            alloc: ColAlloc::new(layout.compute_base, xbar_cols),
            steps: Vec::new(),
            n_reduces: 0,
        };

        // 1. base filter mask (persistent) = predicate AND valid
        let mask = c.alloc.persistent(1, 0)?;
        let mark = c.alloc.mark();
        c.lower_pred(&rq.filter, mask, OpCategory::Filter)?;
        c.emit(
            PimInstruction::binary(
                Opcode::And,
                ColRange::new(mask, 1),
                ColRange::new(layout.valid_col, 1),
                ColRange::new(mask, 1),
            ),
            OpCategory::Filter,
        );
        c.alloc.release_to(mark);

        if rq.aggregates.is_empty() {
            // filter-only: transform the mask for row-oriented read-out
            c.emit(
                PimInstruction::unary(
                    Opcode::ColumnTransform,
                    ColRange::new(mask, 1),
                    ColRange::new(mask, 1),
                ),
                OpCategory::ColTransform,
            );
            return Ok(CompiledRelQuery {
                rel: rq.rel,
                steps: c.steps,
                read: ReadKind::FilterMask,
                groups: vec![vec![]],
                outputs: vec![],
                n_reduces: 0,
                mask_col: mask,
                peak_inter_cells: c.alloc.peak,
                spans: c.alloc.spans,
                compute_base: layout.compute_base,
                valid_col: layout.valid_col,
            });
        }

        // 2. group expansion over the dictionary domains
        let groups = expand_groups(rq);
        let mut outputs = Vec::new();
        for (gi, key) in groups.iter().enumerate() {
            let gmask = if key.is_empty() {
                mask
            } else {
                let gm = c.alloc.scratch(1, c.steps.len())?;
                c.group_mask(mask, key, gm)?;
                gm
            };
            let group_mark = c.alloc.mark();
            let mut count_idx: Option<usize> = None;
            // pre-pass: COUNT / AVG need the mask count once per group
            let needs_count = rq
                .aggregates
                .iter()
                .any(|a| matches!(a.kind, AggKind::Count | AggKind::Avg));
            if needs_count {
                count_idx = Some(c.emit_reduce_count(gmask));
            }
            for agg in &rq.aggregates {
                let m2 = c.alloc.mark();
                match agg.kind {
                    AggKind::Count => {
                        outputs.push(OutputSpec {
                            group: gi,
                            label: agg.label,
                            kind: agg.kind,
                            reduce_index: count_idx.unwrap(),
                            count_index: None,
                        });
                    }
                    AggKind::Sum | AggKind::Avg => {
                        let (cols, _) = c.lower_masked_value(&agg.expr, gmask)?;
                        let ri = c.emit_reduce(Opcode::ReduceSum, cols);
                        outputs.push(OutputSpec {
                            group: gi,
                            label: agg.label,
                            kind: agg.kind,
                            reduce_index: ri,
                            count_index: if agg.kind == AggKind::Avg {
                                count_idx
                            } else {
                                None
                            },
                        });
                    }
                    AggKind::Min | AggKind::Max => {
                        let cols = c.lower_minmax_adjusted(&agg.expr, gmask, agg.kind)?;
                        let op = if agg.kind == AggKind::Min {
                            Opcode::ReduceMin
                        } else {
                            Opcode::ReduceMax
                        };
                        let ri = c.emit_reduce(op, cols);
                        outputs.push(OutputSpec {
                            group: gi,
                            label: agg.label,
                            kind: agg.kind,
                            reduce_index: ri,
                            count_index: count_idx,
                        });
                    }
                }
                c.alloc.release_to(m2); // aggregate results are read out
            }
            c.alloc.release_to(group_mark);
        }

        let n_reduces = c.n_reduces;
        Ok(CompiledRelQuery {
            rel: rq.rel,
            steps: c.steps,
            read: ReadKind::Aggregates {
                values: n_reduces,
                bits: 64,
            },
            groups,
            outputs,
            n_reduces,
            mask_col: mask,
            peak_inter_cells: c.alloc.peak,
            spans: c.alloc.spans,
            compute_base: layout.compute_base,
            valid_col: layout.valid_col,
        })
    }

    fn emit(&mut self, instr: PimInstruction, category: OpCategory) {
        self.steps.push(Step { instr, category });
    }

    fn attr_range(&self, name: &str) -> Result<ColRange, CompileError> {
        let slot = self
            .layout
            .slot(name)
            .ok_or_else(|| CompileError::NoSuchAttribute {
                rel: self.layout.rel,
                attr: name.to_string(),
            })?;
        Ok(ColRange::new(slot.start, slot.attr.bits))
    }

    /// Lower a predicate into single-column mask `dst`.
    fn lower_pred(
        &mut self,
        p: &Pred,
        dst: usize,
        cat: OpCategory,
    ) -> Result<(), CompileError> {
        let d = ColRange::new(dst, 1);
        match p {
            Pred::True => {
                self.emit(
                    PimInstruction::unary(Opcode::Set, d, d),
                    cat,
                );
            }
            Pred::CmpImm { attr, op, value } => {
                let a = self.attr_range(attr)?;
                self.lower_cmp_imm(a, *op, *value, dst, cat)?;
            }
            Pred::InSet { attr, values } => {
                let a = self.attr_range(attr)?;
                self.emit(PimInstruction::unary(Opcode::Reset, d, d), cat);
                let mark = self.alloc.mark();
                let t = self.alloc.scratch(1, self.steps.len())?;
                for &v in values {
                    self.lower_cmp_imm(a, CmpOp::Eq, v, t, cat)?;
                    self.emit(
                        PimInstruction::binary(Opcode::Or, d, ColRange::new(t, 1), d),
                        cat,
                    );
                }
                self.alloc.release_to(mark);
            }
            Pred::Between { attr, lo, hi } => {
                let a = self.attr_range(attr)?;
                let mark = self.alloc.mark();
                let t = self.alloc.scratch(1, self.steps.len())?;
                self.lower_cmp_imm(a, CmpOp::Ge, *lo, dst, cat)?;
                self.lower_cmp_imm(a, CmpOp::Le, *hi, t, cat)?;
                self.emit(
                    PimInstruction::binary(Opcode::And, d, ColRange::new(t, 1), d),
                    cat,
                );
                self.alloc.release_to(mark);
            }
            Pred::CmpCols { a, op, b } => {
                let ra = self.attr_range(a)?;
                let rb = self.attr_range(b)?;
                if ra.len != rb.len {
                    return Err(CompileError::CmpWidthMismatch {
                        a: a.to_string(),
                        a_bits: ra.len as usize,
                        b: b.to_string(),
                        b_bits: rb.len as usize,
                    });
                }
                match op {
                    CmpOp::Eq => {
                        self.emit(PimInstruction::binary(Opcode::Eq, ra, rb, d), cat)
                    }
                    CmpOp::Ne => {
                        self.emit(PimInstruction::binary(Opcode::Eq, ra, rb, d), cat);
                        self.emit(PimInstruction::unary(Opcode::Not, d, d), cat);
                    }
                    CmpOp::Lt => {
                        self.emit(PimInstruction::binary(Opcode::Lt, ra, rb, d), cat)
                    }
                    CmpOp::Gt => {
                        self.emit(PimInstruction::binary(Opcode::Lt, rb, ra, d), cat)
                    }
                    CmpOp::Le => {
                        self.emit(PimInstruction::binary(Opcode::Lt, rb, ra, d), cat);
                        self.emit(PimInstruction::unary(Opcode::Not, d, d), cat);
                    }
                    CmpOp::Ge => {
                        self.emit(PimInstruction::binary(Opcode::Lt, ra, rb, d), cat);
                        self.emit(PimInstruction::unary(Opcode::Not, d, d), cat);
                    }
                }
            }
            Pred::And(ps) | Pred::Or(ps) => {
                let combine = if matches!(p, Pred::And(_)) {
                    Opcode::And
                } else {
                    Opcode::Or
                };
                let mut first = true;
                let mark = self.alloc.mark();
                let t = self.alloc.scratch(1, self.steps.len())?;
                for sub in ps {
                    if first {
                        self.lower_pred(sub, dst, cat)?;
                        first = false;
                    } else {
                        self.lower_pred(sub, t, cat)?;
                        self.emit(
                            PimInstruction::binary(combine, d, ColRange::new(t, 1), d),
                            cat,
                        );
                    }
                }
                if first {
                    // empty conjunction/disjunction
                    let op = if combine == Opcode::And {
                        Opcode::Set
                    } else {
                        Opcode::Reset
                    };
                    self.emit(PimInstruction::unary(op, d, d), cat);
                }
                self.alloc.release_to(mark);
            }
            Pred::Not(sub) => {
                self.lower_pred(sub, dst, cat)?;
                self.emit(PimInstruction::unary(Opcode::Not, d, d), cat);
            }
        }
        Ok(())
    }

    /// attr <op> imm into mask column `dst`. Uses the immediate-in-control-
    /// path instructions (§3.3), rewriting Le/Ge to Lt/Gt bounds.
    fn lower_cmp_imm(
        &mut self,
        a: ColRange,
        op: CmpOp,
        value: u64,
        dst: usize,
        cat: OpCategory,
    ) -> Result<(), CompileError> {
        let d = ColRange::new(dst, 1);
        let max = if a.len as u32 >= 64 {
            u64::MAX
        } else {
            (1u64 << a.len) - 1
        };
        let mk = |op, v| PimInstruction::with_imm(op, a, d, v);
        // The engine's CmpImm ops truncate the immediate to the operand's
        // low `a.len` bits (ISA contract), so any immediate wider than the
        // attribute MUST be canonicalized here: an a.len-bit value can never
        // equal (or exceed) an out-of-range constant, making each predicate
        // a compile-time constant mask.
        match op {
            CmpOp::Eq => {
                if value > max {
                    self.emit(PimInstruction::unary(Opcode::Reset, d, d), cat);
                } else {
                    self.emit(mk(Opcode::EqImm, value), cat);
                }
            }
            CmpOp::Ne => {
                if value > max {
                    self.emit(PimInstruction::unary(Opcode::Set, d, d), cat);
                } else {
                    self.emit(mk(Opcode::NeImm, value), cat);
                }
            }
            CmpOp::Lt => {
                if value == 0 {
                    self.emit(PimInstruction::unary(Opcode::Reset, d, d), cat);
                } else if value > max {
                    self.emit(PimInstruction::unary(Opcode::Set, d, d), cat);
                } else {
                    self.emit(mk(Opcode::LtImm, value), cat);
                }
            }
            CmpOp::Gt => {
                if value >= max {
                    self.emit(PimInstruction::unary(Opcode::Reset, d, d), cat);
                } else {
                    self.emit(mk(Opcode::GtImm, value), cat);
                }
            }
            CmpOp::Le => {
                if value >= max {
                    self.emit(PimInstruction::unary(Opcode::Set, d, d), cat);
                } else {
                    self.emit(mk(Opcode::LtImm, value + 1), cat);
                }
            }
            CmpOp::Ge => {
                if value == 0 {
                    self.emit(PimInstruction::unary(Opcode::Set, d, d), cat);
                } else if value > max {
                    self.emit(PimInstruction::unary(Opcode::Reset, d, d), cat);
                } else {
                    self.emit(mk(Opcode::GtImm, value - 1), cat);
                }
            }
        }
        Ok(())
    }

    /// Group mask: base AND eq(attr, v) for each key part.
    fn group_mask(&mut self, base: usize, key: &GroupKey, dst: usize) -> Result<(), CompileError> {
        let d = ColRange::new(dst, 1);
        let mark = self.alloc.mark();
        let t = self.alloc.scratch(1, self.steps.len())?;
        let mut first = true;
        for &(attr, v) in key {
            let a = self.attr_range(attr)?;
            let target = if first { dst } else { t };
            self.lower_cmp_imm(a, CmpOp::Eq, v, target, OpCategory::Filter)?;
            if !first {
                self.emit(
                    PimInstruction::binary(Opcode::And, d, ColRange::new(t, 1), d),
                    OpCategory::Filter,
                );
            }
            first = false;
        }
        self.emit(
            PimInstruction::binary(
                Opcode::And,
                d,
                ColRange::new(base, 1),
                d,
            ),
            OpCategory::Filter,
        );
        self.alloc.release_to(mark);
        Ok(())
    }

    /// Zero-extend copy of `src` into a fresh `width`-column field:
    /// Reset(width) then Or(src, zero-broadcast) into the low bits.
    fn widen_copy(&mut self, src: ColRange, width: usize) -> Result<ColRange, CompileError> {
        debug_assert!(width >= src.len as usize);
        let at = self.alloc.scratch(width, self.steps.len())?;
        let dst = ColRange::new(at, width);
        self.emit(
            PimInstruction::unary(Opcode::Reset, dst, dst),
            OpCategory::Arith,
        );
        let zero = self.alloc.scratch(1, self.steps.len())?;
        let z = ColRange::new(zero, 1);
        self.emit(PimInstruction::unary(Opcode::Reset, z, z), OpCategory::Arith);
        self.emit(
            PimInstruction::binary(Opcode::Or, src, z, ColRange::new(at, src.len as usize)),
            OpCategory::Arith,
        );
        Ok(dst)
    }

    /// (scale - other) as a fresh field wide enough for `scale`.
    fn complement_field(&mut self, other: &str, scale: u64) -> Result<ColRange, CompileError> {
        let o = self.attr_range(other)?;
        let width = (64 - scale.leading_zeros() as usize).max(o.len as usize);
        let f = self.widen_copy(o, width)?;
        // NOT gives (2^w - 1 - x); AddImm of (scale - (2^w - 1)) mod 2^w
        // yields scale - x.
        self.emit(PimInstruction::unary(Opcode::Not, f, f), OpCategory::Arith);
        let modw = 1u64 << width;
        let imm = (scale + modw - (modw - 1)) % modw; // == scale+1 mod 2^w
        self.emit(
            PimInstruction::with_imm(Opcode::AddImm, f, f, imm),
            OpCategory::Arith,
        );
        Ok(f)
    }

    /// (scale + other) as a fresh field.
    fn sum_field(&mut self, other: &str, scale: u64) -> Result<ColRange, CompileError> {
        let o = self.attr_range(other)?;
        let width = (64 - scale.leading_zeros() as usize).max(o.len as usize) + 1;
        let f = self.widen_copy(o, width)?;
        self.emit(
            PimInstruction::with_imm(Opcode::AddImm, f, f, scale),
            OpCategory::Arith,
        );
        Ok(f)
    }

    /// Masked copy of an attribute: And(attr, mask-broadcast) into scratch.
    fn masked_attr(&mut self, attr: &str, mask: usize) -> Result<ColRange, CompileError> {
        let a = self.attr_range(attr)?;
        let at = self.alloc.scratch(a.len as usize, self.steps.len())?;
        let dst = ColRange::new(at, a.len as usize);
        self.emit(
            PimInstruction::binary(Opcode::And, a, ColRange::new(mask, 1), dst),
            OpCategory::Arith,
        );
        Ok(dst)
    }

    /// Lower a value expression masked by `mask`; returns the value columns
    /// (zero for non-selected rows) and their width.
    fn lower_masked_value(
        &mut self,
        e: &ValExpr,
        mask: usize,
    ) -> Result<(ColRange, usize), CompileError> {
        match e {
            ValExpr::Attr(a) => {
                let c = self.masked_attr(a, mask)?;
                Ok((c, c.len as usize))
            }
            ValExpr::One => {
                // the mask column itself is the per-row 0/1 value
                Ok((ColRange::new(mask, 1), 1))
            }
            ValExpr::MulAttrs(a, b) => {
                let ma = self.masked_attr(a, mask)?;
                let rb = self.attr_range(b)?;
                let w = ma.len as usize + rb.len as usize;
                let at = self.alloc.scratch(w, self.steps.len())?;
                let dst = ColRange::new(at, w);
                self.emit(
                    PimInstruction::binary(Opcode::Mul, ma, rb, dst),
                    OpCategory::Arith,
                );
                Ok((dst, w))
            }
            ValExpr::MulComplement { attr, scale, other } => {
                let f = self.complement_field(other, *scale)?;
                let ma = self.masked_attr(attr, mask)?;
                let w = ma.len as usize + f.len as usize;
                let at = self.alloc.scratch(w, self.steps.len())?;
                let dst = ColRange::new(at, w);
                self.emit(
                    PimInstruction::binary(Opcode::Mul, ma, f, dst),
                    OpCategory::Arith,
                );
                Ok((dst, w))
            }
            ValExpr::MulSum { attr, scale, other } => {
                let f = self.sum_field(other, *scale)?;
                let ma = self.masked_attr(attr, mask)?;
                let w = ma.len as usize + f.len as usize;
                let at = self.alloc.scratch(w, self.steps.len())?;
                let dst = ColRange::new(at, w);
                self.emit(
                    PimInstruction::binary(Opcode::Mul, ma, f, dst),
                    OpCategory::Arith,
                );
                Ok((dst, w))
            }
            ValExpr::MulComplementSum {
                attr,
                scale1,
                other1,
                scale2,
                other2,
            } => {
                let f1 = self.complement_field(other1, *scale1)?;
                let f2 = self.sum_field(other2, *scale2)?;
                let ma = self.masked_attr(attr, mask)?;
                let w1 = ma.len as usize + f1.len as usize;
                let t = ColRange::new(self.alloc.scratch(w1, self.steps.len())?, w1);
                self.emit(
                    PimInstruction::binary(Opcode::Mul, ma, f1, t),
                    OpCategory::Arith,
                );
                let w2 = w1 + f2.len as usize;
                let dst = ColRange::new(self.alloc.scratch(w2, self.steps.len())?, w2);
                self.emit(
                    PimInstruction::binary(Opcode::Mul, t, f2, dst),
                    OpCategory::Arith,
                );
                Ok((dst, w2))
            }
        }
    }

    /// MIN/MAX row adjustment (paper §4.2): non-selected rows are forced to
    /// the identity (all-ones for MIN via OR ~mask; zero for MAX via AND).
    fn lower_minmax_adjusted(
        &mut self,
        e: &ValExpr,
        mask: usize,
        kind: AggKind,
    ) -> Result<ColRange, CompileError> {
        if kind == AggKind::Max {
            let (cols, _) = self.lower_masked_value(e, mask)?;
            return Ok(cols);
        }
        // MIN: value OR broadcast(NOT mask)
        let (cols, _) = self.lower_masked_value(e, mask)?;
        if cols.start as usize == mask {
            // ValExpr::One returns the mask column itself; adjusting it in
            // place would corrupt the mask for every later aggregate. The
            // adjusted constant-1 column is mask | !mask == all-ones, so
            // materialize that directly in fresh scratch.
            let t = self.alloc.scratch(1, self.steps.len())?;
            let tr = ColRange::new(t, 1);
            self.emit(PimInstruction::unary(Opcode::Set, tr, tr), OpCategory::Arith);
            return Ok(tr);
        }
        let nm = self.alloc.scratch(1, self.steps.len())?;
        let n = ColRange::new(nm, 1);
        self.emit(
            PimInstruction::unary(Opcode::Not, ColRange::new(mask, 1), n),
            OpCategory::Arith,
        );
        self.emit(
            PimInstruction::binary(Opcode::Or, cols, n, cols),
            OpCategory::Arith,
        );
        Ok(cols)
    }

    fn emit_reduce(&mut self, op: Opcode, cols: ColRange) -> usize {
        let idx = self.n_reduces;
        // result lands at the start of fresh columns; width n+10 for sums
        self.emit(
            PimInstruction::unary(op, cols, cols),
            OpCategory::AggCol, // split col/row happens in accounting
        );
        self.n_reduces += 1;
        idx
    }

    /// COUNT: SUM-reduce the 1-bit mask column itself (paper §4.2).
    fn emit_reduce_count(&mut self, mask: usize) -> usize {
        self.emit_reduce(Opcode::ReduceSum, ColRange::new(mask, 1))
    }
}

/// One field of an INSERT row image: `(first column, bits, encoded
/// value)` in crossbar-column space.
pub type InsertField = (usize, usize, u64);

/// How a compiled DML statement executes.
#[derive(Clone, Debug)]
pub enum CompiledDmlOp {
    /// Row-wise host write of one encoded record into a free row
    /// (paper §3.1: the host writes PIM data with ordinary stores,
    /// flushing the written lines so they reach the media).
    Insert {
        /// Every attribute slot's `(start, bits, value)` — unlisted
        /// attributes write their encoded 0.
        fields: Vec<InsertField>,
        /// The VALID column (set to 1 on the target row).
        valid_col: usize,
        /// Bits one record occupies, including VALID (write volume).
        row_bits: usize,
    },
    /// Column-wise filter + in-place mutation over all crossbars
    /// (UPDATE / DELETE): the same PIM-request machinery queries use.
    Mask {
        /// The instruction stream (filter, then the mutation writes,
        /// then a column transform of the mask for affected-row
        /// read-out).
        steps: Vec<Step>,
        /// Column holding the filter mask (post valid-AND).
        mask_col: usize,
        /// Peak compute-area columns used.
        peak_inter_cells: usize,
        /// First compute-area column (for the post-run area clear).
        compute_base: usize,
        /// Whether the statement clears liveness (DELETE): the executor
        /// releases the selected rows in the relation's free-row map.
        deletes: bool,
    },
}

/// Compiled program of one DML statement.
#[derive(Clone, Debug)]
pub struct CompiledDml {
    /// The relation the statement mutates.
    pub rel: RelId,
    /// The executable form.
    pub op: CompiledDmlOp,
}

/// Compile one DML statement against its relation layout.
///
/// DELETE keeps the engine's **all-zero-dead-row invariant**: besides
/// clearing VALID, it zeroes the deleted rows' data columns (And with
/// the negated mask), so the optimizer's zero-row abstract
/// interpretation — which proves the valid-AND elidable for predicates
/// that reject all-zero rows — stays sound on mutated relations.
pub fn compile_dml(
    dml: &Dml,
    layout: &RelationLayout,
    xbar_cols: usize,
) -> Result<CompiledDml, CompileError> {
    match dml {
        Dml::Insert { rel, values } => {
            let mut fields: Vec<InsertField> = Vec::with_capacity(layout.slots.len());
            for slot in &layout.slots {
                fields.push((slot.start, slot.attr.bits, 0));
            }
            for (name, value) in values {
                let idx = layout
                    .slots
                    .iter()
                    .position(|s| s.attr.name == *name)
                    .ok_or_else(|| CompileError::NoSuchAttribute {
                        rel: *rel,
                        attr: name.to_string(),
                    })?;
                let bits = layout.slots[idx].attr.bits;
                check_dml_value(name, bits, *value)?;
                if values.iter().filter(|(n, _)| n == name).count() > 1 {
                    return Err(CompileError::DuplicateAttr {
                        rel: *rel,
                        attr: name.to_string(),
                    });
                }
                fields[idx].2 = *value;
            }
            Ok(CompiledDml {
                rel: *rel,
                op: CompiledDmlOp::Insert {
                    fields,
                    valid_col: layout.valid_col,
                    row_bits: layout.row_bits,
                },
            })
        }
        Dml::Update { rel, filter, sets } => {
            let (mut c, mask, nm) = dml_mask_program(filter, layout, xbar_cols)?;
            for (name, value) in sets {
                if sets.iter().filter(|(n, _)| n == name).count() > 1 {
                    return Err(CompileError::DuplicateAttr {
                        rel: *rel,
                        attr: name.to_string(),
                    });
                }
                let slot = c
                    .layout
                    .slot(name)
                    .ok_or_else(|| CompileError::NoSuchAttribute {
                        rel: *rel,
                        attr: name.to_string(),
                    })?;
                check_dml_value(name, slot.attr.bits, *value)?;
                // rewrite the attribute on selected rows only: runs of
                // 1-bits OR in the mask, runs of 0-bits AND in NOT mask
                // (non-selected and dead rows keep their value)
                let mut b = 0;
                while b < slot.attr.bits {
                    let bit = (*value >> b) & 1;
                    let mut e = b + 1;
                    while e < slot.attr.bits && ((*value >> e) & 1) == bit {
                        e += 1;
                    }
                    let r = ColRange::new(slot.start + b, e - b);
                    let (op, m) = if bit == 1 {
                        (Opcode::Or, mask)
                    } else {
                        (Opcode::And, nm)
                    };
                    c.emit(
                        PimInstruction::binary(op, r, ColRange::new(m, 1), r),
                        OpCategory::Arith,
                    );
                    b = e;
                }
            }
            c.emit_mask_transform(mask);
            Ok(CompiledDml {
                rel: *rel,
                op: CompiledDmlOp::Mask {
                    steps: c.steps,
                    mask_col: mask,
                    peak_inter_cells: c.alloc.peak,
                    compute_base: layout.compute_base,
                    deletes: false,
                },
            })
        }
        Dml::Delete { rel, filter } => {
            let (mut c, mask, nm) = dml_mask_program(filter, layout, xbar_cols)?;
            // zero the deleted rows' data columns (the all-zero-dead-row
            // invariant the loader establishes and valid-elide relies on)
            for slot in &layout.slots {
                let r = ColRange::new(slot.start, slot.attr.bits);
                c.emit(
                    PimInstruction::binary(Opcode::And, r, ColRange::new(nm, 1), r),
                    OpCategory::Arith,
                );
            }
            // clear VALID on the selected rows
            let v = ColRange::new(layout.valid_col, 1);
            c.emit(
                PimInstruction::binary(Opcode::And, v, ColRange::new(nm, 1), v),
                OpCategory::Arith,
            );
            c.emit_mask_transform(mask);
            Ok(CompiledDml {
                rel: *rel,
                op: CompiledDmlOp::Mask {
                    steps: c.steps,
                    mask_col: mask,
                    peak_inter_cells: c.alloc.peak,
                    compute_base: layout.compute_base,
                    deletes: true,
                },
            })
        }
    }
}

fn check_dml_value(attr: &str, bits: usize, value: u64) -> Result<(), CompileError> {
    if bits < 64 && value >= (1u64 << bits) {
        return Err(CompileError::ValueTooWide {
            attr: attr.to_string(),
            bits,
            value,
        });
    }
    Ok(())
}

/// Shared UPDATE/DELETE prologue: lower the filter into a persistent mask
/// column, AND it with VALID (only live rows mutate), and materialize the
/// negated mask for the keep-side writes. Returns the compiler with the
/// prologue emitted plus the `(mask, not_mask)` columns.
fn dml_mask_program<'a>(
    filter: &Pred,
    layout: &'a RelationLayout,
    xbar_cols: usize,
) -> Result<(Compiler<'a>, usize, usize), CompileError> {
    let mut c = Compiler {
        layout,
        alloc: ColAlloc::new(layout.compute_base, xbar_cols),
        steps: Vec::new(),
        n_reduces: 0,
    };
    let mask = c.alloc.persistent(1, 0)?;
    let mark = c.alloc.mark();
    c.lower_pred(filter, mask, OpCategory::Filter)?;
    c.alloc.release_to(mark);
    c.emit(
        PimInstruction::binary(
            Opcode::And,
            ColRange::new(mask, 1),
            ColRange::new(layout.valid_col, 1),
            ColRange::new(mask, 1),
        ),
        OpCategory::Filter,
    );
    let nm = c.alloc.persistent(1, c.steps.len())?;
    c.emit(
        PimInstruction::unary(Opcode::Not, ColRange::new(mask, 1), ColRange::new(nm, 1)),
        OpCategory::Filter,
    );
    Ok((c, mask, nm))
}

impl Compiler<'_> {
    /// Transform the mask column for row-oriented affected-row read-out
    /// (the same read path filter-only queries use).
    fn emit_mask_transform(&mut self, mask: usize) {
        self.emit(
            PimInstruction::unary(
                Opcode::ColumnTransform,
                ColRange::new(mask, 1),
                ColRange::new(mask, 1),
            ),
            OpCategory::ColTransform,
        );
    }
}

/// Expand group_by attributes over their dictionary domains.
fn expand_groups(rq: &RelQuery) -> Vec<GroupKey> {
    if rq.group_by.is_empty() {
        return vec![vec![]];
    }
    let mut combos: Vec<GroupKey> = vec![vec![]];
    for &attr in &rq.group_by {
        let a = schema::attr(rq.rel, attr).expect("group attr");
        let domain = dict_domain(rq.rel, attr, a.bits);
        let mut next = Vec::new();
        for c in &combos {
            for &v in &domain {
                let mut c2 = c.clone();
                c2.push((attr, v));
                next.push(c2);
            }
        }
        combos = next;
    }
    combos
}

/// Dictionary domain sizes for group-by attributes.
fn dict_domain(rel: RelId, attr: &str, bits: usize) -> Vec<u64> {
    let n = match (rel, attr) {
        (RelId::Lineitem, "l_returnflag") => schema::RETURNFLAGS.len(),
        (RelId::Lineitem, "l_linestatus") => schema::LINESTATUS.len(),
        (RelId::Orders, "o_orderstatus") => schema::ORDERSTATUS.len(),
        (RelId::Customer, "c_mktsegment") => schema::SEGMENTS.len(),
        _ => 1 << bits.min(6),
    };
    (0..n as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::db::layout::DbLayout;
    use crate::query::tpch;

    fn layouts() -> (SystemConfig, DbLayout) {
        let cfg = SystemConfig::default();
        let l = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        (cfg, l)
    }

    fn compile_query(name: &str) -> Vec<CompiledRelQuery> {
        let (cfg, l) = layouts();
        let q = tpch::query(name).unwrap();
        q.rels
            .iter()
            .map(|rq| Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols).unwrap())
            .collect()
    }

    #[test]
    fn all_queries_compile() {
        let (cfg, l) = layouts();
        for q in tpch::all_queries() {
            for rq in &q.rels {
                let c = Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols)
                    .unwrap_or_else(|e| panic!("{} failed: {e}", q.name));
                assert!(!c.steps.is_empty());
                assert!(c.peak_inter_cells <= cfg.xbar_cols - l.rel(rq.rel).compute_base);
            }
        }
    }

    #[test]
    fn filter_only_ends_with_column_transform() {
        for c in compile_query("Q12") {
            assert_eq!(c.read, ReadKind::FilterMask);
            let last = c.steps.last().unwrap();
            assert_eq!(last.instr.op, Opcode::ColumnTransform);
            assert!(c.steps.iter().any(|s| s.category == OpCategory::Filter));
        }
    }

    #[test]
    fn q1_reduce_count_matches_groups_times_aggregates() {
        let c = &compile_query("Q1")[0];
        // 6 group combos (3 returnflag x 2 linestatus); per group: 1 count
        // reduce + 5 sum reduces (count_order reuses the count reduce)
        assert_eq!(c.groups.len(), 6);
        assert_eq!(c.n_reduces, 6 * 6);
        assert_eq!(c.outputs.len(), 6 * 6);
        match c.read {
            ReadKind::Aggregates { values, .. } => assert_eq!(values, 36),
            _ => panic!("expected aggregate read"),
        }
        // arithmetic instructions present (the revenue/charge products)
        assert!(c.steps.iter().any(|s| s.category == OpCategory::Arith));
        assert!(c
            .steps
            .iter()
            .any(|s| s.instr.op == Opcode::Mul));
    }

    #[test]
    fn q6_single_sum_reduce() {
        let c = &compile_query("Q6")[0];
        assert_eq!(c.n_reduces, 1);
        assert_eq!(c.groups.len(), 1);
        assert!(c.steps.iter().any(|s| s.instr.op == Opcode::Mul));
    }

    #[test]
    fn q22_avg_pairs_sum_with_count() {
        let c = &compile_query("Q22_sub")[0];
        assert_eq!(c.n_reduces, 2); // count + sum
        let avg = &c.outputs[0];
        assert_eq!(avg.kind, AggKind::Avg);
        assert!(avg.count_index.is_some());
    }

    #[test]
    fn in_set_emits_one_eq_per_value_plus_or() {
        let c = &compile_query("Q11")[0]; // single eq: nationkey = GERMANY
        let eq_count = c
            .steps
            .iter()
            .filter(|s| s.instr.op == Opcode::EqImm)
            .count();
        assert_eq!(eq_count, 1);
        let c5 = compile_query("Q5");
        // supplier filter: 5 ASIA nations -> 5 EqImm + 5 Or + reset
        let sup = &c5[0];
        assert_eq!(
            sup.steps
                .iter()
                .filter(|s| s.instr.op == Opcode::EqImm)
                .count(),
            5
        );
    }

    #[test]
    fn cmp_cols_uses_two_operand_lt() {
        let c = compile_query("Q4");
        let li = &c[1];
        assert!(li.steps.iter().any(|s| s.instr.op == Opcode::Lt
            && s.instr.src_b.is_some()));
    }

    /// Differential check at the immediate-width boundary: `p_size` is 6
    /// bits, so immediates above 63 can never match stored data. The engine
    /// truncates CmpImm immediates to the operand width (ISA contract), so
    /// the compiler must canonicalize wide immediates to constant Set/Reset
    /// masks — otherwise e.g. `p_size = 64` would alias to `p_size = 0`.
    #[test]
    fn cmp_imm_width_boundary_matches_scalar_semantics() {
        use crate::exec::engine::{exec_steps_native, XbarState};
        use crate::util::bits::XBAR_ROWS;

        let (cfg, l) = layouts();
        let lay = l.rel(RelId::Part);
        let slot = lay.slot("p_size").unwrap();
        let bits = slot.attr.bits;
        let max = (1u64 << bits) - 1;
        let scalar = |op: CmpOp, v: u64, imm: u64| match op {
            CmpOp::Eq => v == imm,
            CmpOp::Ne => v != imm,
            CmpOp::Lt => v < imm,
            CmpOp::Gt => v > imm,
            CmpOp::Le => v <= imm,
            CmpOp::Ge => v >= imm,
        };

        let ops = [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Gt, CmpOp::Le, CmpOp::Ge];
        let imms = [max - 1, max, max + 1, max + 2, u64::MAX];
        for op in ops {
            for imm in imms {
                let rq = RelQuery {
                    rel: RelId::Part,
                    filter: Pred::CmpImm {
                        attr: "p_size",
                        op,
                        value: imm,
                    },
                    group_by: vec![],
                    aggregates: vec![],
                };
                let c = Compiler::compile(&rq, lay, cfg.xbar_cols).unwrap();
                // no surviving CmpImm may carry an immediate the engine
                // would truncate (LtImm's exclusive bound may sit at max+1)
                for s in &c.steps {
                    let bound = if s.instr.op == Opcode::LtImm { max + 1 } else { max };
                    if matches!(
                        s.instr.op,
                        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm
                    ) {
                        assert!(
                            s.instr.imm <= bound,
                            "{op:?} {imm}: truncating imm {} survived",
                            s.instr.imm
                        );
                    }
                }
                // execute and compare the mask against scalar semantics
                let mut st = XbarState::new(cfg.xbar_cols);
                for row in 0..XBAR_ROWS {
                    let v = (row as u64) & max;
                    st.write_value(row, ColRange::new(slot.start, bits), v);
                    st.write_value(row, ColRange::new(lay.valid_col, 1), 1);
                }
                let mut states = [st];
                exec_steps_native(&mut states, &c.steps, c.mask_col);
                for row in 0..XBAR_ROWS {
                    let v = (row as u64) & max;
                    let got = states[0].value_at(row, ColRange::new(c.mask_col, 1)) == 1;
                    assert_eq!(got, scalar(op, v, imm), "{op:?} {imm} row {row} (v={v})");
                }
            }
        }
    }

    #[test]
    fn compile_errors_are_typed_and_render_stable_messages() {
        let (cfg, l) = layouts();
        let rq = RelQuery {
            rel: RelId::Part,
            filter: Pred::CmpImm {
                attr: "p_nonexistent",
                op: CmpOp::Eq,
                value: 1,
            },
            group_by: vec![],
            aggregates: vec![],
        };
        let err = Compiler::compile(&rq, l.rel(RelId::Part), cfg.xbar_cols).unwrap_err();
        assert!(matches!(err, CompileError::NoSuchAttribute { rel: RelId::Part, .. }));
        assert!(err.to_string().contains("no attribute p_nonexistent"));

        let rq = RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::CmpCols {
                a: "l_quantity",
                op: CmpOp::Lt,
                b: "l_extendedprice",
            },
            group_by: vec![],
            aggregates: vec![],
        };
        let err = Compiler::compile(&rq, l.rel(RelId::Lineitem), cfg.xbar_cols).unwrap_err();
        assert!(matches!(err, CompileError::CmpWidthMismatch { .. }));
        assert!(err.to_string().contains("widths differ"));

        // a tiny crossbar exhausts the compute area
        let rq = RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::True,
            group_by: vec![],
            aggregates: vec![Aggregate {
                kind: AggKind::Sum,
                expr: ValExpr::MulAttrs("l_extendedprice", "l_quantity"),
                label: "x",
            }],
        };
        let tiny = l.rel(RelId::Lineitem).compute_base + 2;
        let err = Compiler::compile(&rq, l.rel(RelId::Lineitem), tiny).unwrap_err();
        assert!(matches!(err, CompileError::ComputeAreaExhausted { .. }));
        assert!(err.to_string().contains("exhausted"), "{err}");
        // CompileError implements std::error::Error
        let _: &dyn std::error::Error = &err;
    }

    #[test]
    fn spans_metadata_covers_every_written_compute_column() {
        let (cfg, l) = layouts();
        for q in tpch::all_queries() {
            for rq in &q.rels {
                let c = Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols).unwrap();
                assert_eq!(c.compute_base, l.rel(rq.rel).compute_base);
                assert_eq!(c.valid_col, l.rel(rq.rel).valid_col);
                assert!(!c.spans.is_empty());
                // births are nondecreasing and within the step stream
                for w in c.spans.windows(2) {
                    assert!(w[0].born_step <= w[1].born_step);
                }
                for s in &c.spans {
                    assert!(s.born_step <= c.steps.len());
                    assert!(s.start >= c.compute_base);
                    assert!(s.width >= 1);
                }
                // every compute-area column a step writes lies in a span
                let covered = |col: usize| {
                    c.spans.iter().any(|s| col >= s.start && col < s.start + s.width)
                };
                for step in &c.steps {
                    let d = step.instr.dst;
                    for col in d.start as usize..d.end() {
                        if col >= c.compute_base {
                            assert!(covered(col), "{}: col {col} uncovered", q.name);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dml_delete_clears_valid_and_zeroes_data() {
        let (cfg, l) = layouts();
        let rl = l.rel(RelId::Supplier);
        let d = Dml::Delete {
            rel: RelId::Supplier,
            filter: Pred::CmpImm {
                attr: "s_suppkey",
                op: CmpOp::Le,
                value: 10,
            },
        };
        let c = compile_dml(&d, rl, cfg.xbar_cols).unwrap();
        let CompiledDmlOp::Mask {
            steps,
            mask_col,
            deletes,
            ..
        } = &c.op
        else {
            panic!("delete compiles to a mask program");
        };
        assert!(*deletes);
        assert!(*mask_col >= rl.compute_base);
        // one And per attribute slot (data zeroing) + one on VALID
        let ands_on_data = steps
            .iter()
            .filter(|s| {
                s.instr.op == Opcode::And
                    && (s.instr.dst.start as usize) < rl.valid_col
                    && s.category == OpCategory::Arith
            })
            .count();
        assert_eq!(ands_on_data, rl.slots.len());
        assert!(steps.iter().any(|s| s.instr.op == Opcode::And
            && s.instr.dst.start as usize == rl.valid_col));
        // the program ends with the affected-row mask transform
        assert_eq!(steps.last().unwrap().instr.op, Opcode::ColumnTransform);
        // and the filter mask is ANDed with VALID before any mutation
        let valid_and = steps
            .iter()
            .position(|s| {
                s.instr.op == Opcode::And
                    && s.instr.src_b == Some(ColRange::new(rl.valid_col, 1))
            })
            .expect("mask AND valid present");
        let first_mutation = steps
            .iter()
            .position(|s| (s.instr.dst.start as usize) < rl.row_bits)
            .expect("mutation writes exist");
        assert!(valid_and < first_mutation);
    }

    #[test]
    fn dml_update_rewrites_only_set_bit_runs() {
        let (cfg, l) = layouts();
        let rl = l.rel(RelId::Part);
        // p_size = 0b001101 (13): runs are 1(2 bits at 0? -> 13 = 0b001101)
        let d = Dml::Update {
            rel: RelId::Part,
            filter: Pred::True,
            sets: vec![("p_size", 13)],
        };
        let c = compile_dml(&d, rl, cfg.xbar_cols).unwrap();
        let CompiledDmlOp::Mask { steps, deletes, .. } = &c.op else {
            panic!("update compiles to a mask program");
        };
        assert!(!*deletes);
        let slot = rl.slot("p_size").unwrap();
        // 13 = 0b001101 over 6 bits: runs [1,0,11,00] -> Or, And, Or, And
        let writes: Vec<(Opcode, u16, u16)> = steps
            .iter()
            .filter(|s| {
                let d = s.instr.dst.start as usize;
                d >= slot.start && d < slot.start + slot.attr.bits
            })
            .map(|s| (s.instr.op, s.instr.dst.start, s.instr.dst.len))
            .collect();
        assert_eq!(
            writes,
            vec![
                (Opcode::Or, slot.start as u16, 1),
                (Opcode::And, slot.start as u16 + 1, 1),
                (Opcode::Or, slot.start as u16 + 2, 2),
                (Opcode::And, slot.start as u16 + 4, 2),
            ]
        );
    }

    #[test]
    fn dml_insert_compiles_full_row_image() {
        let (cfg, l) = layouts();
        let rl = l.rel(RelId::Supplier);
        let d = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_suppkey", 42), ("s_acctbal", 100_500)],
        };
        let c = compile_dml(&d, rl, cfg.xbar_cols).unwrap();
        let CompiledDmlOp::Insert {
            fields,
            valid_col,
            row_bits,
        } = &c.op
        else {
            panic!("insert compiles to a row image");
        };
        assert_eq!(*valid_col, rl.valid_col);
        assert_eq!(*row_bits, rl.row_bits);
        assert_eq!(fields.len(), rl.slots.len());
        let by_start: std::collections::BTreeMap<usize, u64> =
            fields.iter().map(|&(s, _, v)| (s, v)).collect();
        let key_slot = rl.slot("s_suppkey").unwrap();
        let bal_slot = rl.slot("s_acctbal").unwrap();
        assert_eq!(by_start[&key_slot.start], 42);
        assert_eq!(by_start[&bal_slot.start], 100_500);
        // unlisted attributes are zero
        let nk = rl.slot("s_nationkey").unwrap();
        assert_eq!(by_start[&nk.start], 0);
    }

    #[test]
    fn dml_compile_errors_are_typed() {
        let (cfg, l) = layouts();
        let rl = l.rel(RelId::Supplier);
        let bad_attr = Dml::Update {
            rel: RelId::Supplier,
            filter: Pred::True,
            sets: vec![("s_nope", 1)],
        };
        assert!(matches!(
            compile_dml(&bad_attr, rl, cfg.xbar_cols).unwrap_err(),
            CompileError::NoSuchAttribute { .. }
        ));
        let too_wide = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_nationkey", 32)], // 5 bits
        };
        let err = compile_dml(&too_wide, rl, cfg.xbar_cols).unwrap_err();
        assert!(matches!(err, CompileError::ValueTooWide { .. }));
        assert!(err.to_string().contains("does not fit"));
        let dup = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_nationkey", 1), ("s_nationkey", 2)],
        };
        let err = compile_dml(&dup, rl, cfg.xbar_cols).unwrap_err();
        assert!(matches!(err, CompileError::DuplicateAttr { .. }));
        assert!(err.to_string().contains("listed twice"));
    }

    #[test]
    fn step_display_renders_disassembly() {
        let s = Step {
            instr: PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(12, 24),
                ColRange::new(400, 1),
                42,
            ),
            category: OpCategory::Filter,
        };
        let line = s.to_string();
        assert!(line.contains("lt_imm"), "{line}");
        assert!(line.contains("[c12+24]"), "{line}");
        assert!(line.contains("#42"), "{line}");
        assert!(line.contains("-> [c400]"), "{line}");
        assert!(line.contains("; filter"), "{line}");
    }

    #[test]
    fn filter_cycles_in_paper_range() {
        // Table 5 filter cycles are O(100-700) for filter-only queries;
        // check ours land in a sane band
        use crate::pim::controller::cost;
        for name in ["Q2", "Q4", "Q12", "Q19"] {
            let total: u64 = compile_query(name)
                .iter()
                .flat_map(|c| &c.steps)
                .filter(|s| s.category == OpCategory::Filter)
                .map(|s| cost(&s.instr, 1024).total_cycles())
                .sum();
            assert!(
                (50..5000).contains(&total),
                "{name}: {total} filter cycles"
            );
        }
    }
}
