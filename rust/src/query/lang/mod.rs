//! PQL — the ad-hoc text query frontend (text → [`crate::query::ast`]).
//!
//! A small PRQL-inspired pipeline language that turns the engine from a
//! benchmark harness into a queryable system: any filter/aggregate the PIM
//! substrate supports can be written as a string and executed with
//! `pimdb run --sql "..."`, no Rust required. The hand-written lexer
//! ([`lexer`]), recursive-descent parser ([`parser`]) and schema-validating
//! lowering ([`lower`]) produce exactly the same [`crate::query::ast`]
//! values the hardcoded TPC-H definitions use — the `.pql` fixtures under
//! `rust/tests/pql/` re-express all 19 evaluated queries and are asserted
//! node-for-node equal to [`crate::query::tpch`].
//!
//! # Grammar
//!
//! ```text
//! program     := statement (';' statement)*
//! statement   := block | insert | update | delete
//! insert      := 'insert' 'into' TABLE '(' column (',' column)* ')'
//!                'values' '(' scalar (',' scalar)* ')'
//! update      := 'update' TABLE 'set' column '=' scalar
//!                (',' column '=' scalar)* ('where' pred)?
//! delete      := 'delete' 'from' TABLE ('where' pred)?
//! block       := ('query' NAME)? pipeline+
//! pipeline    := 'from' TABLE stage*
//! stage       := '|' ( 'filter' pred
//!                    | 'group' 'by'? column (',' column)*
//!                    | 'aggregate' agg (',' agg)* )      -- aggregate last
//! agg         := ('sum'|'count'|'min'|'max'|'avg') '(' vexpr? ')'
//!                ('as' LABEL)?
//! vexpr       := factor ('*' factor)*   -- shapes the PIM ALU computes:
//!                column | 1 | column '*' column
//!                | column '*' '(' INT ('+'|'-') column ')' [× again]
//! pred        := conj ('or' conj)*      -- 'or' binds loosest
//! conj        := unit ('and' unit)*
//! unit        := 'not' unit | '(' pred ')' | 'true' | comparison
//! comparison  := column OP scalar       -- OP: == != < <= > >=
//!              | column OP column       -- same width & encoding
//!              | column 'between' scalar '..' scalar    -- inclusive
//!              | column 'in' '(' scalar (',' scalar)* ')'
//!              | column 'in' 'region' '(' STRING ')'    -- nation keys
//!              | column 'like' STRING   -- '%'-pattern over a dictionary
//! scalar      := ['-'] base (('+'|'-') INT)*            -- const folding
//! base        := INT                    -- always the raw encoded value
//!              | DECIMAL                -- ×100: money cents / percent
//!              | STRING                 -- dictionary word -> id
//!              | 'date' '(' Y '-' M '-' D ')'           -- epoch days
//!              | 'nation' '(' STRING ')'                -- nation key
//! ```
//!
//! `#` starts a line comment; newlines are whitespace. A block with any
//! `aggregate` stage is a *full* query (filter and aggregation both run
//! in PIM); a block with none is *filter-only*, as in the paper.
//!
//! # Examples
//!
//! TPC-H Q6 as a one-liner (decimals scale to the stored hundredths, so
//! `0.05` means a 5% discount):
//!
//! ```
//! use pimdb::query::ast::QueryKind;
//! use pimdb::query::lang::parse_program;
//!
//! let queries = parse_program(
//!     "from lineitem
//!      | filter (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01))
//!          and l_discount between 0.05..0.07 and l_quantity < 24
//!      | aggregate sum(l_extendedprice * l_discount) as revenue_x100",
//! ).unwrap();
//! assert_eq!(queries.len(), 1);
//! assert_eq!(queries[0].kind, QueryKind::Full);
//! assert_eq!(queries[0].rels[0].aggregates[0].label, "revenue_x100");
//! ```
//!
//! Dictionary words, dates and DRAM-side dimension folds are encoded at
//! parse time against [`crate::db::schema`]:
//!
//! ```
//! use pimdb::query::ast::Pred;
//! use pimdb::query::lang::parse_program;
//!
//! let queries = parse_program(
//!     "query brass_eu
//!      from part | filter p_size == 15 and p_type like \"%BRASS\"
//!      from supplier | filter s_nationkey in region(\"EUROPE\")",
//! ).unwrap();
//! assert_eq!(queries[0].name, "brass_eu");
//! assert_eq!(queries[0].rels.len(), 2);
//! match &queries[0].rels[1].filter {
//!     Pred::InSet { attr, values } => {
//!         assert_eq!(*attr, "s_nationkey");
//!         assert_eq!(values.len(), 5); // five European nations
//!     }
//!     other => panic!("unexpected filter {other:?}"),
//! }
//! ```
//!
//! Errors carry the source span and render with a caret:
//!
//! ```
//! use pimdb::query::lang::parse_program;
//!
//! let src = "from lineitem | filter l_shipdat <= date(1998-09-02)";
//! let err = parse_program(src).unwrap_err();
//! assert!(err.msg.contains("unknown column 'l_shipdat'"));
//! assert!(err.render(src).contains("^^^^^^^^^"));
//! ```

pub mod lexer;
pub mod lower;
pub mod parser;
pub mod print;

use crate::query::ast::{Dml, Query, RelQuery, Statement};

/// A byte range in the source text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    /// First byte of the spanned region.
    pub start: usize,
    /// One past the last byte.
    pub end: usize,
}

impl Span {
    /// A span covering `start..end`.
    pub fn new(start: usize, end: usize) -> Span {
        Span { start, end }
    }

    /// The smallest span covering both `self` and `other`.
    pub fn join(self, other: Span) -> Span {
        Span {
            start: self.start.min(other.start),
            end: self.end.max(other.end),
        }
    }
}

/// A diagnostic: what went wrong and where in the source.
#[derive(Clone, Debug)]
pub struct Diag {
    /// Human-readable description of the problem.
    pub msg: String,
    /// Source location the message refers to.
    pub span: Span,
}

impl Diag {
    /// Build a diagnostic from a message and its location.
    pub fn new(msg: impl Into<String>, span: Span) -> Diag {
        Diag { msg: msg.into(), span }
    }

    /// Render the diagnostic against its source text: the message, the
    /// offending line, and a caret underline.
    ///
    /// ```text
    /// error: unknown column 'l_shipdat' on LINEITEM (available: ...)
    ///   1 | from lineitem | filter l_shipdat <= date(1998-09-02)
    ///     |                        ^^^^^^^^^
    /// ```
    pub fn render(&self, src: &str) -> String {
        let start = self.span.start.min(src.len());
        let line_start = src[..start].rfind('\n').map(|i| i + 1).unwrap_or(0);
        let line_end = src[start..]
            .find('\n')
            .map(|i| start + i)
            .unwrap_or(src.len());
        let line_no = src[..line_start].matches('\n').count() + 1;
        let line = &src[line_start..line_end];
        let col = start - line_start;
        let width = self
            .span
            .end
            .min(line_end)
            .saturating_sub(start)
            .max(1);
        let gutter = format!("{line_no}");
        let pad = " ".repeat(gutter.len());
        format!(
            "error: {}\n  {gutter} | {line}\n  {pad} | {}{}",
            self.msg,
            " ".repeat(col),
            "^".repeat(width),
        )
    }
}

/// Parse a PQL source text into executable queries.
///
/// Each `query` block becomes one [`Query`]; a headerless single block is
/// named `adhoc`. DML statements are rejected with a spanned diagnostic
/// (use [`parse_statements`] for the mixed form). The first error aborts
/// the parse — render it with [`Diag::render`] for a caret-annotated
/// message.
pub fn parse_program(src: &str) -> Result<Vec<Query>, Diag> {
    lower::lower_program(&parser::parse(src)?)
}

/// Parse a PQL source text into executable statements: `query` blocks
/// *and* DML statements (`insert into` / `update ... set` /
/// `delete from`), in source order.
///
/// ```
/// use pimdb::query::ast::{Dml, Statement};
/// use pimdb::query::lang::parse_statements;
///
/// let stmts = parse_statements(
///     "delete from lineitem where l_quantity < 2;
///      from lineitem | filter true | aggregate count() as n",
/// ).unwrap();
/// assert!(matches!(&stmts[0], Statement::Dml(Dml::Delete { .. })));
/// assert!(matches!(&stmts[1], Statement::Query(_)));
/// ```
pub fn parse_statements(src: &str) -> Result<Vec<Statement>, Diag> {
    lower::lower_statements(&parser::parse(src)?)
}

/// Parse a source text that must contain exactly one DML statement,
/// returning it (convenience for `execute_dml`-style callers).
pub fn parse_dml(src: &str) -> Result<Dml, Diag> {
    let mut stmts = parse_statements(src)?;
    if stmts.len() != 1 {
        return Err(Diag::new(
            format!("expected exactly one DML statement, got {}", stmts.len()),
            Span::new(0, src.len()),
        ));
    }
    match stmts.pop().expect("length checked above") {
        Statement::Dml(d) => Ok(d),
        Statement::Query(_) => Err(Diag::new(
            "expected a DML statement (insert/update/delete), got a query",
            Span::new(0, src.len()),
        )),
    }
}

/// Parse a source text that must contain exactly one single-relation
/// query, returning its [`RelQuery`] (convenience for tests and library
/// callers that drive [`crate::query::compiler`] directly).
pub fn parse_rel_query(src: &str) -> Result<RelQuery, Diag> {
    let mut queries = parse_program(src)?;
    if queries.len() != 1 || queries[0].rels.len() != 1 {
        return Err(Diag::new(
            "expected exactly one pipeline",
            Span::new(0, src.len()),
        ));
    }
    let mut query = queries.pop().expect("length checked above");
    Ok(query.rels.pop().expect("length checked above"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_points_at_the_column() {
        let src = "from lineitem\n| filter l_shipdat <= 5";
        let err = parse_program(src).unwrap_err();
        let rendered = err.render(src);
        assert!(rendered.contains("error: unknown column"), "{rendered}");
        assert!(rendered.contains("2 | | filter l_shipdat <= 5"), "{rendered}");
        assert!(rendered.contains("^^^^^^^^^"), "{rendered}");
    }

    #[test]
    fn render_survives_eof_spans() {
        let src = "from lineitem | filter l_quantity <";
        let err = parse_program(src).unwrap_err();
        let rendered = err.render(src);
        assert!(rendered.contains("error:"), "{rendered}");
    }

    #[test]
    fn parse_rel_query_accepts_only_single_pipelines() {
        assert!(parse_rel_query("from supplier | filter s_suppkey < 10").is_ok());
        assert!(parse_rel_query(
            "from supplier | filter true from part | filter true"
        )
        .is_err());
    }

    #[test]
    fn span_join() {
        let j = Span::new(3, 5).join(Span::new(10, 12));
        assert_eq!((j.start, j.end), (3, 12));
    }
}
