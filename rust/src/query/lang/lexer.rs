//! Hand-written lexer for the PQL pipeline language.
//!
//! Produces a flat token stream with byte-offset [`Span`]s. Newlines are
//! plain whitespace (pipelines may span lines); `#` starts a line comment.
//! Decimal literals are scaled to hundredths at lex time (`0.05` → 5,
//! `912.34` → 91234) because every fractional domain in the schema —
//! money in cents, discount/tax in percent — is stored ×100.

use super::{Diag, Span};

/// One lexical token kind.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`from`, `filter`, `l_shipdate`, ...).
    Ident(String),
    /// Unsigned integer literal (underscores allowed: `100_000`).
    Int(u64),
    /// Decimal literal with at most two fractional digits, scaled ×100.
    Decimal(u64),
    /// Double-quoted string literal (no escapes).
    Str(String),
    /// `|`
    Pipe,
    /// `,`
    Comma,
    /// `;`
    Semi,
    /// `(`
    LParen,
    /// `)`
    RParen,
    /// `==`
    EqEq,
    /// `=` (UPDATE `set col = value` assignments; not a comparison).
    Assign,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
    /// `..`
    DotDot,
    /// `+`
    Plus,
    /// `-`
    Minus,
    /// `*`
    Star,
}

/// A token with its source span.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    /// The token kind and payload.
    pub tok: Tok,
    /// Byte range in the source text.
    pub span: Span,
}

/// Tokenize `src`; the first lexical error aborts with a spanned [`Diag`].
pub fn lex(src: &str) -> Result<Vec<Token>, Diag> {
    let bytes = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        match b {
            b' ' | b'\t' | b'\r' | b'\n' => {
                i += 1;
            }
            b'#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'|' => {
                out.push(Token { tok: Tok::Pipe, span: Span::new(i, i + 1) });
                i += 1;
            }
            b',' => {
                out.push(Token { tok: Tok::Comma, span: Span::new(i, i + 1) });
                i += 1;
            }
            b';' => {
                out.push(Token { tok: Tok::Semi, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'(' => {
                out.push(Token { tok: Tok::LParen, span: Span::new(i, i + 1) });
                i += 1;
            }
            b')' => {
                out.push(Token { tok: Tok::RParen, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'+' => {
                out.push(Token { tok: Tok::Plus, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'-' => {
                out.push(Token { tok: Tok::Minus, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'*' => {
                out.push(Token { tok: Tok::Star, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'=' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token { tok: Tok::EqEq, span: Span::new(i, i + 2) });
                    i += 2;
                } else {
                    // single '=' is the UPDATE `set col = value` assignment;
                    // the parser rejects it in comparison position with a
                    // pointed "use '=='" diagnostic
                    out.push(Token { tok: Tok::Assign, span: Span::new(i, i + 1) });
                    i += 1;
                }
            }
            b'!' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token { tok: Tok::Ne, span: Span::new(i, i + 2) });
                    i += 2;
                } else {
                    return Err(Diag::new(
                        "expected '!=' (use 'not' for negation)",
                        Span::new(i, i + 1),
                    ));
                }
            }
            b'<' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token { tok: Tok::Le, span: Span::new(i, i + 2) });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Lt, span: Span::new(i, i + 1) });
                    i += 1;
                }
            }
            b'>' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    out.push(Token { tok: Tok::Ge, span: Span::new(i, i + 2) });
                    i += 2;
                } else {
                    out.push(Token { tok: Tok::Gt, span: Span::new(i, i + 1) });
                    i += 1;
                }
            }
            b'.' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'.' {
                    out.push(Token { tok: Tok::DotDot, span: Span::new(i, i + 2) });
                    i += 2;
                } else {
                    return Err(Diag::new(
                        "unexpected '.' (ranges are written 'lo..hi')",
                        Span::new(i, i + 1),
                    ));
                }
            }
            b'"' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                loop {
                    if i >= bytes.len() || bytes[i] == b'\n' {
                        return Err(Diag::new(
                            "unterminated string literal",
                            Span::new(start, i),
                        ));
                    }
                    if bytes[i] == b'"' {
                        i += 1;
                        break;
                    }
                    if bytes[i] >= 0x80 {
                        return Err(Diag::new(
                            "string literals are ASCII-only",
                            Span::new(i, i + 1),
                        ));
                    }
                    s.push(bytes[i] as char);
                    i += 1;
                }
                out.push(Token { tok: Tok::Str(s), span: Span::new(start, i) });
            }
            b'0'..=b'9' => {
                let start = i;
                let mut int_part: u64 = 0;
                while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == b'_') {
                    if bytes[i] != b'_' {
                        int_part = int_part
                            .checked_mul(10)
                            .and_then(|v| v.checked_add((bytes[i] - b'0') as u64))
                            .ok_or_else(|| {
                                Diag::new("integer literal overflows u64", Span::new(start, i + 1))
                            })?;
                    }
                    i += 1;
                }
                // a '.' followed by a digit is a decimal literal; '..' is a
                // range operator and belongs to the next token
                if i + 1 < bytes.len() && bytes[i] == b'.' && bytes[i + 1].is_ascii_digit() {
                    i += 1;
                    let frac_start = i;
                    while i < bytes.len() && bytes[i].is_ascii_digit() {
                        i += 1;
                    }
                    let digits = i - frac_start;
                    if digits > 2 {
                        return Err(Diag::new(
                            "decimal literals carry at most two fractional digits \
                             (values are stored in hundredths)",
                            Span::new(start, i),
                        ));
                    }
                    let mut frac: u64 = 0;
                    for &b in &bytes[frac_start..i] {
                        frac = frac * 10 + (b - b'0') as u64;
                    }
                    if digits == 1 {
                        frac *= 10;
                    }
                    let cents = int_part
                        .checked_mul(100)
                        .and_then(|v| v.checked_add(frac))
                        .ok_or_else(|| {
                            Diag::new("decimal literal overflows u64", Span::new(start, i))
                        })?;
                    out.push(Token { tok: Tok::Decimal(cents), span: Span::new(start, i) });
                } else {
                    out.push(Token { tok: Tok::Int(int_part), span: Span::new(start, i) });
                }
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                let s = src[start..i].to_string();
                out.push(Token { tok: Tok::Ident(s), span: Span::new(start, i) });
            }
            other => {
                return Err(Diag::new(
                    format!("unexpected character '{}'", other as char),
                    Span::new(i, i + 1),
                ));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_pipeline_tokens() {
        assert_eq!(
            kinds("from lineitem | filter l_quantity < 24"),
            vec![
                Tok::Ident("from".into()),
                Tok::Ident("lineitem".into()),
                Tok::Pipe,
                Tok::Ident("filter".into()),
                Tok::Ident("l_quantity".into()),
                Tok::Lt,
                Tok::Int(24),
            ]
        );
    }

    #[test]
    fn decimal_scales_to_hundredths() {
        assert_eq!(kinds("0.05"), vec![Tok::Decimal(5)]);
        assert_eq!(kinds("912.3"), vec![Tok::Decimal(91230)]);
        assert_eq!(kinds("1000.00"), vec![Tok::Decimal(100_000)]);
        assert!(lex("1.234").is_err());
    }

    #[test]
    fn range_is_not_a_decimal() {
        assert_eq!(
            kinds("between 5..7"),
            vec![Tok::Ident("between".into()), Tok::Int(5), Tok::DotDot, Tok::Int(7)]
        );
    }

    #[test]
    fn dates_lex_as_int_minus_int() {
        assert_eq!(
            kinds("date(1998-09-02)"),
            vec![
                Tok::Ident("date".into()),
                Tok::LParen,
                Tok::Int(1998),
                Tok::Minus,
                Tok::Int(9),
                Tok::Minus,
                Tok::Int(2),
                Tok::RParen,
            ]
        );
    }

    #[test]
    fn strings_comments_underscores() {
        assert_eq!(
            kinds("x == \"SAUDI ARABIA\" # trailing comment\n100_000"),
            vec![
                Tok::Ident("x".into()),
                Tok::EqEq,
                Tok::Str("SAUDI ARABIA".into()),
                Tok::Int(100_000),
            ]
        );
    }

    #[test]
    fn errors_carry_spans() {
        assert!(lex("\"open").is_err());
        assert!(lex("a $ b").is_err());
        assert!(lex("99999999999999999999").is_err());
    }

    #[test]
    fn single_equals_lexes_as_assign() {
        // '=' is the UPDATE assignment token (the parser rejects it in
        // comparison position with a pointed diagnostic)
        assert_eq!(
            kinds("set l_tax = 5"),
            vec![
                Tok::Ident("set".into()),
                Tok::Ident("l_tax".into()),
                Tok::Assign,
                Tok::Int(5),
            ]
        );
    }

    #[test]
    fn comparison_operators() {
        assert_eq!(
            kinds("== != < <= > >="),
            vec![Tok::EqEq, Tok::Ne, Tok::Lt, Tok::Le, Tok::Gt, Tok::Ge]
        );
    }
}
