//! Pretty-printer: executable AST → PQL text.
//!
//! Inverse of parsing for the supported AST shapes: values are printed as
//! raw encoded integers (which the parser always accepts), compound
//! predicates are parenthesized exactly where the grammar needs them, so
//! `parse(print(q))` reproduces `q` node-for-node. This is what the
//! round-trip property test in `tests/prop_lang.rs` exercises.
//!
//! Only empty IN-sets (unconstructible from text) have no exact printed
//! form; they render as `not true`, which is equivalent but not
//! node-identical.

use crate::query::ast::{AggKind, Aggregate, CmpOp, Dml, Pred, Query, RelQuery, ValExpr};

/// Render a full query block (`query NAME` header plus its pipelines).
pub fn query_to_pql(q: &Query) -> String {
    let mut out = format!("query {}\n", q.name);
    for rq in &q.rels {
        out.push_str(&rel_query_to_pql(rq));
        out.push('\n');
    }
    out
}

/// Render one relation pipeline (`from rel | filter ... | ...`).
pub fn rel_query_to_pql(rq: &RelQuery) -> String {
    let mut out = format!("from {}", rq.rel.name().to_ascii_lowercase());
    out.push_str(" | filter ");
    out.push_str(&pred_to_pql(&rq.filter));
    if !rq.group_by.is_empty() {
        out.push_str(" | group by ");
        out.push_str(&rq.group_by.join(", "));
    }
    if !rq.aggregates.is_empty() {
        out.push_str(" | aggregate ");
        let aggs: Vec<String> = rq.aggregates.iter().map(agg_to_pql).collect();
        out.push_str(&aggs.join(", "));
    }
    out
}

/// Render a DML statement (`parse(print(d))` reproduces `d` node-for-node;
/// values print as raw encoded integers, and a [`Pred::True`] filter
/// prints as an explicit `where true`).
///
/// Like empty IN-sets on the query side, an INSERT with no values or an
/// UPDATE with no assignments (constructible from the AST, where they
/// mean an all-zero row / a pure row-count statement) has no textual
/// form — the grammar requires at least one column and one assignment —
/// so those two shapes do not round-trip.
pub fn dml_to_pql(d: &Dml) -> String {
    match d {
        Dml::Insert { rel, values } => {
            let cols: Vec<&str> = values.iter().map(|(n, _)| *n).collect();
            let vals: Vec<String> = values.iter().map(|(_, v)| v.to_string()).collect();
            format!(
                "insert into {} ({}) values ({})",
                rel.name().to_ascii_lowercase(),
                cols.join(", "),
                vals.join(", ")
            )
        }
        Dml::Update { rel, filter, sets } => {
            let assigns: Vec<String> =
                sets.iter().map(|(n, v)| format!("{n} = {v}")).collect();
            format!(
                "update {} set {} where {}",
                rel.name().to_ascii_lowercase(),
                assigns.join(", "),
                pred_to_pql(filter)
            )
        }
        Dml::Delete { rel, filter } => format!(
            "delete from {} where {}",
            rel.name().to_ascii_lowercase(),
            pred_to_pql(filter)
        ),
    }
}

/// Render a predicate tree with raw encoded values.
pub fn pred_to_pql(p: &Pred) -> String {
    match p {
        Pred::True => "true".into(),
        Pred::CmpImm { attr, op, value } => {
            format!("{attr} {} {value}", op_str(*op))
        }
        Pred::CmpCols { a, op, b } => format!("{a} {} {b}", op_str(*op)),
        Pred::Between { attr, lo, hi } => format!("{attr} between {lo}..{hi}"),
        Pred::InSet { attr, values } => {
            if values.is_empty() {
                // unconstructible from text; equivalent but not identical
                return "not true".into();
            }
            let items: Vec<String> = values.iter().map(|v| v.to_string()).collect();
            format!("{attr} in ({})", items.join(", "))
        }
        Pred::And(ps) => {
            let parts: Vec<String> = ps.iter().map(operand_to_pql).collect();
            parts.join(" and ")
        }
        Pred::Or(ps) => {
            let parts: Vec<String> = ps.iter().map(operand_to_pql).collect();
            parts.join(" or ")
        }
        Pred::Not(inner) => format!("not {}", operand_to_pql(inner)),
    }
}

/// An operand of and/or/not: compound children need parentheses to keep
/// their own grouping when re-parsed.
fn operand_to_pql(p: &Pred) -> String {
    match p {
        Pred::And(_) | Pred::Or(_) => format!("({})", pred_to_pql(p)),
        _ => pred_to_pql(p),
    }
}

fn op_str(op: CmpOp) -> &'static str {
    match op {
        CmpOp::Eq => "==",
        CmpOp::Ne => "!=",
        CmpOp::Lt => "<",
        CmpOp::Le => "<=",
        CmpOp::Gt => ">",
        CmpOp::Ge => ">=",
    }
}

fn agg_to_pql(a: &Aggregate) -> String {
    let kind = match a.kind {
        AggKind::Sum => "sum",
        AggKind::Count => "count",
        AggKind::Min => "min",
        AggKind::Max => "max",
        AggKind::Avg => "avg",
    };
    let body = if a.kind == AggKind::Count {
        String::new()
    } else {
        val_expr_to_pql(&a.expr)
    };
    format!("{kind}({body}) as {}", a.label)
}

fn val_expr_to_pql(e: &ValExpr) -> String {
    match e {
        ValExpr::Attr(a) => (*a).to_string(),
        ValExpr::One => "1".into(),
        ValExpr::MulAttrs(a, b) => format!("{a} * {b}"),
        ValExpr::MulComplement { attr, scale, other } => {
            format!("{attr} * ({scale} - {other})")
        }
        ValExpr::MulSum { attr, scale, other } => {
            format!("{attr} * ({scale} + {other})")
        }
        ValExpr::MulComplementSum { attr, scale1, other1, scale2, other2 } => {
            format!("{attr} * ({scale1} - {other1}) * ({scale2} + {other2})")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse_program;
    use super::*;
    use crate::db::schema::RelId;

    fn roundtrip(rq: &RelQuery) {
        let text = rel_query_to_pql(rq);
        let qs = parse_program(&text)
            .unwrap_or_else(|e| panic!("re-parse of '{text}' failed: {}", e.msg));
        assert_eq!(qs.len(), 1);
        assert_eq!(&qs[0].rels[0], rq, "text was: {text}");
    }

    #[test]
    fn hardcoded_tpch_queries_roundtrip_through_text() {
        for q in crate::query::tpch::all_queries() {
            for rq in &q.rels {
                roundtrip(rq);
            }
        }
    }

    #[test]
    fn count_prints_without_argument() {
        let rq = RelQuery {
            rel: RelId::Supplier,
            filter: Pred::True,
            group_by: vec![],
            aggregates: vec![Aggregate {
                kind: AggKind::Count,
                expr: ValExpr::One,
                label: "n",
            }],
        };
        let text = rel_query_to_pql(&rq);
        assert!(text.contains("count() as n"), "{text}");
        roundtrip(&rq);
    }

    #[test]
    fn dml_statements_roundtrip_through_text() {
        use crate::query::lang::parse_dml;
        let cases = vec![
            Dml::Insert {
                rel: RelId::Supplier,
                values: vec![("s_suppkey", 7777), ("s_nationkey", 3), ("s_acctbal", 100_500)],
            },
            Dml::Update {
                rel: RelId::Lineitem,
                filter: Pred::And(vec![
                    Pred::CmpImm { attr: "l_quantity", op: CmpOp::Lt, value: 5 },
                    Pred::Between { attr: "l_discount", lo: 2, hi: 9 },
                ]),
                sets: vec![("l_tax", 0), ("l_discount", 4)],
            },
            Dml::Update {
                rel: RelId::Part,
                filter: Pred::True,
                sets: vec![("p_size", 9)],
            },
            Dml::Delete {
                rel: RelId::Orders,
                filter: Pred::CmpImm {
                    attr: "o_orderstatus",
                    op: CmpOp::Eq,
                    value: 2,
                },
            },
            Dml::Delete { rel: RelId::Customer, filter: Pred::True },
        ];
        for d in cases {
            let text = dml_to_pql(&d);
            let back = parse_dml(&text)
                .unwrap_or_else(|e| panic!("re-parse of '{text}' failed: {}", e.msg));
            assert_eq!(back, d, "text was: {text}");
        }
    }

    #[test]
    fn nested_boolean_grouping_is_preserved() {
        let rq = RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::Not(Box::new(Pred::Or(vec![
                Pred::And(vec![
                    Pred::CmpImm { attr: "l_quantity", op: CmpOp::Lt, value: 5 },
                    Pred::True,
                ]),
                Pred::Between { attr: "l_discount", lo: 2, hi: 9 },
            ]))),
            group_by: vec![],
            aggregates: vec![],
        };
        roundtrip(&rq);
    }
}
