//! Recursive-descent parser: token stream → surface AST.
//!
//! The surface AST keeps every name and literal *unresolved* and tagged
//! with its source [`Span`]; all schema knowledge (does the column exist,
//! what encoding does it use, is the value in range) lives in
//! [`super::lower`]. Keywords are contextual: the parser matches plain
//! identifier text, so column names can never collide with keywords that
//! only appear in other positions.

use crate::query::ast::{AggKind, CmpOp};

use super::lexer::{lex, Tok, Token};
use super::{Diag, Span};

/// A parsed identifier with its span.
#[derive(Clone, Debug, PartialEq)]
pub struct SIdent {
    /// The identifier text as written.
    pub name: String,
    /// Source span of the identifier.
    pub span: Span,
}

/// An unresolved scalar literal: a base value plus `+ n` / `- n`
/// adjustments (`date(1998-12-01) - 90`).
#[derive(Clone, Debug, PartialEq)]
pub struct SScalar {
    /// The literal itself.
    pub kind: SScalarKind,
    /// Leading `-` on an `Int`/`Decimal` literal.
    pub neg: bool,
    /// Net adjustment from trailing `+ n` / `- n` terms.
    pub adjust: i64,
    /// Source span of the whole scalar expression.
    pub span: Span,
}

/// The base of a scalar literal before encoding.
#[derive(Clone, Debug, PartialEq)]
pub enum SScalarKind {
    /// Integer literal: always the raw encoded value.
    Int(u64),
    /// Decimal literal, scaled to hundredths by the lexer.
    Decimal(u64),
    /// String literal: a dictionary word, encoded per attribute.
    Str(String),
    /// `date(Y-M-D)`: days since the TPC-H epoch.
    Date {
        /// Calendar year.
        y: i64,
        /// Calendar month (1-12).
        m: i64,
        /// Calendar day (1-31).
        d: i64,
    },
    /// `nation("NAME")`: the TPC-H nation key.
    Nation(String),
}

/// Right-hand side of a comparison: literal or another column.
#[derive(Clone, Debug, PartialEq)]
pub enum SCmpRhs {
    /// Compare against a constant.
    Scalar(SScalar),
    /// Compare against another column of the same relation.
    Column(SIdent),
}

/// An unresolved filter predicate.
#[derive(Clone, Debug, PartialEq)]
pub enum SPred {
    /// `attr <op> rhs`
    Cmp {
        /// Left-hand column.
        attr: SIdent,
        /// Comparison operator.
        op: CmpOp,
        /// Constant or column right-hand side.
        rhs: SCmpRhs,
    },
    /// `attr between lo..hi` (inclusive on both ends).
    Between {
        /// The column.
        attr: SIdent,
        /// Lower bound.
        lo: SScalar,
        /// Upper bound.
        hi: SScalar,
    },
    /// `attr in (v, v, ...)`
    InList {
        /// The column.
        attr: SIdent,
        /// Set members, in written order.
        items: Vec<SScalar>,
    },
    /// `attr in region("NAME")`: nation keys of a TPC-H region.
    InRegion {
        /// The column (conventionally a `*_nationkey`).
        attr: SIdent,
        /// Region name literal.
        region: SIdent,
    },
    /// `attr like "PATTERN"`: dictionary-expanded to an IN-set.
    Like {
        /// The dictionary-encoded column.
        attr: SIdent,
        /// `%`-wildcard pattern.
        pattern: SIdent,
    },
    /// Conjunction (two or more operands).
    And(Vec<SPred>),
    /// Disjunction (two or more operands).
    Or(Vec<SPred>),
    /// Negation.
    Not(Box<SPred>),
    /// The `true` literal.
    True,
}

/// One factor of an aggregate value expression.
#[derive(Clone, Debug, PartialEq)]
pub enum SValFactor {
    /// A column.
    Attr(SIdent),
    /// A bare integer (only `1` is accepted by lowering).
    Int(u64, Span),
    /// `(scale - attr)` or `(scale + attr)`.
    ScaleOp {
        /// The constant term.
        scale: u64,
        /// `true` for `+`, `false` for `-`.
        plus: bool,
        /// The column term.
        attr: SIdent,
        /// Span of the parenthesized group.
        span: Span,
    },
}

/// An aggregate call: `sum(expr) as label`.
#[derive(Clone, Debug, PartialEq)]
pub struct SAgg {
    /// Which reduction.
    pub kind: AggKind,
    /// `*`-separated factors inside the call (empty for `count()`).
    pub factors: Vec<SValFactor>,
    /// Optional `as` label.
    pub label: Option<SIdent>,
    /// Span of the whole aggregate call.
    pub span: Span,
}

/// One `from <table> | ...` pipeline.
#[derive(Clone, Debug, PartialEq)]
pub struct SPipeline {
    /// Source relation name.
    pub table: SIdent,
    /// `filter` stages in order (multiple stages AND together).
    pub filters: Vec<SPred>,
    /// `group by` attributes (empty when absent).
    pub group_by: Vec<SIdent>,
    /// `aggregate` outputs (empty for filter-only pipelines).
    pub aggregates: Vec<SAgg>,
}

/// One query block: optional `query NAME` header plus its pipelines.
#[derive(Clone, Debug, PartialEq)]
pub struct SQueryBlock {
    /// The `query NAME` header, when present.
    pub name: Option<SIdent>,
    /// The block's pipelines (one per relation).
    pub pipelines: Vec<SPipeline>,
}

/// An unresolved DML statement (INSERT / UPDATE / DELETE).
#[derive(Clone, Debug, PartialEq)]
pub enum SDml {
    /// `insert into <table> (col, ...) values (scalar, ...)`
    Insert {
        /// Target table.
        table: SIdent,
        /// Column list, parallel to `values`.
        columns: Vec<SIdent>,
        /// Value list, parallel to `columns`.
        values: Vec<SScalar>,
    },
    /// `update <table> set col = scalar, ... [where pred]`
    Update {
        /// Target table.
        table: SIdent,
        /// `col = scalar` assignments in written order.
        sets: Vec<(SIdent, SScalar)>,
        /// The `where` predicate, when present.
        filter: Option<SPred>,
    },
    /// `delete from <table> [where pred]`
    Delete {
        /// Target table.
        table: SIdent,
        /// The `where` predicate, when present.
        filter: Option<SPred>,
    },
}

/// One parsed statement: a query block or a DML statement.
#[derive(Clone, Debug, PartialEq)]
pub enum SStatement {
    /// A `from ...` query block (optionally `query NAME`-headed).
    Block(SQueryBlock),
    /// An INSERT / UPDATE / DELETE statement.
    Dml(SDml),
}

/// A whole source text: one or more statements.
#[derive(Clone, Debug, PartialEq)]
pub struct SProgram {
    /// The statements in source order.
    pub stmts: Vec<SStatement>,
}

impl SProgram {
    /// The `i`-th statement as a query block (test/convenience accessor;
    /// panics when it is a DML statement).
    pub fn block(&self, i: usize) -> &SQueryBlock {
        match &self.stmts[i] {
            SStatement::Block(b) => b,
            SStatement::Dml(d) => panic!("statement {i} is DML: {d:?}"),
        }
    }
}

/// Parse a full source text into its surface AST.
pub fn parse(src: &str) -> Result<SProgram, Diag> {
    let tokens = lex(src)?;
    let mut p = Parser { tokens, pos: 0, eof: src.len() };
    p.program()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
    eof: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos).map(|t| &t.tok)
    }

    fn peek2(&self) -> Option<&Tok> {
        self.tokens.get(self.pos + 1).map(|t| &t.tok)
    }

    fn span(&self) -> Span {
        self.tokens
            .get(self.pos)
            .map(|t| t.span)
            .unwrap_or(Span::new(self.eof, self.eof))
    }

    fn prev_span(&self) -> Span {
        if self.pos == 0 {
            Span::new(0, 0)
        } else {
            self.tokens[self.pos - 1].span
        }
    }

    fn bump(&mut self) -> Option<Token> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, Diag> {
        Err(Diag::new(msg, self.span()))
    }

    /// True when the next token is the identifier `kw`.
    fn at_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s == kw)
    }

    /// Consume the identifier `kw` if it is next.
    fn eat_kw(&mut self, kw: &str) -> bool {
        if self.at_kw(kw) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), Diag> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            self.err(format!("expected '{kw}'"))
        }
    }

    fn eat_tok(&mut self, t: &Tok) -> bool {
        if self.peek() == Some(t) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_tok(&mut self, t: &Tok, what: &str) -> Result<(), Diag> {
        if self.eat_tok(t) {
            Ok(())
        } else {
            self.err(format!("expected {what}"))
        }
    }

    fn ident(&mut self, what: &str) -> Result<SIdent, Diag> {
        match self.peek() {
            Some(Tok::Ident(_)) => {
                let t = self.bump().unwrap();
                let name = match t.tok {
                    Tok::Ident(s) => s,
                    _ => unreachable!(),
                };
                Ok(SIdent { name, span: t.span })
            }
            _ => self.err(format!("expected {what}")),
        }
    }

    fn int(&mut self, what: &str) -> Result<(u64, Span), Diag> {
        match self.peek() {
            Some(Tok::Int(_)) => {
                let t = self.bump().unwrap();
                let v = match t.tok {
                    Tok::Int(v) => v,
                    _ => unreachable!(),
                };
                Ok((v, t.span))
            }
            _ => self.err(format!("expected {what}")),
        }
    }

    // --- grammar ----------------------------------------------------------

    fn program(&mut self) -> Result<SProgram, Diag> {
        let mut stmts = Vec::new();
        while self.eat_tok(&Tok::Semi) {}
        while self.peek().is_some() {
            if self.at_kw("insert") || self.at_kw("update") || self.at_kw("delete") {
                stmts.push(SStatement::Dml(self.dml()?));
            } else {
                stmts.push(SStatement::Block(self.query_block()?));
            }
            while self.eat_tok(&Tok::Semi) {}
        }
        if stmts.is_empty() {
            return Err(Diag::new(
                "empty input: expected 'from <table> | ...', 'insert', \
                 'update' or 'delete'",
                Span::new(self.eof, self.eof),
            ));
        }
        Ok(SProgram { stmts })
    }

    /// One DML statement (the leading keyword is still unconsumed).
    fn dml(&mut self) -> Result<SDml, Diag> {
        if self.eat_kw("insert") {
            self.expect_kw("into")?;
            let table = self.ident("a table name after 'insert into'")?;
            self.expect_tok(&Tok::LParen, "'(' opening the column list")?;
            let mut columns = vec![self.ident("a column name")?];
            while self.eat_tok(&Tok::Comma) {
                columns.push(self.ident("a column name")?);
            }
            self.expect_tok(&Tok::RParen, "')' closing the column list")?;
            self.expect_kw("values")?;
            self.expect_tok(&Tok::LParen, "'(' opening the value list")?;
            let mut values = vec![self.scalar()?];
            while self.eat_tok(&Tok::Comma) {
                values.push(self.scalar()?);
            }
            self.expect_tok(&Tok::RParen, "')' closing the value list")?;
            return Ok(SDml::Insert { table, columns, values });
        }
        if self.eat_kw("update") {
            let table = self.ident("a table name after 'update'")?;
            self.expect_kw("set")?;
            let mut sets = Vec::new();
            loop {
                let col = self.ident("a column name in 'set'")?;
                self.expect_tok(&Tok::Assign, "'=' in the assignment")?;
                sets.push((col, self.scalar()?));
                if !self.eat_tok(&Tok::Comma) {
                    break;
                }
            }
            let filter = if self.eat_kw("where") {
                Some(self.pred()?)
            } else {
                None
            };
            return Ok(SDml::Update { table, sets, filter });
        }
        self.expect_kw("delete")?;
        self.expect_kw("from")?;
        let table = self.ident("a table name after 'delete from'")?;
        let filter = if self.eat_kw("where") {
            Some(self.pred()?)
        } else {
            None
        };
        Ok(SDml::Delete { table, filter })
    }

    fn query_block(&mut self) -> Result<SQueryBlock, Diag> {
        let name = if self.at_kw("query") {
            self.pos += 1;
            Some(self.ident("a query name after 'query'")?)
        } else {
            None
        };
        let mut pipelines = Vec::new();
        if !self.at_kw("from") {
            return self.err("expected 'from <table>'");
        }
        // consecutive `from` pipelines belong to this block; a ';' ends it
        // (program() starts the next block after the separator)
        while self.at_kw("from") {
            pipelines.push(self.pipeline()?);
        }
        Ok(SQueryBlock { name, pipelines })
    }

    fn pipeline(&mut self) -> Result<SPipeline, Diag> {
        self.expect_kw("from")?;
        let table = self.ident("a table name after 'from'")?;
        let mut filters = Vec::new();
        let mut group_by: Vec<SIdent> = Vec::new();
        let mut aggregates: Vec<SAgg> = Vec::new();
        while self.eat_tok(&Tok::Pipe) {
            if self.eat_kw("filter") {
                if !aggregates.is_empty() {
                    return Err(Diag::new(
                        "the aggregate stage must be last in a pipeline",
                        self.prev_span(),
                    ));
                }
                filters.push(self.pred()?);
            } else if self.at_kw("group") {
                let kw_span = self.span();
                self.pos += 1;
                self.eat_kw("by"); // optional sugar: 'group by'
                if !group_by.is_empty() {
                    return Err(Diag::new("duplicate group stage", kw_span));
                }
                if !aggregates.is_empty() {
                    return Err(Diag::new(
                        "the aggregate stage must be last in a pipeline",
                        kw_span,
                    ));
                }
                loop {
                    group_by.push(self.ident("a column name in 'group by'")?);
                    if !self.eat_tok(&Tok::Comma) {
                        break;
                    }
                }
            } else if self.at_kw("aggregate") {
                let kw_span = self.span();
                self.pos += 1;
                if !aggregates.is_empty() {
                    return Err(Diag::new("duplicate aggregate stage", kw_span));
                }
                loop {
                    aggregates.push(self.aggregate()?);
                    if !self.eat_tok(&Tok::Comma) {
                        break;
                    }
                }
            } else {
                return self.err(
                    "expected a stage: 'filter', 'group by' or 'aggregate'",
                );
            }
        }
        Ok(SPipeline { table, filters, group_by, aggregates })
    }

    fn aggregate(&mut self) -> Result<SAgg, Diag> {
        let start = self.span();
        let func = self.ident("an aggregate function (sum/count/min/max/avg)")?;
        let kind = match func.name.as_str() {
            "sum" => AggKind::Sum,
            "count" => AggKind::Count,
            "min" => AggKind::Min,
            "max" => AggKind::Max,
            "avg" => AggKind::Avg,
            other => {
                return Err(Diag::new(
                    format!("unknown aggregate function '{other}' \
                             (expected sum/count/min/max/avg)"),
                    func.span,
                ))
            }
        };
        self.expect_tok(&Tok::LParen, "'(' after the aggregate function")?;
        let mut factors = Vec::new();
        if kind == AggKind::Count {
            // count() or count(*)
            self.eat_tok(&Tok::Star);
        } else {
            loop {
                factors.push(self.val_factor()?);
                if !self.eat_tok(&Tok::Star) {
                    break;
                }
            }
        }
        self.expect_tok(&Tok::RParen, "')' closing the aggregate call")?;
        let label = if self.eat_kw("as") {
            Some(self.ident("a label after 'as'")?)
        } else {
            None
        };
        let end = self.prev_span();
        Ok(SAgg { kind, factors, label, span: start.join(end) })
    }

    fn val_factor(&mut self) -> Result<SValFactor, Diag> {
        match self.peek() {
            Some(Tok::Ident(_)) => Ok(SValFactor::Attr(self.ident("a column")?)),
            Some(Tok::Int(_)) => {
                let (v, span) = self.int("an integer")?;
                Ok(SValFactor::Int(v, span))
            }
            Some(Tok::LParen) => {
                let start = self.span();
                self.pos += 1;
                let (scale, _) = self.int("a constant scale, e.g. (100 - l_discount)")?;
                let plus = match self.peek() {
                    Some(Tok::Plus) => true,
                    Some(Tok::Minus) => false,
                    _ => return self.err("expected '+' or '-' in a scale term"),
                };
                self.pos += 1;
                let attr = self.ident("a column in the scale term")?;
                self.expect_tok(&Tok::RParen, "')' closing the scale term")?;
                let span = start.join(self.prev_span());
                Ok(SValFactor::ScaleOp { scale, plus, attr, span })
            }
            _ => self.err("expected a column, integer, or (scale ± column)"),
        }
    }

    // predicates: or_pred > and_pred > not_pred > primary
    fn pred(&mut self) -> Result<SPred, Diag> {
        let first = self.and_pred()?;
        if !self.at_kw("or") {
            return Ok(first);
        }
        let mut parts = vec![first];
        while self.eat_kw("or") {
            parts.push(self.and_pred()?);
        }
        Ok(SPred::Or(parts))
    }

    fn and_pred(&mut self) -> Result<SPred, Diag> {
        let first = self.not_pred()?;
        if !self.at_kw("and") {
            return Ok(first);
        }
        let mut parts = vec![first];
        while self.eat_kw("and") {
            parts.push(self.not_pred()?);
        }
        Ok(SPred::And(parts))
    }

    fn not_pred(&mut self) -> Result<SPred, Diag> {
        if self.eat_kw("not") {
            Ok(SPred::Not(Box::new(self.not_pred()?)))
        } else {
            self.primary_pred()
        }
    }

    fn primary_pred(&mut self) -> Result<SPred, Diag> {
        if self.eat_tok(&Tok::LParen) {
            let inner = self.pred()?;
            self.expect_tok(&Tok::RParen, "')' closing the group")?;
            return Ok(inner);
        }
        if self.eat_kw("true") {
            return Ok(SPred::True);
        }
        let attr = self.ident("a column name, '(' or 'true'")?;
        if self.eat_kw("between") {
            let lo = self.scalar()?;
            self.expect_tok(&Tok::DotDot, "'..' between the range bounds")?;
            let hi = self.scalar()?;
            return Ok(SPred::Between { attr, lo, hi });
        }
        if self.eat_kw("in") {
            if self.at_kw("region") {
                let _ = self.bump();
                self.expect_tok(&Tok::LParen, "'(' after 'region'")?;
                let region = self.str_lit("a region name string")?;
                self.expect_tok(&Tok::RParen, "')' closing 'region(..)'")?;
                return Ok(SPred::InRegion { attr, region });
            }
            self.expect_tok(&Tok::LParen, "'(' opening the IN-list")?;
            let mut items = vec![self.scalar()?];
            while self.eat_tok(&Tok::Comma) {
                items.push(self.scalar()?);
            }
            self.expect_tok(&Tok::RParen, "')' closing the IN-list")?;
            return Ok(SPred::InList { attr, items });
        }
        if self.eat_kw("like") {
            let pattern = self.str_lit("a '%'-pattern string after 'like'")?;
            return Ok(SPred::Like { attr, pattern });
        }
        let op = match self.peek() {
            Some(Tok::EqEq) => CmpOp::Eq,
            Some(Tok::Ne) => CmpOp::Ne,
            Some(Tok::Lt) => CmpOp::Lt,
            Some(Tok::Le) => CmpOp::Le,
            Some(Tok::Gt) => CmpOp::Gt,
            Some(Tok::Ge) => CmpOp::Ge,
            Some(Tok::Assign) => {
                return self.err(
                    "'=' is the UPDATE assignment operator; comparisons \
                     are written '=='",
                )
            }
            _ => {
                return self.err(
                    "expected a comparison ('==', '!=', '<', '<=', '>', '>='), \
                     'between', 'in' or 'like'",
                )
            }
        };
        self.pos += 1;
        // a bare identifier on the right that is not a scalar function is a
        // column-column comparison
        let is_column_rhs = {
            let scalar_fn = matches!(
                self.peek(),
                Some(Tok::Ident(name)) if name == "date" || name == "nation"
            ) && self.peek2() == Some(&Tok::LParen);
            matches!(self.peek(), Some(Tok::Ident(_))) && !scalar_fn
        };
        let rhs = if is_column_rhs {
            SCmpRhs::Column(self.ident("a column")?)
        } else {
            SCmpRhs::Scalar(self.scalar()?)
        };
        Ok(SPred::Cmp { attr, op, rhs })
    }

    fn str_lit(&mut self, what: &str) -> Result<SIdent, Diag> {
        match self.peek() {
            Some(Tok::Str(_)) => {
                let t = self.bump().unwrap();
                let name = match t.tok {
                    Tok::Str(s) => s,
                    _ => unreachable!(),
                };
                Ok(SIdent { name, span: t.span })
            }
            _ => self.err(format!("expected {what}")),
        }
    }

    /// scalar := ['-'] base (('+'|'-') INT)*
    fn scalar(&mut self) -> Result<SScalar, Diag> {
        let start = self.span();
        let neg = self.eat_tok(&Tok::Minus);
        let kind = match self.peek().cloned() {
            Some(Tok::Int(v)) => {
                self.pos += 1;
                SScalarKind::Int(v)
            }
            Some(Tok::Decimal(c)) => {
                self.pos += 1;
                SScalarKind::Decimal(c)
            }
            Some(Tok::Str(_)) => {
                if neg {
                    return self.err("'-' cannot prefix a string literal");
                }
                let s = self.str_lit("a string")?;
                SScalarKind::Str(s.name)
            }
            Some(Tok::Ident(name)) if name == "date" => {
                if neg {
                    return self.err("'-' cannot prefix date(..)");
                }
                self.pos += 1;
                self.expect_tok(&Tok::LParen, "'(' after 'date'")?;
                let (y, _) = self.int("a year")?;
                self.expect_tok(&Tok::Minus, "'-' in the date")?;
                let (m, _) = self.int("a month")?;
                self.expect_tok(&Tok::Minus, "'-' in the date")?;
                let (d, _) = self.int("a day")?;
                self.expect_tok(&Tok::RParen, "')' closing 'date(..)'")?;
                SScalarKind::Date { y: y as i64, m: m as i64, d: d as i64 }
            }
            Some(Tok::Ident(name)) if name == "nation" => {
                if neg {
                    return self.err("'-' cannot prefix nation(..)");
                }
                self.pos += 1;
                self.expect_tok(&Tok::LParen, "'(' after 'nation'")?;
                let n = self.str_lit("a nation name string")?;
                self.expect_tok(&Tok::RParen, "')' closing 'nation(..)'")?;
                SScalarKind::Nation(n.name)
            }
            _ => {
                return self.err(
                    "expected a literal: integer, decimal, string, \
                     date(Y-M-D) or nation(\"NAME\")",
                )
            }
        };
        // constant adjustments: date(1998-12-01) - 90
        let mut adjust: i64 = 0;
        loop {
            let positive = match self.peek() {
                Some(Tok::Plus) => true,
                // '- INT' is an adjustment; '- ident' would be a new token
                // sequence the caller handles (never valid after a scalar)
                Some(Tok::Minus) => false,
                _ => break,
            };
            // only consume when an integer follows: 'x - 90' adjusts, but a
            // stray '-' without an int is a syntax error here
            if !matches!(self.peek2(), Some(Tok::Int(_))) {
                break;
            }
            self.pos += 1;
            let (v, vspan) = self.int("an integer adjustment")?;
            let v = i64::try_from(v)
                .map_err(|_| Diag::new("adjustment overflows i64", vspan))?;
            adjust = adjust
                .checked_add(if positive { v } else { -v })
                .ok_or_else(|| Diag::new("adjustment overflows i64", vspan))?;
        }
        let span = start.join(self.prev_span());
        Ok(SScalar { kind, neg, adjust, span })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_pipeline() {
        let p = parse("from lineitem | filter l_quantity < 24").unwrap();
        assert_eq!(p.stmts.len(), 1);
        let pl = &p.block(0).pipelines[0];
        assert_eq!(pl.table.name, "lineitem");
        assert_eq!(pl.filters.len(), 1);
        match &pl.filters[0] {
            SPred::Cmp { attr, op, rhs } => {
                assert_eq!(attr.name, "l_quantity");
                assert_eq!(*op, CmpOp::Lt);
                assert!(matches!(
                    rhs,
                    SCmpRhs::Scalar(SScalar { kind: SScalarKind::Int(24), .. })
                ));
            }
            other => panic!("unexpected predicate {other:?}"),
        }
    }

    #[test]
    fn and_or_nesting_follows_parens() {
        let p = parse(
            "from lineitem | filter (a >= 1 and a < 2) and b between 5..7 and c < 24",
        )
        .unwrap();
        match &p.block(0).pipelines[0].filters[0] {
            SPred::And(parts) => {
                assert_eq!(parts.len(), 3);
                assert!(matches!(&parts[0], SPred::And(inner) if inner.len() == 2));
                assert!(matches!(&parts[1], SPred::Between { .. }));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn or_of_ands() {
        let p = parse("from part | filter (a == 1 and b == 2) or (a == 3 and b == 4)")
            .unwrap();
        match &p.block(0).pipelines[0].filters[0] {
            SPred::Or(parts) => {
                assert_eq!(parts.len(), 2);
                assert!(parts.iter().all(|q| matches!(q, SPred::And(_))));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn column_column_comparison() {
        let p = parse("from lineitem | filter l_commitdate < l_receiptdate").unwrap();
        match &p.block(0).pipelines[0].filters[0] {
            SPred::Cmp { rhs: SCmpRhs::Column(c), .. } => {
                assert_eq!(c.name, "l_receiptdate")
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn date_adjustment_and_in_region() {
        let p = parse(
            "from orders | filter o_orderdate <= date(1998-12-01) - 90 \
             from supplier | filter s_nationkey in region(\"EUROPE\")",
        )
        .unwrap();
        assert_eq!(p.block(0).pipelines.len(), 2);
        match &p.block(0).pipelines[0].filters[0] {
            SPred::Cmp { rhs: SCmpRhs::Scalar(s), .. } => {
                assert_eq!(s.adjust, -90);
                assert!(matches!(s.kind, SScalarKind::Date { y: 1998, m: 12, d: 1 }));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(matches!(
            &p.block(0).pipelines[1].filters[0],
            SPred::InRegion { .. }
        ));
    }

    #[test]
    fn aggregates_group_by_and_labels() {
        let p = parse(
            "query Q1 from lineitem | filter true | group by l_returnflag, l_linestatus \
             | aggregate sum(l_extendedprice * (100 - l_discount)) as disc, count() as n",
        )
        .unwrap();
        let b = &p.block(0);
        assert_eq!(b.name.as_ref().unwrap().name, "Q1");
        let pl = &b.pipelines[0];
        assert_eq!(pl.group_by.len(), 2);
        assert_eq!(pl.aggregates.len(), 2);
        assert_eq!(pl.aggregates[0].kind, AggKind::Sum);
        assert_eq!(pl.aggregates[0].factors.len(), 2);
        assert!(matches!(
            &pl.aggregates[0].factors[1],
            SValFactor::ScaleOp { scale: 100, plus: false, .. }
        ));
        assert_eq!(pl.aggregates[1].kind, AggKind::Count);
        assert!(pl.aggregates[1].factors.is_empty());
        assert_eq!(pl.aggregates[1].label.as_ref().unwrap().name, "n");
    }

    #[test]
    fn multiple_blocks_and_semicolons() {
        let p = parse("query A from part | filter true; query B from orders | filter true")
            .unwrap();
        assert_eq!(p.stmts.len(), 2);
        assert_eq!(p.block(1).name.as_ref().unwrap().name, "B");
    }

    #[test]
    fn parses_dml_statements() {
        let p = parse("insert into supplier (s_suppkey, s_acctbal) values (7, -1.50)")
            .unwrap();
        match &p.stmts[0] {
            SStatement::Dml(SDml::Insert { table, columns, values }) => {
                assert_eq!(table.name, "supplier");
                assert_eq!(columns.len(), 2);
                assert_eq!(columns[1].name, "s_acctbal");
                assert!(values[1].neg);
                assert_eq!(values[1].kind, SScalarKind::Decimal(150));
            }
            other => panic!("unexpected {other:?}"),
        }
        let p = parse(
            "update lineitem set l_tax = 0, l_discount = 5 where l_quantity < 10",
        )
        .unwrap();
        match &p.stmts[0] {
            SStatement::Dml(SDml::Update { sets, filter, .. }) => {
                assert_eq!(sets.len(), 2);
                assert_eq!(sets[0].0.name, "l_tax");
                assert!(matches!(filter, Some(SPred::Cmp { .. })));
            }
            other => panic!("unexpected {other:?}"),
        }
        // DELETE without a where clause, and mixed DML + query programs
        let p = parse("delete from orders; from part | filter true").unwrap();
        assert!(matches!(
            &p.stmts[0],
            SStatement::Dml(SDml::Delete { filter: None, .. })
        ));
        assert!(matches!(&p.stmts[1], SStatement::Block(_)));
    }

    #[test]
    fn dml_parse_errors_are_pointed() {
        assert!(parse("insert into supplier s_suppkey values (1)").is_err());
        assert!(parse("insert into supplier (s_suppkey) values ()").is_err());
        assert!(parse("update supplier set = 5").is_err());
        assert!(parse("update supplier where s_suppkey == 1").is_err());
        assert!(parse("delete supplier").is_err());
        // '=' in comparison position points at '=='
        let e = parse("from supplier | filter s_suppkey = 5").unwrap_err();
        assert!(e.msg.contains("'=='"), "{}", e.msg);
    }

    #[test]
    fn error_spans_point_at_the_problem() {
        let e = parse("from lineitem | filter l_quantity <").unwrap_err();
        assert!(e.msg.contains("literal"));
        let e = parse("from lineitem | sort x").unwrap_err();
        assert!(e.msg.contains("stage"));
        assert!(parse("").is_err());
        assert!(parse("from lineitem | aggregate total(x)").is_err());
        assert!(parse("from lineitem | filter a == 1 | aggregate count() | filter b == 2").is_err());
    }

    #[test]
    fn negative_scalars() {
        let p = parse("from supplier | filter s_acctbal > -100.50").unwrap();
        match &p.block(0).pipelines[0].filters[0] {
            SPred::Cmp { rhs: SCmpRhs::Scalar(s), .. } => {
                assert!(s.neg);
                assert_eq!(s.kind, SScalarKind::Decimal(10050));
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
