//! Lowering: surface AST → the executable [`crate::query::ast`] types,
//! validated against the PIM schema.
//!
//! Every name is resolved to its `&'static str` in [`crate::db::schema`]
//! (so lowered queries compare equal to the hardcoded TPC-H definitions),
//! and every literal is encoded into the attribute's storage domain:
//! dictionary words to ids, `date(Y-M-D)` to epoch-day offsets, decimals
//! to hundredths (cents / percent) plus the money offset. All checks
//! produce span-carrying [`Diag`]s pointing at the offending token.

use crate::db::schema::{self, Attr, Encoding, RelId};
use crate::query::ast::{
    Aggregate, AggKind, Dml, Pred, Query, QueryKind, RelQuery, Statement, ValExpr,
};

use super::parser::{
    SAgg, SCmpRhs, SDml, SIdent, SPipeline, SPred, SProgram, SQueryBlock, SScalar,
    SScalarKind, SStatement, SValFactor,
};
use super::{Diag, Span};

/// Lower a parsed program to executable queries. DML statements are a
/// spanned error here — query-only callers ([`super::parse_program`])
/// cannot execute them; use [`lower_statements`] for the mixed form.
pub fn lower_program(prog: &SProgram) -> Result<Vec<Query>, Diag> {
    let single = prog.stmts.len() == 1;
    prog.stmts
        .iter()
        .enumerate()
        .map(|(i, s)| match s {
            SStatement::Block(b) => lower_block(b, i, single),
            SStatement::Dml(d) => Err(Diag::new(
                "DML statement in a query-only context (INSERT/UPDATE/\
                 DELETE execute via execute_dml / run --sql)",
                dml_table(d).span,
            )),
        })
        .collect()
}

/// Lower a parsed program to executable statements (queries and DML,
/// in source order).
pub fn lower_statements(prog: &SProgram) -> Result<Vec<Statement>, Diag> {
    let single = prog.stmts.len() == 1;
    prog.stmts
        .iter()
        .enumerate()
        .map(|(i, s)| match s {
            SStatement::Block(b) => Ok(Statement::Query(lower_block(b, i, single)?)),
            SStatement::Dml(d) => Ok(Statement::Dml(lower_dml(d)?)),
        })
        .collect()
}

fn dml_table(d: &SDml) -> &SIdent {
    match d {
        SDml::Insert { table, .. } | SDml::Update { table, .. } | SDml::Delete { table, .. } => {
            table
        }
    }
}

fn lower_dml(d: &SDml) -> Result<Dml, Diag> {
    match d {
        SDml::Insert { table, columns, values } => {
            let rel = resolve_rel(table)?;
            if columns.len() != values.len() {
                return Err(Diag::new(
                    format!(
                        "insert lists {} columns but {} values",
                        columns.len(),
                        values.len()
                    ),
                    table.span,
                ));
            }
            let mut out = Vec::new();
            for (c, v) in columns.iter().zip(values) {
                let a = resolve_attr(rel, c)?;
                if out.iter().any(|(n, _)| *n == a.name) {
                    return Err(Diag::new(
                        format!("duplicate insert column '{}'", a.name),
                        c.span,
                    ));
                }
                out.push((a.name, encode_scalar(a, v)?));
            }
            Ok(Dml::Insert { rel, values: out })
        }
        SDml::Update { table, sets, filter } => {
            let rel = resolve_rel(table)?;
            let mut lowered = Vec::new();
            for (c, v) in sets {
                let a = resolve_attr(rel, c)?;
                if lowered.iter().any(|(n, _)| *n == a.name) {
                    return Err(Diag::new(
                        format!("duplicate set column '{}'", a.name),
                        c.span,
                    ));
                }
                lowered.push((a.name, encode_scalar(a, v)?));
            }
            let filter = match filter {
                Some(p) => lower_pred(rel, p)?,
                None => Pred::True,
            };
            Ok(Dml::Update { rel, filter, sets: lowered })
        }
        SDml::Delete { table, filter } => {
            let rel = resolve_rel(table)?;
            let filter = match filter {
                Some(p) => lower_pred(rel, p)?,
                None => Pred::True,
            };
            Ok(Dml::Delete { rel, filter })
        }
    }
}

/// Intern a string as `&'static str` (the AST keeps static names). The
/// interner bounds leakage to *distinct* strings, so long-lived callers
/// parsing in a loop don't grow without bound.
fn leak(s: String) -> &'static str {
    use std::collections::HashSet;
    use std::sync::{Mutex, OnceLock};
    static INTERN: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let mut set = INTERN
        .get_or_init(|| Mutex::new(HashSet::new()))
        .lock()
        .expect("interner poisoned");
    if let Some(&existing) = set.get(s.as_str()) {
        return existing;
    }
    let leaked: &'static str = Box::leak(s.into_boxed_str());
    set.insert(leaked);
    leaked
}

fn lower_block(b: &SQueryBlock, index: usize, single: bool) -> Result<Query, Diag> {
    let rels: Vec<RelQuery> = b
        .pipelines
        .iter()
        .map(lower_pipeline)
        .collect::<Result<_, _>>()?;
    let with_aggs = rels.iter().filter(|r| !r.aggregates.is_empty()).count();
    let kind = if with_aggs == 0 {
        QueryKind::FilterOnly
    } else if with_aggs == rels.len() {
        QueryKind::Full
    } else {
        let bad = b
            .pipelines
            .iter()
            .zip(&rels)
            .find(|(_, r)| r.aggregates.is_empty())
            .map(|(p, _)| p.table.span)
            .unwrap_or(Span::new(0, 0));
        return Err(Diag::new(
            "all pipelines of one query must aggregate, or none: mixed \
             filter-only and aggregate pipelines cannot run as one query",
            bad,
        ));
    };
    let name: &'static str = match &b.name {
        Some(n) => leak(n.name.clone()),
        None if single => "adhoc",
        None => leak(format!("adhoc{}", index + 1)),
    };
    Ok(Query { name, kind, rels })
}

fn lower_pipeline(p: &SPipeline) -> Result<RelQuery, Diag> {
    let rel = resolve_rel(&p.table)?;
    let filter = match p.filters.len() {
        0 => Pred::True,
        1 => lower_pred(rel, &p.filters[0])?,
        _ => Pred::And(
            p.filters
                .iter()
                .map(|f| lower_pred(rel, f))
                .collect::<Result<_, _>>()?,
        ),
    };
    let mut group_by = Vec::new();
    for g in &p.group_by {
        let a = resolve_attr(rel, g)?;
        let small = a.bits <= 6;
        if !matches!(a.enc, Encoding::Dict) && !small {
            return Err(Diag::new(
                format!(
                    "'{}' cannot be a group key: group by needs a \
                     dictionary-encoded (or ≤6-bit) attribute",
                    g.name
                ),
                g.span,
            ));
        }
        group_by.push(a.name);
    }
    let aggregates: Vec<Aggregate> = p
        .aggregates
        .iter()
        .map(|a| lower_agg(rel, a))
        .collect::<Result<_, _>>()?;
    if !group_by.is_empty() && aggregates.is_empty() {
        return Err(Diag::new(
            "'group by' needs an aggregate stage after it",
            p.group_by[0].span,
        ));
    }
    Ok(RelQuery { rel, filter, group_by, aggregates })
}

fn resolve_rel(table: &SIdent) -> Result<RelId, Diag> {
    let rel = match table.name.to_ascii_uppercase().as_str() {
        "PART" => RelId::Part,
        "SUPPLIER" => RelId::Supplier,
        "PARTSUPP" => RelId::Partsupp,
        "CUSTOMER" => RelId::Customer,
        "ORDERS" => RelId::Orders,
        "LINEITEM" => RelId::Lineitem,
        "NATION" | "REGION" => {
            return Err(Diag::new(
                format!(
                    "{} is DRAM-resident, not a PIM relation; fold it into \
                     a key predicate via region(\"..\") or nation(\"..\")",
                    table.name.to_ascii_uppercase()
                ),
                table.span,
            ))
        }
        _ => {
            return Err(Diag::new(
                format!(
                    "unknown table '{}' (PIM relations: part, supplier, \
                     partsupp, customer, orders, lineitem)",
                    table.name
                ),
                table.span,
            ))
        }
    };
    Ok(rel)
}

fn resolve_attr(rel: RelId, ident: &SIdent) -> Result<&'static Attr, Diag> {
    schema::attrs(rel)
        .iter()
        .find(|a| a.name == ident.name)
        .ok_or_else(|| {
            let names: Vec<&str> = schema::attrs(rel).iter().map(|a| a.name).collect();
            Diag::new(
                format!(
                    "unknown column '{}' on {} (available: {})",
                    ident.name,
                    rel.name(),
                    names.join(", ")
                ),
                ident.span,
            )
        })
}

fn lower_pred(rel: RelId, p: &SPred) -> Result<Pred, Diag> {
    match p {
        SPred::True => Ok(Pred::True),
        SPred::Cmp { attr, op, rhs } => {
            let a = resolve_attr(rel, attr)?;
            match rhs {
                SCmpRhs::Column(bid) => {
                    let b = resolve_attr(rel, bid)?;
                    if std::mem::discriminant(&a.enc) != std::mem::discriminant(&b.enc) {
                        return Err(Diag::new(
                            format!(
                                "cannot compare '{}' ({:?}) with '{}' ({:?}): \
                                 encodings differ",
                                a.name, a.enc, b.name, b.enc
                            ),
                            bid.span,
                        ));
                    }
                    if a.bits != b.bits {
                        return Err(Diag::new(
                            format!(
                                "column compare needs equal widths: '{}' is \
                                 {} bits, '{}' is {} bits",
                                a.name, a.bits, b.name, b.bits
                            ),
                            bid.span,
                        ));
                    }
                    Ok(Pred::CmpCols { a: a.name, op: *op, b: b.name })
                }
                SCmpRhs::Scalar(s) => {
                    let value = encode_scalar(a, s)?;
                    Ok(Pred::CmpImm { attr: a.name, op: *op, value })
                }
            }
        }
        SPred::Between { attr, lo, hi } => {
            let a = resolve_attr(rel, attr)?;
            let lo_v = encode_scalar(a, lo)?;
            let hi_v = encode_scalar(a, hi)?;
            if lo_v > hi_v {
                return Err(Diag::new(
                    format!("empty range: {lo_v} > {hi_v} after encoding"),
                    lo.span.join(hi.span),
                ));
            }
            Ok(Pred::Between { attr: a.name, lo: lo_v, hi: hi_v })
        }
        SPred::InList { attr, items } => {
            let a = resolve_attr(rel, attr)?;
            let values = items
                .iter()
                .map(|s| encode_scalar(a, s))
                .collect::<Result<Vec<u64>, _>>()?;
            Ok(Pred::InSet { attr: a.name, values })
        }
        SPred::InRegion { attr, region } => {
            let a = resolve_attr(rel, attr)?;
            if !matches!(a.enc, Encoding::Uint) {
                return Err(Diag::new(
                    format!(
                        "region(..) produces nation keys; '{}' is not an \
                         integer-encoded column",
                        a.name
                    ),
                    attr.span,
                ));
            }
            if !schema::REGIONS.contains(&region.name.as_str()) {
                return Err(Diag::new(
                    format!(
                        "unknown region '{}' (expected one of {})",
                        region.name,
                        schema::REGIONS.join(", ")
                    ),
                    region.span,
                ));
            }
            let values = schema::nations_in_region(&region.name);
            for &v in &values {
                check_range(a, v as i128, region.span)?;
            }
            Ok(Pred::InSet { attr: a.name, values })
        }
        SPred::Like { attr, pattern } => {
            let a = resolve_attr(rel, attr)?;
            let vocab = vocab(a.name).ok_or_else(|| {
                Diag::new(
                    format!(
                        "'like' needs a dictionary-encoded column with a \
                         string vocabulary; '{}' has none",
                        a.name
                    ),
                    attr.span,
                )
            })?;
            let values: Vec<u64> = vocab
                .iter()
                .filter(|(w, _)| glob_match(&pattern.name, w))
                .map(|&(_, id)| id)
                .collect();
            if values.is_empty() {
                return Err(Diag::new(
                    format!(
                        "pattern '{}' matches nothing in the '{}' dictionary",
                        pattern.name, a.name
                    ),
                    pattern.span,
                ));
            }
            Ok(Pred::InSet { attr: a.name, values })
        }
        SPred::And(ps) => Ok(Pred::And(
            ps.iter()
                .map(|q| lower_pred(rel, q))
                .collect::<Result<_, _>>()?,
        )),
        SPred::Or(ps) => Ok(Pred::Or(
            ps.iter()
                .map(|q| lower_pred(rel, q))
                .collect::<Result<_, _>>()?,
        )),
        SPred::Not(q) => Ok(Pred::Not(Box::new(lower_pred(rel, q)?))),
    }
}

/// Encode one scalar literal into `attr`'s storage domain.
fn encode_scalar(attr: &Attr, s: &SScalar) -> Result<u64, Diag> {
    let base: i128 = match (&s.kind, attr.enc) {
        // a bare integer is always the raw encoded value
        (SScalarKind::Int(v), _) => {
            if s.neg {
                return Err(Diag::new(
                    "raw integer values are unsigned encoded values and \
                     cannot be negative; use a decimal for signed money",
                    s.span,
                ));
            }
            *v as i128
        }
        (SScalarKind::Decimal(c), Encoding::Money { offset }) => {
            let signed = if s.neg { -(*c as i128) } else { *c as i128 };
            signed + offset as i128
        }
        // percent-style fixed point (discount/tax are stored ×100)
        (SScalarKind::Decimal(c), Encoding::Uint) => {
            if s.neg {
                return Err(Diag::new(
                    format!("'{}' is unsigned; negative values cannot match", attr.name),
                    s.span,
                ));
            }
            *c as i128
        }
        (SScalarKind::Decimal(_), _) => {
            return Err(Diag::new(
                format!(
                    "decimal literal on '{}', which is {:?}-encoded \
                     (decimals fit money and percent columns)",
                    attr.name, attr.enc
                ),
                s.span,
            ))
        }
        (SScalarKind::Str(w), Encoding::Dict) => {
            let vocab = vocab(attr.name).ok_or_else(|| {
                Diag::new(
                    format!(
                        "'{}' has no string dictionary here; use the numeric id",
                        attr.name
                    ),
                    s.span,
                )
            })?;
            match vocab.iter().find(|(word, _)| word == w) {
                Some(&(_, id)) => id as i128,
                None => {
                    let mut sample: Vec<&str> =
                        vocab.iter().take(6).map(|(w, _)| w.as_str()).collect();
                    if vocab.len() > 6 {
                        sample.push("...");
                    }
                    return Err(Diag::new(
                        format!(
                            "'{}' is not in the '{}' dictionary (e.g. {})",
                            w,
                            attr.name,
                            sample.join(", ")
                        ),
                        s.span,
                    ));
                }
            }
        }
        (SScalarKind::Str(_), _) => {
            return Err(Diag::new(
                format!(
                    "string literal on '{}', which is {:?}-encoded, not a \
                     dictionary column",
                    attr.name, attr.enc
                ),
                s.span,
            ))
        }
        (SScalarKind::Date { y, m, d }, Encoding::Date) => {
            // the year cap keeps days_from_civil far from i64 overflow
            if !(1..=12).contains(m) || !(1..=31).contains(d) || *y > 9999 {
                return Err(Diag::new(
                    format!("invalid calendar date {y}-{m:02}-{d:02}"),
                    s.span,
                ));
            }
            if *y < schema::EPOCH.0 {
                return Err(Diag::new(
                    format!(
                        "date {y}-{m:02}-{d:02} is before the TPC-H epoch \
                         ({}-01-01)",
                        schema::EPOCH.0
                    ),
                    s.span,
                ));
            }
            schema::date(*y, *m, *d) as i128
        }
        (SScalarKind::Date { .. }, _) => {
            return Err(Diag::new(
                format!(
                    "date(..) literal on '{}', which is {:?}-encoded, not a \
                     date column",
                    attr.name, attr.enc
                ),
                s.span,
            ))
        }
        (SScalarKind::Nation(n), Encoding::Uint) => {
            match schema::NATIONS.iter().position(|&(name, _)| name == n) {
                Some(k) => k as i128,
                None => {
                    return Err(Diag::new(
                        format!("unknown nation '{n}'"),
                        s.span,
                    ))
                }
            }
        }
        (SScalarKind::Nation(_), _) => {
            return Err(Diag::new(
                format!(
                    "nation(..) produces a nation key; '{}' is not an \
                     integer-encoded column",
                    attr.name
                ),
                s.span,
            ))
        }
    };
    let v = base + s.adjust as i128;
    check_range(attr, v, s.span)?;
    Ok(v as u64)
}

fn check_range(attr: &Attr, v: i128, span: Span) -> Result<(), Diag> {
    if v < 0 {
        return Err(Diag::new(
            format!(
                "value encodes to {v}, below the unsigned storage domain \
                 of '{}'",
                attr.name
            ),
            span,
        ));
    }
    if attr.bits < 64 && v >= (1i128 << attr.bits) {
        return Err(Diag::new(
            format!(
                "value {v} does not fit '{}' ({} bits, max {})",
                attr.name,
                attr.bits,
                (1u64 << attr.bits) - 1
            ),
            span,
        ));
    }
    Ok(())
}

fn lower_agg(rel: RelId, a: &SAgg) -> Result<Aggregate, Diag> {
    let expr = if a.kind == AggKind::Count {
        ValExpr::One
    } else {
        lower_val_expr(rel, &a.factors, a.span)?
    };
    let label: &'static str = match &a.label {
        Some(l) => leak(l.name.clone()),
        None => default_label(a.kind, &expr),
    };
    Ok(Aggregate { kind: a.kind, expr, label })
}

fn default_label(kind: AggKind, expr: &ValExpr) -> &'static str {
    let kind_name = match kind {
        AggKind::Sum => "sum",
        AggKind::Count => "count",
        AggKind::Min => "min",
        AggKind::Max => "max",
        AggKind::Avg => "avg",
    };
    match expr {
        ValExpr::Attr(a) => leak(format!("{kind_name}_{a}")),
        _ => kind_name,
    }
}

/// A resolved aggregate factor.
enum Factor {
    Attr(&'static str),
    One,
    Scale { scale: u64, plus: bool, attr: &'static str },
}

fn lower_val_expr(
    rel: RelId,
    factors: &[SValFactor],
    span: Span,
) -> Result<ValExpr, Diag> {
    let mut resolved = Vec::new();
    for f in factors {
        match f {
            SValFactor::Attr(id) => {
                resolved.push(Factor::Attr(resolve_attr(rel, id)?.name));
            }
            SValFactor::Int(1, _) => resolved.push(Factor::One),
            SValFactor::Int(v, sp) => {
                return Err(Diag::new(
                    format!(
                        "bare integer factor must be 1 (counting); got {v}"
                    ),
                    *sp,
                ))
            }
            SValFactor::ScaleOp { scale, plus, attr, .. } => {
                resolved.push(Factor::Scale {
                    scale: *scale,
                    plus: *plus,
                    attr: resolve_attr(rel, attr)?.name,
                });
            }
        }
    }
    match resolved.as_slice() {
        [Factor::One] => Ok(ValExpr::One),
        [Factor::Attr(a)] => Ok(ValExpr::Attr(*a)),
        [Factor::Attr(a), Factor::Attr(b)] => Ok(ValExpr::MulAttrs(*a, *b)),
        [Factor::Attr(a), Factor::Scale { scale, plus: false, attr }] => {
            Ok(ValExpr::MulComplement { attr: *a, scale: *scale, other: *attr })
        }
        [Factor::Attr(a), Factor::Scale { scale, plus: true, attr }] => {
            Ok(ValExpr::MulSum { attr: *a, scale: *scale, other: *attr })
        }
        [Factor::Attr(a), Factor::Scale { scale: s1, plus: false, attr: o1 }, Factor::Scale { scale: s2, plus: true, attr: o2 }] => {
            Ok(ValExpr::MulComplementSum {
                attr: *a,
                scale1: *s1,
                other1: *o1,
                scale2: *s2,
                other2: *o2,
            })
        }
        _ => Err(Diag::new(
            "unsupported aggregate expression shape; the PIM arithmetic \
             units compute: attr, attr * attr, attr * (k - attr), \
             attr * (k + attr), attr * (k - a) * (k + b)",
            span,
        )),
    }
}

/// String dictionaries keyed by attribute name, ascending by id.
fn vocab(attr: &str) -> Option<Vec<(String, u64)>> {
    fn flat(words: &[&str]) -> Vec<(String, u64)> {
        words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.to_string(), i as u64))
            .collect()
    }
    match attr {
        "p_mfgr" => Some(
            (1..=5u64)
                .map(|i| (format!("Manufacturer#{i}"), i - 1))
                .collect(),
        ),
        "p_brand" => {
            let mut v = Vec::new();
            for m in 1..=5u64 {
                for n in 1..=5u64 {
                    v.push((format!("Brand#{m}{n}"), (m - 1) * 5 + (n - 1)));
                }
            }
            Some(v)
        }
        "p_type" => {
            let mut v = Vec::new();
            for (i1, s1) in schema::TYPE_S1.iter().enumerate() {
                for (i2, s2) in schema::TYPE_S2.iter().enumerate() {
                    for (i3, s3) in schema::TYPE_S3.iter().enumerate() {
                        v.push((
                            format!("{s1} {s2} {s3}"),
                            schema::type_id(i1, i2, i3),
                        ));
                    }
                }
            }
            Some(v)
        }
        "p_container" => {
            let mut v = Vec::new();
            for (i1, s1) in schema::CONTAINER_S1.iter().enumerate() {
                for (i2, s2) in schema::CONTAINER_S2.iter().enumerate() {
                    v.push((format!("{s1} {s2}"), (i1 * 8 + i2) as u64));
                }
            }
            Some(v)
        }
        "c_mktsegment" => Some(flat(&schema::SEGMENTS)),
        "o_orderstatus" => Some(flat(&schema::ORDERSTATUS)),
        "o_orderpriority" => Some(flat(&schema::PRIORITIES)),
        "l_returnflag" => Some(flat(&schema::RETURNFLAGS)),
        "l_linestatus" => Some(flat(&schema::LINESTATUS)),
        "l_shipmode" => Some(flat(&schema::SHIPMODES)),
        "l_shipinstruct" => Some(flat(&schema::INSTRUCTIONS)),
        _ => None,
    }
}

/// `%`-wildcard match ('%' spans any substring, no other metacharacters).
fn glob_match(pattern: &str, s: &str) -> bool {
    let parts: Vec<&str> = pattern.split('%').collect();
    if parts.len() == 1 {
        return pattern == s;
    }
    let first = parts[0];
    let last = parts[parts.len() - 1];
    if !s.starts_with(first) || !s.ends_with(last) {
        return false;
    }
    if s.len() < first.len() + last.len() {
        return false;
    }
    let mut pos = first.len();
    let end = s.len() - last.len();
    for part in &parts[1..parts.len() - 1] {
        if part.is_empty() {
            continue;
        }
        match s[pos..end].find(part) {
            Some(k) => pos += k + part.len(),
            None => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::super::parse_program;
    use super::*;
    use crate::query::ast::CmpOp;

    #[test]
    fn glob_matching() {
        assert!(glob_match("%BRASS", "STANDARD ANODIZED BRASS"));
        assert!(!glob_match("%BRASS", "STANDARD ANODIZED TIN"));
        assert!(glob_match("PROMO%", "PROMO ANODIZED TIN"));
        assert!(glob_match("MEDIUM POLISHED%", "MEDIUM POLISHED COPPER"));
        assert!(glob_match("A%C%E", "ABCDE"));
        assert!(!glob_match("A%C%E", "ACE_X"));
        assert!(glob_match("ACE", "ACE"));
        assert!(!glob_match("ACE", "ACES"));
        assert!(glob_match("%", "anything"));
    }

    #[test]
    fn vocab_ids_match_schema_encoders() {
        let brands = vocab("p_brand").unwrap();
        assert_eq!(brands.len(), 25);
        for (w, id) in &brands {
            assert_eq!(schema::brand_id(w), *id);
        }
        let types = vocab("p_type").unwrap();
        assert_eq!(types.len(), 150);
        for (w, id) in &types {
            assert_eq!(schema::type_id_of(w), *id);
        }
        let containers = vocab("p_container").unwrap();
        assert_eq!(containers.len(), 40);
        for (w, id) in &containers {
            assert_eq!(schema::container_id(w), *id);
        }
        for (w, id) in &vocab("l_shipmode").unwrap() {
            assert_eq!(schema::shipmode_id(w), *id);
        }
        assert!(vocab("c_phone_cc").is_none());
    }

    #[test]
    fn like_expansion_equals_schema_helpers() {
        let q = parse_program("from part | filter p_type like \"%BRASS\"").unwrap();
        match &q[0].rels[0].filter {
            Pred::InSet { attr, values } => {
                assert_eq!(*attr, "p_type");
                assert_eq!(*values, schema::type_ids_ending_with("BRASS"));
            }
            other => panic!("unexpected {other:?}"),
        }
        let q = parse_program("from part | filter p_type like \"PROMO%\"").unwrap();
        match &q[0].rels[0].filter {
            Pred::InSet { values, .. } => {
                assert_eq!(*values, schema::type_ids_starting_with("PROMO"));
            }
            other => panic!("unexpected {other:?}"),
        }
        let q =
            parse_program("from part | filter p_type like \"MEDIUM POLISHED%\"").unwrap();
        match &q[0].rels[0].filter {
            Pred::InSet { values, .. } => {
                assert_eq!(*values, schema::type_ids_with_prefix2("MEDIUM", "POLISHED"));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn money_and_percent_decimals_encode() {
        // c_acctbal carries a +100000 cent offset
        let q = parse_program("from customer | filter c_acctbal > 0.00").unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::CmpImm { attr: "c_acctbal", op: CmpOp::Gt, value: 100_000 }
        );
        // negative money stays in-domain thanks to the offset
        let q = parse_program("from customer | filter c_acctbal > -999.99").unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::CmpImm { attr: "c_acctbal", op: CmpOp::Gt, value: 1 }
        );
        // discount percent
        let q =
            parse_program("from lineitem | filter l_discount between 0.05..0.07").unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::Between { attr: "l_discount", lo: 5, hi: 7 }
        );
    }

    #[test]
    fn dates_region_and_nation_fold() {
        let q = parse_program(
            "from orders | filter o_orderdate < date(1995-03-15)",
        )
        .unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::CmpImm {
                attr: "o_orderdate",
                op: CmpOp::Lt,
                value: schema::date(1995, 3, 15)
            }
        );
        let q = parse_program(
            "from supplier | filter s_nationkey in region(\"EUROPE\")",
        )
        .unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::InSet {
                attr: "s_nationkey",
                values: schema::nations_in_region("EUROPE")
            }
        );
        let q = parse_program(
            "from supplier | filter s_nationkey == nation(\"GERMANY\")",
        )
        .unwrap();
        assert_eq!(
            q[0].rels[0].filter,
            Pred::CmpImm {
                attr: "s_nationkey",
                op: CmpOp::Eq,
                value: schema::nation_id("GERMANY")
            }
        );
    }

    #[test]
    fn kind_inference_and_names() {
        let q = parse_program("from supplier | filter s_suppkey < 10").unwrap();
        assert_eq!(q[0].kind, QueryKind::FilterOnly);
        assert_eq!(q[0].name, "adhoc");
        let q = parse_program(
            "from supplier | filter s_suppkey < 10 | aggregate count() as n",
        )
        .unwrap();
        assert_eq!(q[0].kind, QueryKind::Full);
        assert_eq!(q[0].rels[0].aggregates[0].label, "n");
        let q = parse_program(
            "query mine from supplier | aggregate avg(s_acctbal)",
        )
        .unwrap();
        assert_eq!(q[0].name, "mine");
        assert_eq!(q[0].rels[0].filter, Pred::True);
        assert_eq!(q[0].rels[0].aggregates[0].label, "avg_s_acctbal");
    }

    #[test]
    fn dml_lowering_encodes_and_validates() {
        use crate::query::lang::{parse_dml, parse_statements};
        use crate::query::ast::{Dml, Statement};
        let d = parse_dml(
            "update customer set c_acctbal = -1.00 where c_mktsegment == \"BUILDING\"",
        )
        .unwrap();
        assert_eq!(
            d,
            Dml::Update {
                rel: RelId::Customer,
                filter: Pred::CmpImm {
                    attr: "c_mktsegment",
                    op: CmpOp::Eq,
                    value: 1,
                },
                sets: vec![("c_acctbal", 99_900)],
            }
        );
        let d = parse_dml("delete from lineitem where l_shipdate < date(1993-01-01)")
            .unwrap();
        assert_eq!(
            d,
            Dml::Delete {
                rel: RelId::Lineitem,
                filter: Pred::CmpImm {
                    attr: "l_shipdate",
                    op: CmpOp::Lt,
                    value: schema::date(1993, 1, 1),
                },
            }
        );
        // dictionary words encode in INSERT values; missing where is True
        let d = parse_dml("insert into part (p_partkey, p_brand) values (5, \"Brand#23\")")
            .unwrap();
        assert_eq!(
            d,
            Dml::Insert {
                rel: RelId::Part,
                values: vec![("p_partkey", 5), ("p_brand", schema::brand_id("Brand#23"))],
            }
        );
        let d = parse_dml("delete from orders").unwrap();
        assert_eq!(d, Dml::Delete { rel: RelId::Orders, filter: Pred::True });
        // mixed programs preserve source order
        let stmts = parse_statements(
            "delete from part where p_size == 1; from part | filter true",
        )
        .unwrap();
        assert!(matches!(&stmts[0], Statement::Dml(_)));
        assert!(matches!(&stmts[1], Statement::Query(_)));
    }

    #[test]
    fn dml_lowering_errors() {
        use crate::query::lang::parse_dml;
        let e = parse_dml("insert into part (p_partkey) values (1, 2)").unwrap_err();
        assert!(e.msg.contains("columns but"), "{}", e.msg);
        let e = parse_dml("insert into part (p_partkey, p_partkey) values (1, 2)")
            .unwrap_err();
        assert!(e.msg.contains("duplicate insert column"), "{}", e.msg);
        let e = parse_dml("update part set p_size = 1, p_size = 2").unwrap_err();
        assert!(e.msg.contains("duplicate set column"), "{}", e.msg);
        let e = parse_dml("update nation set n_regionkey = 1").unwrap_err();
        assert!(e.msg.contains("DRAM-resident"), "{}", e.msg);
        let e = parse_dml("update part set p_size = 99").unwrap_err();
        assert!(e.msg.contains("does not fit"), "{}", e.msg);
        let e = parse_dml("delete from part; delete from part").unwrap_err();
        assert!(e.msg.contains("exactly one"), "{}", e.msg);
        let e = parse_dml("from part | filter true").unwrap_err();
        assert!(e.msg.contains("got a query"), "{}", e.msg);
        // query-only contexts reject DML with a spanned diagnostic
        let e = parse_program("delete from part").unwrap_err();
        assert!(e.msg.contains("query-only context"), "{}", e.msg);
    }

    #[test]
    fn error_unknown_column_is_spanned() {
        let src = "from lineitem | filter l_shipdat <= date(1998-09-02)";
        let e = parse_program(src).unwrap_err();
        assert!(e.msg.contains("unknown column 'l_shipdat'"));
        assert_eq!(&src[e.span.start..e.span.end], "l_shipdat");
    }

    #[test]
    fn error_type_mismatches() {
        let e = parse_program("from lineitem | filter l_shipdate == \"MAIL\"")
            .unwrap_err();
        assert!(e.msg.contains("string literal"), "{}", e.msg);
        let e = parse_program("from lineitem | filter l_quantity == date(1994-01-01)")
            .unwrap_err();
        assert!(e.msg.contains("not a date column"), "{}", e.msg);
        let e = parse_program("from lineitem | filter l_shipmode == \"WARP\"")
            .unwrap_err();
        assert!(e.msg.contains("not in the 'l_shipmode' dictionary"), "{}", e.msg);
        let e = parse_program("from lineitem | filter l_quantity == 100")
            .unwrap_err();
        assert!(e.msg.contains("does not fit"), "{}", e.msg);
        let e = parse_program("from lineitem | filter l_shipdate == date(1994-13-01)")
            .unwrap_err();
        assert!(e.msg.contains("invalid calendar date"), "{}", e.msg);
        let e = parse_program(
            "from lineitem | filter l_shipdate < l_quantity",
        )
        .unwrap_err();
        assert!(e.msg.contains("encodings differ"), "{}", e.msg);
        let e = parse_program("from nation | filter true").unwrap_err();
        assert!(e.msg.contains("DRAM-resident"), "{}", e.msg);
        let e = parse_program("from lineitem | group by l_orderkey | aggregate count()")
            .unwrap_err();
        assert!(e.msg.contains("group key"), "{}", e.msg);
    }

    #[test]
    fn error_mixed_aggregate_pipelines() {
        let e = parse_program(
            "from part | filter p_size == 1 \
             from lineitem | filter true | aggregate count()",
        )
        .unwrap_err();
        assert!(e.msg.contains("mixed"), "{}", e.msg);
    }

    #[test]
    fn unsupported_value_shapes_are_rejected() {
        assert!(parse_program(
            "from lineitem | filter true | aggregate sum(2) as x"
        )
        .is_err());
        assert!(parse_program(
            "from lineitem | filter true \
             | aggregate sum((100 - l_discount) * l_extendedprice) as x"
        )
        .is_err());
    }
}
