//! The individual optimizer passes: IN-set prefix peephole, value-
//! numbering CSE, valid-AND elision (via a zero-row abstract
//! interpretation) and dead-step elimination.
//!
//! All passes reason about instruction *functional* semantics, exactly
//! mirroring [`crate::exec::engine::exec_instr`]: reduce instructions
//! observe columns without writing any, `ColumnTransform` is a pure
//! data-movement no-op, and `And`/`Or` broadcast a single-column second
//! operand. Each pass only deletes steps or renames column operands, so
//! instruction costs (which depend on opcode, widths and immediate alone)
//! never increase.

use std::collections::HashMap;

use crate::pim::isa::{ColRange, Opcode, PimInstruction};
use crate::query::compiler::Step;

/// How many planes of `src_a` and `src_b` the engine actually reads,
/// mirroring [`crate::exec::engine::exec_instr`]'s plane accesses (e.g. a
/// broadcast And reads one plane of its second operand; Add/AddImm/Mul
/// clip their reads to the destination width).
pub(super) fn read_lens(i: &PimInstruction) -> (usize, usize) {
    let al = i.src_a.len as usize;
    let bl = i.src_b.map(|b| b.len as usize).unwrap_or(0);
    let dl = i.dst.len as usize;
    match i.op {
        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm => (al, 0),
        Opcode::Eq | Opcode::Lt => (al, bl),
        Opcode::AddImm => (al.min(dl), 0),
        Opcode::Add => (al.min(dl), bl.min(dl)),
        Opcode::Mul => (al.min(dl), bl),
        Opcode::Set | Opcode::Reset => (0, 0),
        Opcode::Not => (al, 0),
        Opcode::And | Opcode::Or => {
            if bl == 1 && al > 1 {
                (al, 1) // single-column second operand broadcasts
            } else {
                (al, bl.min(al))
            }
        }
        Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax | Opcode::ColumnTransform => {
            (al, 0)
        }
    }
}

/// The columns an instruction fully overwrites; `None` for reduces and
/// column-transform (reduce results leave through the read phase; the
/// transform re-orients bits without changing their value).
pub(super) fn write_span(i: &PimInstruction) -> Option<ColRange> {
    let al = i.src_a.len as usize;
    let d = i.dst;
    match i.op {
        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm | Opcode::Eq | Opcode::Lt => {
            Some(ColRange::new(d.start as usize, 1))
        }
        Opcode::Not | Opcode::And | Opcode::Or => {
            Some(ColRange::new(d.start as usize, al))
        }
        Opcode::AddImm | Opcode::Add | Opcode::Mul | Opcode::Set | Opcode::Reset => Some(d),
        Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax | Opcode::ColumnTransform => None,
    }
}

/// The exact column ranges an instruction reads and (fully over-)writes.
pub(super) fn accesses(i: &PimInstruction) -> (Vec<ColRange>, Option<ColRange>) {
    let (la, lb) = read_lens(i);
    let mut reads = Vec::with_capacity(2);
    if la > 0 {
        reads.push(ColRange::new(i.src_a.start as usize, la));
    }
    if lb > 0 {
        reads.push(ColRange::new(i.src_b.expect("lb > 0").start as usize, lb));
    }
    (reads, write_span(i))
}

/// Whether a reduce or column-transform step — kept unconditionally: the
/// former appends to the program's output stream, the latter is the read
/// phase's re-orientation marker.
pub(super) fn side_effect(op: Opcode) -> bool {
    matches!(
        op,
        Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax | Opcode::ColumnTransform
    )
}

pub(super) fn overlaps(r: ColRange, start: usize, width: usize) -> bool {
    (r.start as usize) < start + width && start < r.end()
}

/// One past the highest column any step touches (sizing scratch tables).
pub(super) fn max_col(steps: &[Step]) -> usize {
    let mut m = 0usize;
    for s in steps {
        let (reads, write) = accesses(&s.instr);
        for r in reads.iter().chain(write.iter()) {
            m = m.max(r.end());
        }
    }
    m
}

// --- IN-set prefix peephole -------------------------------------------------

/// `Reset m; EqImm v0 -> t; Or(m, t) -> m` (the compiler's IN-set prefix —
/// OR-accumulation into an explicitly zeroed mask) is `EqImm v0 -> m`:
/// `0 | eq == eq`. Drops one Reset and one Or per IN-set (and per
/// `Or`-chain whose first arm lowers to a Reset). `mask_col` is the
/// program's final read-out column — a write to it is never "dead".
pub(super) fn peephole_in_set(steps: Vec<Step>, mask_col: usize) -> Vec<Step> {
    let mut out = Vec::with_capacity(steps.len());
    let mut i = 0;
    while i < steps.len() {
        if i + 2 < steps.len() && in_set_prefix_at(&steps, i, mask_col) {
            let eq = &steps[i + 1];
            out.push(Step {
                instr: PimInstruction {
                    dst: steps[i].instr.dst,
                    ..eq.instr
                },
                category: eq.category,
            });
            i += 3;
        } else {
            out.push(steps[i].clone());
            i += 1;
        }
    }
    out
}

fn in_set_prefix_at(steps: &[Step], i: usize, mask_col: usize) -> bool {
    let (r, e, o) = (&steps[i].instr, &steps[i + 1].instr, &steps[i + 2].instr);
    let matches_shape = r.op == Opcode::Reset
        && r.dst.len == 1
        && e.op == Opcode::EqImm
        && e.dst.len == 1
        && e.dst.start != r.dst.start
        // the rewrite stops writing the temporary, so it must not be the
        // mask column (popcounted at program end) ...
        && e.dst.start as usize != mask_col
        // ... and the comparison input must not cover the Reset mask: the
        // rewrite drops the Reset, so the EqImm would read its pre-Reset
        // content
        && !overlaps(e.src_a, r.dst.start as usize, 1)
        && o.op == Opcode::Or
        && o.src_a == r.dst
        && o.src_b == Some(e.dst)
        && o.dst == r.dst;
    if !matches_shape {
        return false;
    }
    // after the rewrite the temporary `t` is no longer written here: prove
    // every later access to it is a write-before-read (the IN-set loop
    // overwrites t with the next EqImm before the next Or reads it)
    let t = e.dst.start as usize;
    for s in &steps[i + 3..] {
        let (reads, write) = accesses(&s.instr);
        if reads.iter().any(|r| overlaps(*r, t, 1)) {
            return false;
        }
        if let Some(w) = write {
            if overlaps(w, t, 1) {
                return true;
            }
        }
    }
    true
}

// --- zero-row abstract interpretation + valid-AND elision -------------------

fn ones(len: usize) -> u128 {
    if len >= 128 {
        u128::MAX
    } else {
        (1u128 << len) - 1
    }
}

fn value_of(vals: &[bool], r: ColRange) -> u128 {
    let mut v = 0u128;
    for i in 0..(r.len as usize).min(128) {
        if vals[r.start as usize + i] {
            v |= 1 << i;
        }
    }
    v
}

fn store(vals: &mut [bool], start: usize, len: usize, v: u128) {
    for i in 0..len.min(128) {
        vals[start + i] = (v >> i) & 1 == 1;
    }
}

/// Execute one instruction on a single all-context row (the abstract
/// "unoccupied row": every data attribute 0, VALID 0, compute area 0) —
/// a one-row mirror of [`crate::exec::engine::exec_instr`].
fn zero_row_exec(vals: &mut [bool], i: &PimInstruction) {
    let a = i.src_a;
    let d = i.dst;
    let al = a.len as usize;
    let dl = d.len as usize;
    match i.op {
        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm => {
            let v = value_of(vals, a);
            let imm = (i.imm as u128) & ones(al);
            let out = match i.op {
                Opcode::EqImm => v == imm,
                Opcode::NeImm => v != imm,
                Opcode::LtImm => v < imm,
                Opcode::GtImm => v > imm,
                _ => unreachable!(),
            };
            vals[d.start as usize] = out;
        }
        Opcode::Eq | Opcode::Lt => {
            let b = i.src_b.expect("binary cmp");
            let va = value_of(vals, a);
            // second operand zero-extends to the first operand's width
            let vb = value_of(vals, ColRange::new(b.start as usize, (b.len as usize).min(al)));
            vals[d.start as usize] = if i.op == Opcode::Eq { va == vb } else { va < vb };
        }
        Opcode::AddImm => {
            // mirrors Add: source zero-extends to the destination width,
            // the immediate is truncated to it, carries fill every dst plane
            let v = value_of(vals, ColRange::new(a.start as usize, al.min(dl)));
            let imm = (i.imm as u128) & ones(dl);
            store(vals, d.start as usize, dl, (v + imm) & ones(dl));
        }
        Opcode::Add => {
            let b = i.src_b.expect("add");
            let va = value_of(vals, ColRange::new(a.start as usize, al.min(dl)));
            let vb = value_of(vals, ColRange::new(b.start as usize, (b.len as usize).min(dl)));
            store(vals, d.start as usize, dl, (va + vb) & ones(dl));
        }
        Opcode::Mul => {
            let b = i.src_b.expect("mul");
            let va = value_of(vals, ColRange::new(a.start as usize, al.min(dl)));
            let vb = value_of(vals, b);
            store(vals, d.start as usize, dl, va.wrapping_mul(vb) & ones(dl));
        }
        Opcode::Set => store(vals, d.start as usize, dl, u128::MAX),
        Opcode::Reset => store(vals, d.start as usize, dl, 0),
        Opcode::Not => {
            let v = value_of(vals, a);
            store(vals, d.start as usize, al, !v & ones(al));
        }
        Opcode::And | Opcode::Or => {
            let b = i.src_b.expect("and/or");
            let va = value_of(vals, a);
            let vb = if b.len == 1 && a.len > 1 {
                // broadcast: replicate the mask bit over the operand width
                if vals[b.start as usize] {
                    ones(al)
                } else {
                    0
                }
            } else {
                value_of(vals, ColRange::new(b.start as usize, (b.len as usize).min(al)))
            };
            let out = if i.op == Opcode::And { va & vb } else { va | vb };
            store(vals, d.start as usize, al, out);
        }
        Opcode::ReduceSum
        | Opcode::ReduceMin
        | Opcode::ReduceMax
        | Opcode::ColumnTransform => {}
    }
}

/// Drop the compiler's final `And(mask, VALID) -> mask` when the zero-row
/// interpretation proves the predicate already evaluates to 0 on
/// unoccupied rows. Occupied rows carry VALID = 1, so the And only ever
/// clears unoccupied rows — whose mask bit the predicate already left at
/// 0. Every TPC-H filter that rejects the all-zero record (any date
/// range, key equality against a non-zero dictionary id, ...) qualifies.
pub(super) fn valid_elide(steps: Vec<Step>, valid_col: usize) -> Vec<Step> {
    let mut vals = vec![false; max_col(&steps) + 1];
    let mut out = Vec::with_capacity(steps.len());
    for step in steps {
        let i = &step.instr;
        let elidable = i.op == Opcode::And
            && i.src_b == Some(ColRange::new(valid_col, 1))
            && i.src_a.len == 1
            && i.dst == i.src_a
            && !vals[i.src_a.start as usize];
        if elidable {
            continue;
        }
        zero_row_exec(&mut vals, i);
        out.push(step);
    }
    out
}

// --- value-numbering CSE -----------------------------------------------------

/// CSE hash key: two instructions with equal keys compute identical
/// column contents (opcode + immediate + write width + the per-operand
/// read widths + the value numbers of every plane they read, in engine
/// read order). The `(la, lb)` split keeps e.g. two Muls whose flattened
/// source numbers coincide but whose operand widths differ apart.
#[derive(Clone, PartialEq, Eq, Hash)]
struct Key {
    op: u8,
    imm: u64,
    write_w: u16,
    la: u16,
    lb: u16,
    srcs: Vec<u64>,
}

struct Entry {
    /// Key-derived value numbers of the write range.
    vns: Vec<u64>,
    /// Columns currently holding the value (the last *kept* def).
    home: Option<usize>,
}

/// Common-subexpression elimination by value numbering, for programs in
/// *virtualized* (reuse-free) column space.
///
/// Every executed instruction assigns its write range value numbers
/// derived from its key, so recomputations of the same expression are
/// recognized even across in-place chains. A recomputation whose previous
/// result columns are intact is elided; later reads of its destination
/// are redirected to the surviving copy. Elision is only performed when a
/// forward scan proves every future read of the destination is fully
/// contained in it and the surviving copy is not overwritten before its
/// last redirected use — otherwise the instruction is simply kept.
///
/// Returns the new steps and the (possibly redirected) mask column, or
/// `None` if an internal invariant is violated (the caller falls back to
/// the un-CSE'd program).
pub(super) fn cse(
    steps: Vec<Step>,
    mask_col: usize,
    compute_base: usize,
) -> Option<(Vec<Step>, usize)> {
    let ncols = max_col(&steps).max(mask_col) + 1;
    // value number per column; unwritten columns are stable "inputs"
    // (data/valid columns, plus the zero-initialized compute area).
    // Input numbers are column ids < 2^32; derived numbers start above.
    let mut col_vn: Vec<u64> = (0..ncols as u64).collect();
    let mut redirect: Vec<Option<usize>> = vec![None; ncols];
    let mut next_vn: u64 = 1 << 32;
    let mut table: HashMap<Key, Entry> = HashMap::new();

    let mut out: Vec<Step> = Vec::with_capacity(steps.len());
    for (idx, step) in steps.iter().enumerate() {
        // 1. rewrite source operands through the redirection map; the
        //    engine-read prefix of each operand must map contiguously
        let mut instr = step.instr;
        let (la, lb) = read_lens(&instr);
        for (field, l) in [(0usize, la), (1, lb)] {
            if l == 0 {
                continue;
            }
            let r = if field == 0 {
                instr.src_a
            } else {
                instr.src_b.expect("lb > 0")
            };
            let s = r.start as usize;
            if s < compute_base {
                continue; // data/valid columns are never redirected
            }
            let mapped0 = redirect[s].unwrap_or(s);
            for k in 1..l {
                if redirect[s + k].unwrap_or(s + k) != mapped0 + k {
                    // an elision's forward guarantee was violated
                    debug_assert!(false, "non-contiguous CSE redirect");
                    return None;
                }
            }
            if mapped0 != s {
                let nr = ColRange::new(mapped0, r.len as usize);
                if field == 0 {
                    instr.src_a = nr;
                } else {
                    instr.src_b = Some(nr);
                }
            }
        }

        let Some(w) = write_span(&instr) else {
            // reduces / column-transform: pure observers; keep the
            // cosmetic dst field mirroring the (redirected) source
            instr.dst = instr.src_a;
            out.push(Step {
                instr,
                category: step.category,
            });
            continue;
        };
        let (w0, ww) = (w.start as usize, w.len as usize);

        // 2. key + key-derived value numbers for the write range
        let (reads, _) = accesses(&instr);
        let mut srcs = Vec::new();
        for r in &reads {
            for k in 0..r.len as usize {
                srcs.push(col_vn[r.start as usize + k]);
            }
        }
        let key = Key {
            op: instr.op as u8,
            imm: if instr.op.has_imm() { instr.imm } else { 0 },
            write_w: ww as u16,
            la: la as u16,
            lb: lb as u16,
            srcs,
        };
        let (vns, home) = {
            let e = table.entry(key.clone()).or_insert_with(|| {
                let vns: Vec<u64> = (0..ww as u64).map(|k| next_vn + k).collect();
                next_vn += ww as u64;
                Entry { vns, home: None }
            });
            (e.vns.clone(), e.home)
        };

        // 3. elide a recomputation whose previous result is intact
        let home_intact = home.filter(|&h| (0..ww).all(|k| col_vn[h + k] == vns[k]));
        if let Some(h) = home_intact {
            if h == w0 {
                // the destination already holds this exact value; dropping
                // the write is only safe when no earlier elision still
                // counts on this step to clear a redirect of these columns
                if (0..ww).all(|k| redirect[w0 + k].is_none()) {
                    continue;
                }
            } else if elision_safe(&steps[idx + 1..], w0, ww, h, mask_col) {
                for k in 0..ww {
                    redirect[w0 + k] = Some(h + k);
                    col_vn[w0 + k] = vns[k];
                }
                continue;
            }
        }

        // 4. keep: the write range becomes the value's newest home
        for k in 0..ww {
            redirect[w0 + k] = None;
            col_vn[w0 + k] = vns[k];
        }
        table.get_mut(&key).expect("inserted above").home = Some(w0);
        out.push(Step {
            instr,
            category: step.category,
        });
    }

    let mask = redirect[mask_col].unwrap_or(mask_col);
    Some((out, mask))
}

/// Forward-safety scan for eliding a def of `[d0, d0+w)` whose value
/// survives at `[h0, h0+w)`: every later read touching the not-yet-
/// rewritten part of the def must be fully contained in it (so it can be
/// redirected contiguously), must not mix live and rewritten columns, and
/// must happen before anything overwrites the home. The final mask
/// read-out counts as a read at program end.
fn elision_safe(rest: &[Step], d0: usize, w: usize, h0: usize, mask_col: usize) -> bool {
    let mut live = vec![true; w];
    let mut n_live = w;
    let mut h_written = false;
    for s in rest {
        let (reads, write) = accesses(&s.instr);
        // a write overlapping the home invalidates all later redirects —
        // flagged before this step's reads: a step that both reads the
        // dead def and overwrites its home would read interleaved planes
        if write.is_some_and(|wr| overlaps(wr, h0, w)) {
            h_written = true;
        }
        for r in &reads {
            if !overlaps(*r, d0, w) {
                continue;
            }
            let rs = r.start as usize;
            let within = rs >= d0 && r.end() <= d0 + w;
            if !within || h_written {
                return false;
            }
            if (rs - d0..r.end() - d0).any(|k| !live[k]) {
                return false; // mixes redirected and rewritten columns
            }
        }
        if let Some(wr) = write {
            for c in (wr.start as usize)..wr.end() {
                if c >= d0 && c < d0 + w && live[c - d0] {
                    live[c - d0] = false;
                    n_live -= 1;
                }
            }
            if n_live == 0 {
                return true;
            }
        }
    }
    // still-live def columns are never read again — except the mask,
    // which the engine pops at program end
    if mask_col >= d0 && mask_col < d0 + w && live[mask_col - d0] && h_written {
        return false;
    }
    true
}

// --- dead-step elimination ---------------------------------------------------

/// Backward column-granular liveness: a step whose entire write range is
/// dead is removed. Roots are the mask column (popcounted by the engine
/// after the last step) and the operands of every side-effecting step
/// (reduces, column-transform), which are kept unconditionally.
pub(super) fn dce(steps: Vec<Step>, mask_col: usize) -> Vec<Step> {
    let ncols = max_col(&steps).max(mask_col) + 1;
    let mut live = vec![false; ncols];
    live[mask_col] = true;
    let mut keep = vec![true; steps.len()];
    for (j, step) in steps.iter().enumerate().rev() {
        let (reads, write) = accesses(&step.instr);
        if side_effect(step.instr.op) {
            for r in &reads {
                live[r.start as usize..r.end()].fill(true);
            }
            continue;
        }
        let w = write.expect("non-side-effect ops write");
        if !live[w.start as usize..w.end()].iter().any(|&l| l) {
            keep[j] = false;
            continue;
        }
        live[w.start as usize..w.end()].fill(false);
        for r in &reads {
            live[r.start as usize..r.end()].fill(true);
        }
    }
    steps
        .into_iter()
        .zip(keep)
        .filter_map(|(s, k)| k.then_some(s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::engine::{exec_steps_native, XbarState};
    use crate::pim::endurance::OpCategory;
    use crate::util::bits::WORDS;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    fn random_states(seed: u64, n: usize, data_cols: usize, total: usize) -> Vec<XbarState> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut st = XbarState::new(total);
                for c in 0..data_cols {
                    for w in 0..WORDS {
                        st.planes[c][w] = rng.next_u64();
                    }
                }
                st
            })
            .collect()
    }

    /// Original and transformed programs must agree on every observable:
    /// reduce streams, mask counts, and the data columns (never written).
    fn assert_equivalent(a: &[Step], b: &[Step], mask_a: usize, mask_b: usize, seed: u64) {
        let total = max_col(a).max(max_col(b)).max(mask_a).max(mask_b) + 1;
        let mut sa = random_states(seed, 3, 24, total);
        let mut sb = sa.clone();
        let ra = exec_steps_native(&mut sa, a, mask_a);
        let rb = exec_steps_native(&mut sb, b, mask_b);
        assert_eq!(ra.reduces, rb.reduces);
        assert_eq!(ra.mask_counts, rb.mask_counts);
    }

    fn in_set_program() -> Vec<Step> {
        let a = ColRange::new(0, 8);
        let d = ColRange::new(30, 1);
        let t = ColRange::new(31, 1);
        vec![
            step(PimInstruction::unary(Opcode::Reset, d, d)),
            step(PimInstruction::with_imm(Opcode::EqImm, a, t, 5)),
            step(PimInstruction::binary(Opcode::Or, d, t, d)),
            step(PimInstruction::with_imm(Opcode::EqImm, a, t, 9)),
            step(PimInstruction::binary(Opcode::Or, d, t, d)),
            step(PimInstruction::unary(Opcode::ReduceSum, d, d)),
        ]
    }

    #[test]
    fn peephole_rewrites_in_set_prefix() {
        let p = in_set_program();
        let q = peephole_in_set(p.clone(), 30);
        assert_eq!(q.len(), p.len() - 2);
        assert_eq!(q[0].instr.op, Opcode::EqImm);
        assert_eq!(q[0].instr.dst, ColRange::new(30, 1));
        assert_equivalent(&p, &q, 30, 30, 11);
        // the temp being the mask blocks the rewrite: its write is live
        let kept = peephole_in_set(p.clone(), 31);
        assert_eq!(kept.len(), p.len());
        assert_equivalent(&p, &kept, 31, 31, 12);
    }

    #[test]
    fn peephole_keeps_pattern_when_temp_is_read_later() {
        let a = ColRange::new(0, 8);
        let d = ColRange::new(30, 1);
        let t = ColRange::new(31, 1);
        let p = vec![
            step(PimInstruction::unary(Opcode::Reset, d, d)),
            step(PimInstruction::with_imm(Opcode::EqImm, a, t, 5)),
            step(PimInstruction::binary(Opcode::Or, d, t, d)),
            // t read again without a fresh write: rewrite must not fire
            step(PimInstruction::binary(Opcode::And, d, t, d)),
        ];
        assert_eq!(peephole_in_set(p.clone(), 30).len(), p.len());
    }

    #[test]
    fn valid_elide_drops_and_when_zero_row_rejects() {
        let a = ColRange::new(0, 8);
        let d = ColRange::new(30, 1);
        let valid = ColRange::new(20, 1);
        // eq against a non-zero imm: zero row fails the predicate
        let p = vec![
            step(PimInstruction::with_imm(Opcode::EqImm, a, d, 7)),
            step(PimInstruction::binary(Opcode::And, d, valid, d)),
        ];
        let q = valid_elide(p.clone(), 20);
        assert_eq!(q.len(), 1);

        // le-style predicate passes the zero row: the And must stay
        let p2 = vec![
            step(PimInstruction::with_imm(Opcode::LtImm, a, d, 200)),
            step(PimInstruction::binary(Opcode::And, d, valid, d)),
        ];
        assert_eq!(valid_elide(p2.clone(), 20).len(), 2);
    }

    #[test]
    fn dce_removes_unobserved_writes() {
        let a = ColRange::new(0, 8);
        let d = ColRange::new(30, 1);
        let dead = ColRange::new(40, 4);
        let p = vec![
            step(PimInstruction::with_imm(Opcode::EqImm, a, d, 7)),
            step(PimInstruction::unary(Opcode::Set, dead, dead)),
            step(PimInstruction::unary(Opcode::ReduceSum, d, d)),
        ];
        let q = dce(p.clone(), 30);
        assert_eq!(q.len(), 2);
        assert!(q.iter().all(|s| s.instr.op != Opcode::Set));
        assert_equivalent(&p, &q, 30, 30, 3);
    }

    #[test]
    fn cse_elides_recomputation_and_redirects_reads() {
        let a = ColRange::new(0, 8);
        let d1 = ColRange::new(30, 1);
        let d2 = ColRange::new(31, 1);
        let m = ColRange::new(32, 1);
        let p = vec![
            step(PimInstruction::with_imm(Opcode::EqImm, a, d1, 7)),
            step(PimInstruction::with_imm(Opcode::EqImm, a, d2, 7)), // dup
            step(PimInstruction::binary(Opcode::Or, d1, d2, m)),
            step(PimInstruction::unary(Opcode::ReduceSum, m, m)),
        ];
        let (q, mask) = cse(p.clone(), 32, 24).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(mask, 32);
        // the Or now reads d1 twice
        assert_eq!(q[1].instr.src_b, Some(d1));
        assert_equivalent(&p, &q, 32, mask, 5);
    }

    #[test]
    fn cse_keeps_recomputation_when_home_overwritten() {
        let a = ColRange::new(0, 8);
        let d1 = ColRange::new(30, 1);
        let d2 = ColRange::new(31, 1);
        let p = vec![
            step(PimInstruction::with_imm(Opcode::EqImm, a, d1, 7)),
            step(PimInstruction::unary(Opcode::Not, d1, d1)), // destroys home
            step(PimInstruction::with_imm(Opcode::EqImm, a, d2, 7)),
            step(PimInstruction::unary(Opcode::ReduceSum, d2, d2)),
        ];
        let (q, _) = cse(p.clone(), 31, 24).unwrap();
        assert_eq!(q.len(), 4, "home destroyed: nothing to elide");
    }

    #[test]
    fn cse_tracks_values_through_in_place_chains() {
        // two identical Not/AddImm chains over the same input: the second
        // chain's final step is elided (its value survives in the first),
        // then DCE removes the rest of the second chain
        let a = ColRange::new(0, 8);
        let f1 = ColRange::new(30, 8);
        let f2 = ColRange::new(40, 8);
        let chain = |f: ColRange| {
            vec![
                step(PimInstruction::unary(Opcode::Reset, f, f)),
                step(PimInstruction::binary(Opcode::Or, a, ColRange::new(20, 1), f)),
                step(PimInstruction::unary(Opcode::Not, f, f)),
                step(PimInstruction::with_imm(Opcode::AddImm, f, f, 101)),
            ]
        };
        let mut p = chain(f1);
        p.extend(chain(f2));
        p.push(step(PimInstruction::unary(Opcode::ReduceSum, f2, f2)));
        p.push(step(PimInstruction::unary(Opcode::ReduceSum, f1, f1)));
        let (q, mask) = cse(p.clone(), 30, 24).unwrap();
        assert!(q.len() < p.len(), "final AddImm of the repeat must elide");
        let q = dce(q, mask);
        // everything of the second chain is gone
        assert_eq!(q.len(), 4 + 2, "{}", q.len());
        assert_equivalent(&p, &q, 30, mask, 17);
    }

    #[test]
    fn passes_preserve_semantics_on_random_programs() {
        // random straight-line programs over data cols [0,24) + scratch
        // [24,64): full pipeline output must match the original on random
        // crossbar states
        check("opt-passes-random", 60, |g| {
            let mut steps = Vec::new();
            let scratch = |g: &mut crate::util::proptest::Gen| {
                ColRange::new(24 + g.usize(0, 36), 1)
            };
            for _ in 0..g.usize(3, 25) {
                let a = ColRange::new(g.usize(0, 16), g.usize(1, 8));
                let d = scratch(g);
                let instr = match g.u64(0, 6) {
                    0 => PimInstruction::with_imm(Opcode::EqImm, a, d, g.u64(0, 255)),
                    1 => PimInstruction::with_imm(Opcode::LtImm, a, d, g.u64(0, 255)),
                    2 => PimInstruction::unary(Opcode::Reset, d, d),
                    3 => PimInstruction::binary(Opcode::Or, d, scratch(g), d),
                    4 => PimInstruction::binary(Opcode::And, d, scratch(g), d),
                    5 => PimInstruction::unary(Opcode::Not, d, d),
                    _ => PimInstruction::unary(Opcode::ReduceSum, a, a),
                };
                steps.push(step(instr));
            }
            let mask = 24 + g.usize(0, 36);
            let p = peephole_in_set(steps.clone(), mask);
            let (p, m) = cse(p, mask, 24).unwrap();
            let p = valid_elide(p, 20);
            let p = dce(p, m);
            assert_equivalent(&steps, &p, mask, m, g.u64(0, 1 << 40));
        });
    }
}
