//! Column virtualization and lifetime-based reallocation.
//!
//! The compiler's LIFO allocator reuses scratch columns aggressively,
//! which (a) destroys the value equalities CSE needs — a recomputed
//! expression's previous result is usually buried under younger scratch —
//! and (b) keeps long-dead columns allocated (per-group masks stack up
//! for the whole program). [`virtualize`] rewrites a program into a
//! *reuse-free* column space using the compiler's [`AllocSpan`] metadata:
//! every allocation becomes its own virtual block, so each column holds
//! exactly one value-producing chain. After the value passes have run,
//! [`realloc`] assigns physical columns back by **live interval** with a
//! first-fit free list — the replacement for the LIFO discipline — and
//! reports the new `peak_inter_cells`. Both stages are total functions on
//! compiler output but verify every assumption they rest on, returning
//! `None` (caller falls back to `-O1`) on anything unexpected.

use crate::pim::isa::ColRange;
use crate::query::compiler::{AllocSpan, CompiledRelQuery, Step};

use super::passes::{accesses, max_col, read_lens};

/// One reuse-free column block (a compiler allocation, relocated).
#[derive(Clone, Copy, Debug)]
pub(super) struct Block {
    /// First virtual column.
    pub vstart: usize,
    /// Columns in the block.
    pub width: usize,
}

/// A program rewritten into reuse-free virtual column space.
pub(super) struct VirtProgram {
    /// Steps with every compute-area operand remapped to virtual columns.
    pub steps: Vec<Step>,
    /// The mask column's virtual home at program end.
    pub mask_col: usize,
    /// All virtual blocks, ascending and disjoint from `compute_base`.
    pub blocks: Vec<Block>,
}

/// A program placed back into physical columns by [`realloc`].
pub(super) struct Placed {
    /// Steps with operands remapped to the new physical columns.
    pub steps: Vec<Step>,
    /// The mask column's physical location.
    pub mask_col: usize,
    /// Columns-above-base high water mark (Table 5 "Inter. cells").
    pub peak: usize,
    /// The surviving allocations (new program's span metadata).
    pub spans: Vec<AllocSpan>,
}

/// Remap a program into reuse-free virtual columns, one block per
/// compiler allocation. Ownership of a physical column at a given step is
/// resolved write-side by allocation birth (`AllocSpan::born_step`) and
/// read-side by last write, so values that outlive their LIFO release —
/// and columns reused by younger allocations — separate cleanly.
pub(super) fn virtualize(c: &CompiledRelQuery) -> Option<VirtProgram> {
    let base = c.compute_base;
    let phys_cols = c
        .spans
        .iter()
        .map(|s| s.start + s.width)
        .max()?
        .max(max_col(&c.steps))
        .max(c.mask_col + 1);

    // per-column span history, in birth order
    let mut history: Vec<Vec<(usize, usize)>> = vec![Vec::new(); phys_cols];
    let mut blocks = Vec::with_capacity(c.spans.len());
    let mut vtop = base;
    for (i, s) in c.spans.iter().enumerate() {
        if s.start < base {
            return None;
        }
        blocks.push(Block {
            vstart: vtop,
            width: s.width,
        });
        vtop += s.width;
        for col in s.start..s.start + s.width {
            if let Some(&(born, _)) = history[col].last() {
                if born == s.born_step {
                    return None; // ambiguous ownership
                }
            }
            history[col].push((s.born_step, i));
        }
    }

    // owner[col]: the span that last wrote the column
    let mut owner: Vec<Option<usize>> = vec![None; phys_cols];
    let map_read = |owner: &[Option<usize>], r: ColRange| -> Option<usize> {
        let s = r.start as usize;
        if s < base {
            return (r.end() <= base).then_some(s);
        }
        let j = owner[s]?;
        let span = &c.spans[j];
        for col in s..s + r.len as usize {
            if owner.get(col).copied().flatten() != Some(j) {
                return None;
            }
        }
        (s + r.len as usize <= span.start + span.width).then(|| blocks[j].vstart + (s - span.start))
    };

    let mut steps = Vec::with_capacity(c.steps.len());
    for (idx, step) in c.steps.iter().enumerate() {
        let mut instr = step.instr;
        // remap operand fields by their engine-read prefixes
        let (la, lb) = read_lens(&instr);
        if la > 0 {
            let new_start = map_read(&owner, ColRange::new(instr.src_a.start as usize, la))?;
            instr.src_a = ColRange::new(new_start, instr.src_a.len as usize);
        }
        if lb > 0 {
            let b = instr.src_b.expect("lb > 0");
            let new_start = map_read(&owner, ColRange::new(b.start as usize, lb))?;
            instr.src_b = Some(ColRange::new(new_start, b.len as usize));
        }
        let (_, write) = accesses(&instr);
        if let Some(w) = write {
            let w0 = step.instr.dst.start as usize;
            if w0 < base {
                return None; // programs never write data columns
            }
            // ownership at a write: the youngest span born by now
            let j = latest_span(&history, w0, idx)?;
            let span = &c.spans[j];
            if w0 + w.len as usize > span.start + span.width {
                return None;
            }
            for col in w0..w0 + w.len as usize {
                if latest_span(&history, col, idx) != Some(j) {
                    return None;
                }
                owner[col] = Some(j);
            }
            let new_start = blocks[j].vstart + (w0 - span.start);
            instr.dst = ColRange::new(new_start, instr.dst.len as usize);
            if la == 0 {
                // Set/Reset read nothing: keep the cosmetic src_a field
                // mirroring the (remapped) destination
                instr.src_a = instr.dst;
            }
        } else {
            // reduces / column-transform: keep dst mirroring src_a
            instr.dst = instr.src_a;
        }
        steps.push(Step {
            instr,
            category: step.category,
        });
    }

    let mask_owner = owner[c.mask_col]?;
    let span = &c.spans[mask_owner];
    let mask_col = blocks[mask_owner].vstart + (c.mask_col - span.start);
    Some(VirtProgram {
        steps,
        mask_col,
        blocks,
    })
}

/// The span covering `col` with the largest `born_step <= step`.
fn latest_span(history: &[Vec<(usize, usize)>], col: usize, step: usize) -> Option<usize> {
    history
        .get(col)?
        .iter()
        .take_while(|&&(born, _)| born <= step)
        .last()
        .map(|&(_, j)| j)
}

/// Assign physical columns to virtual blocks by live interval.
///
/// Decreasing-lifetime placement: long-lived blocks (the mask, CSE'd
/// arithmetic fields) are placed first and sink to the bottom of the
/// compute area; short-lived per-group scratch packs above and reuses
/// columns across disjoint lifetimes. Two blocks may share columns only
/// when their `[first_write, last_access]` intervals are strictly
/// disjoint — touching at one step counts as a conflict, mirroring the
/// engine's per-plane read/write interleave. The mask block stays live
/// to program end for the engine's final popcount. Returns `None` if any
/// invariant fails or the new peak would exceed `orig_peak` —
/// `peak_inter_cells` never increases, per the acceptance contract.
pub(super) fn realloc(
    steps: Vec<Step>,
    blocks: &[Block],
    mask_col: usize,
    compute_base: usize,
    orig_peak: usize,
) -> Option<Placed> {
    let vtop = blocks.last().map(|b| b.vstart + b.width).unwrap_or(compute_base);
    // vcol -> block id
    let mut block_of = vec![usize::MAX; vtop];
    for (i, b) in blocks.iter().enumerate() {
        block_of[b.vstart..b.vstart + b.width].fill(i);
    }
    let lookup = |r: ColRange| -> Option<usize> {
        let s = r.start as usize;
        if s < compute_base {
            return (r.end() <= compute_base).then_some(usize::MAX);
        }
        let i = *block_of.get(s)?;
        let last = *block_of.get(r.end().checked_sub(1)?)?;
        (i != usize::MAX && i == last).then_some(i)
    };

    // live intervals + write-before-read validation
    let mut first_write = vec![usize::MAX; blocks.len()];
    let mut last_access = vec![0usize; blocks.len()];
    let mut written = vec![false; vtop];
    for (idx, step) in steps.iter().enumerate() {
        let (reads, write) = accesses(&step.instr);
        for r in &reads {
            let i = lookup(*r)?;
            if i == usize::MAX {
                continue;
            }
            if (r.start as usize..r.end()).any(|c| !written[c]) {
                return None; // value passes guarantee write-before-read
            }
            last_access[i] = idx;
        }
        if let Some(w) = write {
            let i = lookup(w)?;
            if i == usize::MAX {
                return None;
            }
            first_write[i] = first_write[i].min(idx);
            last_access[i] = idx;
            written[w.start as usize..w.end()].fill(true);
        }
    }
    let mask_block = lookup(ColRange::new(mask_col, 1))?;
    if mask_block == usize::MAX || first_write[mask_block] == usize::MAX {
        return None;
    }
    last_access[mask_block] = usize::MAX; // popcounted at program end

    // decreasing-lifetime placement over live intervals
    let mut order: Vec<usize> = (0..blocks.len())
        .filter(|&i| first_write[i] != usize::MAX)
        .collect();
    order.sort_by_key(|&i| {
        (
            std::cmp::Reverse(last_access[i] - first_write[i]),
            first_write[i],
            blocks[i].vstart,
        )
    });
    let mut placed: Vec<(usize, usize, usize, usize)> = Vec::new(); // (at, w, fw, la)
    let mut peak = 0usize;
    let mut placement = vec![usize::MAX; blocks.len()];
    for &i in &order {
        let w = blocks[i].width;
        let mut conflicts: Vec<(usize, usize)> = placed
            .iter()
            .filter(|&&(_, _, f, l)| !(l < first_write[i] || last_access[i] < f))
            .map(|&(at, aw, _, _)| (at, aw))
            .collect();
        conflicts.sort_unstable();
        let mut at = compute_base;
        for (cs, cw) in conflicts {
            if at + w <= cs {
                break;
            }
            at = at.max(cs + cw);
        }
        placement[i] = at;
        placed.push((at, w, first_write[i], last_access[i]));
        peak = peak.max(at + w - compute_base);
    }
    if peak > orig_peak {
        return None;
    }

    // remap every operand field through its block's placement
    let remap = |r: ColRange| -> Option<ColRange> {
        let s = r.start as usize;
        if s < compute_base {
            return Some(r);
        }
        let i = *block_of.get(s)?;
        if i == usize::MAX || placement[i] == usize::MAX {
            return None;
        }
        Some(ColRange::new(
            placement[i] + (s - blocks[i].vstart),
            r.len as usize,
        ))
    };
    let mut out = Vec::with_capacity(steps.len());
    for step in &steps {
        let mut instr = step.instr;
        instr.src_a = remap(instr.src_a)?;
        if let Some(b) = instr.src_b {
            instr.src_b = Some(remap(b)?);
        }
        instr.dst = remap(instr.dst)?;
        out.push(Step {
            instr,
            category: step.category,
        });
    }
    let mask = placement[mask_block] + (mask_col - blocks[mask_block].vstart);
    // CompiledRelQuery::spans is documented as allocation order: births
    // must come out nondecreasing so a re-virtualization stays sound
    let mut spans: Vec<AllocSpan> = order
        .iter()
        .map(|&i| AllocSpan {
            start: placement[i],
            width: blocks[i].width,
            born_step: first_write[i],
        })
        .collect();
    spans.sort_by_key(|s| (s.born_step, s.start));
    Some(Placed {
        steps: out,
        mask_col: mask,
        peak,
        spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::db::layout::DbLayout;
    use crate::exec::engine::{exec_steps_native, XbarState};
    use crate::query::compiler::Compiler;
    use crate::query::tpch;
    use crate::util::bits::WORDS;
    use crate::util::rng::Rng;

    fn layouts() -> (SystemConfig, DbLayout) {
        let cfg = SystemConfig::default();
        let l = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        (cfg, l)
    }

    /// virtualize + realloc (without value passes) must preserve the
    /// functional outputs of every TPC-H program on random crossbars.
    #[test]
    fn virtualize_then_realloc_is_functionally_identity() {
        let (cfg, l) = layouts();
        for q in tpch::all_queries() {
            for rq in &q.rels {
                let c = Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols).unwrap();
                let v = virtualize(&c).expect("compiler output virtualizes");
                let p = realloc(
                    v.steps.clone(),
                    &v.blocks,
                    v.mask_col,
                    c.compute_base,
                    c.peak_inter_cells,
                )
                .expect("realloc within original peak");
                assert!(p.peak <= c.peak_inter_cells, "{}", q.name);

                // same random data columns, clean compute area, both ways
                let mut rng = Rng::new(0xA11C ^ q.name.len() as u64);
                let mut st = XbarState::new(cfg.xbar_cols);
                for col in 0..l.rel(rq.rel).compute_base {
                    for w in 0..WORDS {
                        st.planes[col][w] = rng.next_u64();
                    }
                }
                let mut s1 = vec![st];
                let mut s2 = s1.clone();
                let b = exec_steps_native(&mut s1, &c.steps, c.mask_col);
                let r = exec_steps_native(&mut s2, &p.steps, p.mask_col);
                assert_eq!(b.reduces, r.reduces, "{}/{}", q.name, rq.rel.name());
                assert_eq!(b.mask_counts, r.mask_counts, "{}", q.name);
            }
        }
    }

    #[test]
    fn realloc_reuses_dead_columns() {
        // Q1's per-group masks stack under LIFO; interval placement must
        // reuse them and shrink the peak
        let (cfg, l) = layouts();
        let q = tpch::query("Q1").unwrap();
        let rq = &q.rels[0];
        let c = Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols).unwrap();
        let v = virtualize(&c).unwrap();
        let p = realloc(v.steps, &v.blocks, v.mask_col, c.compute_base, c.peak_inter_cells)
            .unwrap();
        assert!(
            p.peak < c.peak_inter_cells,
            "Q1 peak {} -> {}",
            c.peak_inter_cells,
            p.peak
        );
    }

    #[test]
    fn virtual_blocks_are_disjoint_and_cover_spans() {
        let (cfg, l) = layouts();
        let q = tpch::query("Q5").unwrap();
        for rq in &q.rels {
            let c = Compiler::compile(rq, l.rel(rq.rel), cfg.xbar_cols).unwrap();
            let v = virtualize(&c).unwrap();
            assert_eq!(v.blocks.len(), c.spans.len());
            let mut edge = c.compute_base;
            for (b, s) in v.blocks.iter().zip(&c.spans) {
                assert_eq!(b.vstart, edge);
                assert_eq!(b.width, s.width);
                edge += b.width;
            }
        }
    }
}
