//! Multi-query scan fusion: cross-query common-subexpression DAG over a
//! batch of shared-scan filter prefixes.
//!
//! PR 6's shared-scan layer ([`super::sharedscan`]) amortizes scans only
//! between queries whose canonical filter-prefix keys are *byte-identical*
//! — replay, not merging. This pass closes ROADMAP item 3's other half
//! (the MQO batching of arXiv:1905.09822 / arXiv:2307.00658): it takes N
//! filter prefixes over the same relation and emits one *fused* program
//! that computes every query's mask in a single pass over the data,
//! computing each distinct subexpression once.
//!
//! The construction generalizes the within-query value-numbering CSE in
//! `passes::cse` to run *across* queries, in SSA form: every emitted write
//! allocates fresh fused compute columns (so a column is written exactly
//! once and its id doubles as its value number), and each member query
//! carries a private rename map from its original compute columns to
//! fused columns. A step whose `(opcode, immediate, width, operand value
//! numbers)` key was already computed by an earlier member is elided and
//! its destination renamed to the existing home — the cross-query CSE
//! DAG. Data columns (below `compute_base`) are shared inputs and pass
//! through unrenamed, exactly like the renaming normalization behind the
//! canonical scan key.
//!
//! Safety mirrors sharedscan's four checks, re-proved per member here
//! rather than trusted from the key: (1) no side-effect step (reduce /
//! column-transform) in a fused prefix; (2) every write lands at or above
//! `compute_base` (fresh fused columns, so members cannot alias each
//! other's intermediates); (3) every read is either a data column or a
//! compute column the member has already written (renames are dense, so
//! a read of a never-written compute column — which would observe zeroed
//! scratch — refuses fusion instead of aliasing another member); (4)
//! every multi-column operand renames *contiguously*. A member failing
//! any check falls back to a singleton [`FusedScan`] that runs its
//! original prefix unchanged; a member that would overflow the crossbar's
//! column budget closes the current chunk and starts a new one (greedy
//! packing), so `fuse` never fails — it degrades to per-query scans.

use std::collections::HashMap;

use super::passes;
use crate::pim::isa::{ColRange, Opcode};
use crate::query::compiler::Step;

/// One member query's shared-scan filter prefix, as split by
/// [`super::sharedscan::scan_info`]: `steps` are the program's first
/// `prefix_len` steps and `mask_col` is the filter-mask column the prefix
/// materializes.
#[derive(Clone, Copy, Debug)]
pub struct ScanProgram<'a> {
    /// The filter-prefix steps (side-effect free, compute-area writes).
    pub steps: &'a [Step],
    /// Column holding the member's filter mask after the prefix runs.
    pub mask_col: usize,
}

/// One fused scan program covering a subset of the input members.
#[derive(Clone, Debug)]
pub struct FusedScan {
    /// The fused steps: the union of the members' prefixes with
    /// cross-query common subexpressions computed once.
    pub steps: Vec<Step>,
    /// Fused mask column of each member, parallel to `members` (members
    /// with identical predicates share a column).
    pub mask_cols: Vec<usize>,
    /// Indices into the `fuse` input slice this chunk covers.
    pub members: Vec<usize>,
    /// Steps elided by the cross-query CSE (emitted = sum of member
    /// prefix lengths - saved).
    pub saved_steps: usize,
    /// Compute columns the fused program occupies above `compute_base`.
    pub peak_cols: usize,
}

impl FusedScan {
    /// A one-member chunk running the member's original prefix verbatim
    /// (the fallback when a member refuses fusion).
    fn singleton(idx: usize, p: &ScanProgram) -> FusedScan {
        FusedScan {
            steps: p.steps.to_vec(),
            mask_cols: vec![p.mask_col],
            members: vec![idx],
            saved_steps: 0,
            peak_cols: 0,
        }
    }
}

/// Why a member could not join the current fused chunk.
enum FuseErr {
    /// The member violates a fusion safety check; it can never fuse.
    Unfusable,
    /// The chunk's column budget is exhausted; retry in a fresh chunk.
    ChunkFull,
}

/// Value-number key of one step: two steps with equal keys compute the
/// same planes (operands are SSA ids: data column ids below
/// `compute_base`, write-once fused column ids above it).
#[derive(Clone, PartialEq, Eq, Hash)]
struct StepKey {
    op: u8,
    imm: u64,
    width: u16,
    la: usize,
    lb: usize,
    srcs: Vec<u32>,
}

/// Incremental fusion state for one chunk.
#[derive(Clone)]
struct Fuser {
    compute_base: usize,
    col_limit: usize,
    next_col: usize,
    table: HashMap<StepKey, usize>,
    steps: Vec<Step>,
    mask_cols: Vec<usize>,
    members: Vec<usize>,
    saved: usize,
}

impl Fuser {
    fn new(compute_base: usize, col_limit: usize) -> Fuser {
        Fuser {
            compute_base,
            col_limit,
            next_col: compute_base,
            table: HashMap::new(),
            steps: Vec::new(),
            mask_cols: Vec::new(),
            members: Vec::new(),
            saved: 0,
        }
    }

    /// Rename one member's source range: data ranges pass through,
    /// compute ranges must map contiguously onto already-written fused
    /// columns (safety checks 3 and 4). Only the first `read_len` columns
    /// are actually read by the engine; trailing unread columns of a
    /// wider field keep the mapped base without a contiguity obligation.
    fn rename_read(
        &self,
        remap: &HashMap<usize, usize>,
        r: ColRange,
        read_len: usize,
    ) -> Result<ColRange, FuseErr> {
        let s = r.start as usize;
        if s < self.compute_base {
            if s + read_len > self.compute_base {
                return Err(FuseErr::Unfusable);
            }
            return Ok(r);
        }
        let mapped0 = *remap.get(&s).ok_or(FuseErr::Unfusable)?;
        for k in 1..read_len {
            if remap.get(&(s + k)) != Some(&(mapped0 + k)) {
                return Err(FuseErr::Unfusable);
            }
        }
        Ok(ColRange::new(mapped0, r.len as usize))
    }

    /// Try to add member `idx`. On error the chunk state is unchanged
    /// only if the caller attempted on a clone (see [`fuse`]).
    fn add(&mut self, idx: usize, p: &ScanProgram) -> Result<(), FuseErr> {
        let mut remap: HashMap<usize, usize> = HashMap::new();
        for step in p.steps {
            let mut instr = step.instr.clone();
            if matches!(
                instr.op,
                Opcode::ReduceSum
                    | Opcode::ReduceMin
                    | Opcode::ReduceMax
                    | Opcode::ColumnTransform
            ) {
                return Err(FuseErr::Unfusable); // safety check 1
            }
            let (la, lb) = passes::read_lens(&instr);
            if la > 0 {
                instr.src_a = self.rename_read(&remap, instr.src_a, la)?;
            }
            if lb > 0 {
                let b = instr.src_b.expect("read_lens reported a second operand");
                instr.src_b = Some(self.rename_read(&remap, b, lb)?);
            }
            let (_, write) = passes::accesses(&instr);
            let w = write.expect("non-side-effect steps write");
            if (w.start as usize) < self.compute_base {
                return Err(FuseErr::Unfusable); // safety check 2
            }
            let srcs: Vec<u32> = {
                let mut v = Vec::with_capacity(la + lb);
                for k in 0..la {
                    v.push(instr.src_a.start as u32 + k as u32);
                }
                for k in 0..lb {
                    v.push(instr.src_b.expect("second operand").start as u32 + k as u32);
                }
                v
            };
            let key = StepKey {
                op: instr.op as u8,
                imm: if instr.op.has_imm() { instr.imm } else { 0 },
                width: w.len,
                la,
                lb,
                srcs,
            };
            let ww = w.len as usize;
            let w0 = w.start as usize;
            match self.table.get(&key) {
                Some(&home) => {
                    // cross-query CSE hit: rename instead of emitting
                    for k in 0..ww {
                        remap.insert(w0 + k, home + k);
                    }
                    self.saved += 1;
                }
                None => {
                    let at = self.next_col;
                    if at + ww > self.col_limit {
                        return Err(FuseErr::ChunkFull);
                    }
                    self.next_col = at + ww;
                    for k in 0..ww {
                        remap.insert(w0 + k, at + k);
                    }
                    self.table.insert(key, at);
                    instr.dst = ColRange::new(at, ww);
                    if la == 0 {
                        // Set/Reset read nothing: keep the cosmetic src_a
                        // field mirroring the destination (cse does the same)
                        instr.src_a = instr.dst;
                    }
                    self.steps.push(Step {
                        instr,
                        category: step.category,
                    });
                }
            }
        }
        let mask = *remap.get(&p.mask_col).ok_or(FuseErr::Unfusable)?;
        self.mask_cols.push(mask);
        self.members.push(idx);
        Ok(())
    }

    fn finish(self) -> FusedScan {
        FusedScan {
            peak_cols: self.next_col - self.compute_base,
            steps: self.steps,
            mask_cols: self.mask_cols,
            members: self.members,
            saved_steps: self.saved,
        }
    }
}

/// Fuse a batch of shared-scan prefixes over one relation into as few
/// fused programs as the crossbar's column budget allows.
///
/// `compute_base` is the relation's compute-area base (fused columns are
/// allocated upward from it) and `col_limit` the exclusive column bound
/// (the crossbar states' plane count). Members are packed greedily in
/// input order; a member that refuses fusion (see the module docs) comes
/// back as a singleton chunk running its original prefix, so every input
/// index appears in exactly one returned chunk.
pub fn fuse(programs: &[ScanProgram], compute_base: usize, col_limit: usize) -> Vec<FusedScan> {
    let mut out = Vec::new();
    let mut cur = Fuser::new(compute_base, col_limit);
    for (idx, p) in programs.iter().enumerate() {
        let mut trial = cur.clone();
        match trial.add(idx, p) {
            Ok(()) => cur = trial,
            Err(FuseErr::ChunkFull) if !cur.members.is_empty() => {
                out.push(cur.finish());
                cur = Fuser::new(compute_base, col_limit);
                let mut retry = cur.clone();
                match retry.add(idx, p) {
                    Ok(()) => cur = retry,
                    Err(_) => out.push(FusedScan::singleton(idx, p)),
                }
            }
            Err(_) => out.push(FusedScan::singleton(idx, p)),
        }
    }
    if !cur.members.is_empty() {
        out.push(cur.finish());
    }
    out
}

/// FNV-1a digest of a fusion result — the cross-language golden pin
/// shared with `python/fusionmirror.py` (each value folds in as 8
/// little-endian bytes; chunks are delimited by a marker byte).
pub fn digest(fused: &[FusedScan]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    let mut byte = |h: &mut u64, b: u8| {
        *h ^= b as u64;
        *h = h.wrapping_mul(PRIME);
    };
    let mut word = |h: &mut u64, v: u64| {
        for b in v.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(PRIME);
        }
    };
    for fs in fused {
        byte(&mut h, 0xF5);
        for step in &fs.steps {
            let i = &step.instr;
            word(&mut h, i.op as u64);
            word(&mut h, if i.op.has_imm() { i.imm } else { 0 });
            word(&mut h, i.src_a.start as u64);
            word(&mut h, i.src_a.len as u64);
            match i.src_b {
                Some(b) => {
                    word(&mut h, 1);
                    word(&mut h, b.start as u64);
                    word(&mut h, b.len as u64);
                }
                None => word(&mut h, 0),
            }
            word(&mut h, i.dst.start as u64);
            word(&mut h, i.dst.len as u64);
        }
        for &m in &fs.mask_cols {
            word(&mut h, m as u64);
        }
        for &m in &fs.members {
            word(&mut h, m as u64);
        }
        word(&mut h, fs.saved_steps as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;
    use crate::pim::isa::PimInstruction;

    const BASE: usize = 25;
    const VALID: usize = 24;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    /// `LtImm(attr < imm) -> tmp; And(tmp, VALID) -> mask` — the same
    /// shape sharedscan's tests use.
    fn lt_prefix(imm: u64, tmp: usize, mask: usize) -> Vec<Step> {
        vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 8),
                ColRange::new(tmp, 1),
                imm,
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(tmp, 1),
                ColRange::new(VALID, 1),
                ColRange::new(mask, 1),
            )),
        ]
    }

    #[test]
    fn fuse_dedups_cross_query_subexpressions() {
        // q1 shares q0's LtImm *and* its And-with-valid, then narrows
        // with an extra EqImm conjunct
        let p0 = lt_prefix(50, 26, 25);
        let mut p1 = lt_prefix(50, 30, 28);
        p1.push(step(PimInstruction::with_imm(
            Opcode::EqImm,
            ColRange::new(8, 8),
            ColRange::new(29, 1),
            3,
        )));
        p1.push(step(PimInstruction::binary(
            Opcode::And,
            ColRange::new(28, 1),
            ColRange::new(29, 1),
            ColRange::new(31, 1),
        )));
        let progs = [
            ScanProgram { steps: &p0, mask_col: 25 },
            ScanProgram { steps: &p1, mask_col: 31 },
        ];
        let fused = fuse(&progs, BASE, 64);
        assert_eq!(fused.len(), 1);
        let f = &fused[0];
        assert_eq!(f.members, vec![0, 1]);
        // 6 input steps, 2 elided (q1's LtImm and And-with-valid)
        assert_eq!(f.steps.len(), 4);
        assert_eq!(f.saved_steps, 2);
        assert_eq!(f.peak_cols, 4);
        // q0's mask is the shared And home; q1's is the final And
        assert_eq!(f.mask_cols, vec![BASE + 1, BASE + 3]);
        // byte-identical prefixes fuse to zero new steps and the same mask
        let fused2 = fuse(
            &[
                ScanProgram { steps: &p0, mask_col: 25 },
                ScanProgram { steps: &p0, mask_col: 25 },
            ],
            BASE,
            64,
        );
        assert_eq!(fused2.len(), 1);
        assert_eq!(fused2[0].steps.len(), 2);
        assert_eq!(fused2[0].mask_cols, vec![BASE + 1, BASE + 1]);
    }

    #[test]
    fn column_budget_overflow_starts_a_new_chunk() {
        let p0 = lt_prefix(10, 26, 25);
        let p1 = lt_prefix(20, 26, 25);
        let p2 = lt_prefix(30, 26, 25);
        let progs = [
            ScanProgram { steps: &p0, mask_col: 25 },
            ScanProgram { steps: &p1, mask_col: 25 },
            ScanProgram { steps: &p2, mask_col: 25 },
        ];
        // room for two members (2 cols each), not three
        let fused = fuse(&progs, BASE, BASE + 5);
        assert_eq!(fused.len(), 2);
        assert_eq!(fused[0].members, vec![0, 1]);
        assert_eq!(fused[1].members, vec![2]);
        // the second chunk re-bases its allocation at compute_base
        assert_eq!(fused[1].mask_cols, vec![BASE + 1]);
    }

    #[test]
    fn unsafe_members_fall_back_to_singletons() {
        // reads compute column 40 without ever writing it (would observe
        // zeroed scratch; fusing could alias another member's value)
        let bad = vec![step(PimInstruction::binary(
            Opcode::And,
            ColRange::new(40, 1),
            ColRange::new(VALID, 1),
            ColRange::new(25, 1),
        ))];
        let good = lt_prefix(7, 26, 25);
        let progs = [
            ScanProgram { steps: &bad, mask_col: 25 },
            ScanProgram { steps: &good, mask_col: 25 },
        ];
        let fused = fuse(&progs, BASE, 64);
        assert_eq!(fused.len(), 2);
        assert_eq!(fused[0].members, vec![0]);
        assert_eq!(fused[0].saved_steps, 0);
        // the singleton runs its original steps verbatim
        assert_eq!(fused[0].steps, bad);
        assert_eq!(fused[0].mask_cols, vec![25]);
        assert_eq!(fused[1].members, vec![1]);
    }

    #[test]
    fn golden_digest_matches_python_mirror() {
        // Pinned from python/fusionmirror.py over the identical input
        // (test_fusionmirror.py::test_golden_digest) — a change to either
        // side's key/DAG construction breaks the twin assertion there.
        let p0 = lt_prefix(50, 26, 25);
        let mut p1 = lt_prefix(50, 30, 28);
        p1.push(step(PimInstruction::with_imm(
            Opcode::GtImm,
            ColRange::new(8, 8),
            ColRange::new(29, 1),
            11,
        )));
        p1.push(step(PimInstruction::binary(
            Opcode::And,
            ColRange::new(28, 1),
            ColRange::new(29, 1),
            ColRange::new(31, 1),
        )));
        let p2 = lt_prefix(9, 27, 26);
        let progs = [
            ScanProgram { steps: &p0, mask_col: 25 },
            ScanProgram { steps: &p1, mask_col: 31 },
            ScanProgram { steps: &p2, mask_col: 26 },
        ];
        let fused = fuse(&progs, BASE, 64);
        assert_eq!(digest(&fused), 0x22A4_5855_9DAA_CA33);
    }
}
