//! Optimizing pass pipeline over compiled PIM programs.
//!
//! The compiler ([`crate::query::compiler`]) emits a naive linear
//! instruction stream: IN-sets start from an explicitly Reset mask,
//! repeated predicate sub-chains and the per-group arithmetic fields are
//! recomputed from scratch, and intermediate columns follow a LIFO
//! discipline that keeps dead columns allocated. Every wasted instruction
//! is charged to cycles, energy and endurance in Tables 5–6, so this
//! module interposes an optimizer between compilation and execution
//! (mirroring the explicit translation/optimization layer of Seshadri &
//! Mutlu's in-DRAM bulk-bitwise execution engine):
//!
//! * **IN-set prefix peephole** — `Reset m; Eq v0 -> t; Or(m,t)->m; ...`
//!   becomes `Eq v0 -> m; ...`, dropping the Reset and the first Or.
//! * **CSE** ([`passes::cse`]) — value-numbering elimination of repeated
//!   predicate sub-chains and arithmetic field chains (the Q1 per-group
//!   `(100-l_discount)`/`(100+l_tax)` fields, repeated dictionary Eqs).
//! * **Valid-AND elision** ([`passes::valid_elide`]) — the final
//!   `And(mask, VALID)` is dropped when a zero-row interpretation proves
//!   the predicate already rejects unoccupied rows.
//! * **Dead-step elimination** ([`passes::dce`]) — backward column-granular
//!   liveness from the mask column and the reduce reads.
//! * **Lifetime reallocation** ([`alloc::realloc`]) — replaces the LIFO
//!   column discipline with first-fit allocation over actual live
//!   intervals, shrinking `peak_inter_cells` (Table 5 "Inter. cells").
//! * **Shared-scan analysis** ([`sharedscan`]) — the cross-*query*
//!   generalization of the value-numbering CSE: each optimized program
//!   is split at its last mask write and the filter prefix is keyed by
//!   a renaming-normalized serialization, so the service handle can run
//!   one shared scan for many prepared queries over a relation.
//! * **Multi-query scan fusion** ([`fusion`]) — the batching half of the
//!   shared-scan story: N distinct filter prefixes over one relation are
//!   value-numbered *across* queries into a single fused program with one
//!   mask output per member, so a batch pays for each distinct
//!   subexpression once instead of once per query.
//!
//! Correctness contract (enforced by `tests/opt_equivalence.rs`): `-O2`
//! outputs are bit-identical to `-O0` for every query, total cycles never
//! increase, and the intermediate-cell peak never grows. Passes only ever
//! delete or rename; every fallible transform falls back to the safe
//! `-O1` (peephole + valid-elide + DCE, original columns) and `-O1` falls
//! back to the untouched program at `-O0`.

mod alloc;
pub mod fusion;
mod passes;
pub mod prune;
pub mod sharedscan;

use std::fmt;
use std::str::FromStr;

use crate::pim::controller::cost;

use super::compiler::{CompiledRelQuery, Step};

/// Optimization level for compiled PIM programs (`-O0`..`-O2`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// No passes: execute the compiler's naive stream (golden reference).
    O0,
    /// Local cleanups only: IN-set prefix peephole, valid-AND elision,
    /// dead-step elimination. Column placement is untouched.
    O1,
    /// `-O1` plus value-numbering CSE over a virtualized (reuse-free)
    /// column space and lifetime-based column reallocation.
    #[default]
    O2,
}

impl OptLevel {
    /// All levels, lowest first.
    pub const ALL: [OptLevel; 3] = [OptLevel::O0, OptLevel::O1, OptLevel::O2];
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptLevel::O0 => write!(f, "O0"),
            OptLevel::O1 => write!(f, "O1"),
            OptLevel::O2 => write!(f, "O2"),
        }
    }
}

impl FromStr for OptLevel {
    type Err = String;

    /// Accepts `0|1|2`, `O0|O1|O2` and `-O0|-O1|-O2` (case-insensitive).
    fn from_str(s: &str) -> Result<OptLevel, String> {
        let t = s.trim().trim_start_matches('-');
        let t = t.strip_prefix(['o', 'O']).unwrap_or(t);
        match t {
            "0" => Ok(OptLevel::O0),
            "1" => Ok(OptLevel::O1),
            "2" => Ok(OptLevel::O2),
            _ => Err(format!("bad opt level '{s}' (expected -O0, -O1 or -O2)")),
        }
    }
}

/// What the pass pipeline did to one relation's program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptStats {
    /// Instructions before any pass ran.
    pub steps_before: usize,
    /// Instructions in the executed program.
    pub steps_after: usize,
    /// Per-crossbar stateful-logic cycles before passes.
    pub cycles_before: u64,
    /// Per-crossbar cycles of the executed program.
    pub cycles_after: u64,
    /// Peak intermediate cells before passes (LIFO allocator).
    pub inter_before: usize,
    /// Peak intermediate cells of the executed program.
    pub inter_after: usize,
}

impl OptStats {
    /// Fold another relation's stats into a per-query summary: step and
    /// cycle counts add, the cell peaks take the max (Table 5 semantics).
    pub fn merge(&mut self, other: &OptStats) {
        self.steps_before += other.steps_before;
        self.steps_after += other.steps_after;
        self.cycles_before += other.cycles_before;
        self.cycles_after += other.cycles_after;
        self.inter_before = self.inter_before.max(other.inter_before);
        self.inter_after = self.inter_after.max(other.inter_after);
    }
}

/// Total per-crossbar stateful-logic cycles of a program (cost model of
/// [`crate::pim::controller`], same accounting as Table 5).
pub fn program_cycles(steps: &[Step], xbar_rows: usize) -> u64 {
    steps
        .iter()
        .map(|s| cost(&s.instr, xbar_rows).total_cycles())
        .sum()
}

/// Run the pass pipeline over one compiled relation program.
///
/// The returned program is functionally bit-identical to the input for
/// every crossbar content: passes only delete provably redundant steps or
/// rename intermediate columns. Cycles and `peak_inter_cells` never
/// increase; any transform that cannot prove itself safe falls back to
/// the next-lower level.
pub fn optimize(
    c: &CompiledRelQuery,
    level: OptLevel,
    xbar_rows: usize,
) -> (CompiledRelQuery, OptStats) {
    optimize_with_stats(c, level, xbar_rows, None)
}

/// [`optimize`] with an optional zone-map selectivity model: when
/// present, `-O2` additionally runs the cost-based predicate-ordering
/// pass ([`prune`]), permuting commutative AND-chain segments
/// most-selective-then-cheapest-first so the runtime all-zero
/// short-circuit fires as early as possible. The permutation preserves
/// the instruction multiset, so cycles, wear and the cell peak are
/// untouched; without a model the pipeline is byte-identical to
/// [`optimize`].
pub fn optimize_with_stats(
    c: &CompiledRelQuery,
    level: OptLevel,
    xbar_rows: usize,
    sel: Option<&prune::SelectivityModel<'_>>,
) -> (CompiledRelQuery, OptStats) {
    let mut stats = OptStats {
        steps_before: c.steps.len(),
        cycles_before: program_cycles(&c.steps, xbar_rows),
        inter_before: c.peak_inter_cells,
        steps_after: c.steps.len(),
        cycles_after: 0,
        inter_after: c.peak_inter_cells,
    };
    if level == OptLevel::O0 {
        stats.cycles_after = stats.cycles_before;
        return (c.clone(), stats);
    }

    let out = if level == OptLevel::O2 {
        run_o2(c, xbar_rows, sel).unwrap_or_else(|| run_o1(c))
    } else {
        run_o1(c)
    };

    stats.steps_after = out.steps.len();
    stats.cycles_after = program_cycles(&out.steps, xbar_rows);
    stats.inter_after = out.peak_inter_cells;
    debug_assert!(stats.cycles_after <= stats.cycles_before);
    debug_assert!(stats.inter_after <= stats.inter_before);
    (out, stats)
}

/// `-O1`: local passes on the original (physical-column) program. Column
/// placement — and therefore `peak_inter_cells` — is left untouched. The
/// span metadata is dropped: its `born_step` indices point into the
/// pre-pass stream, and rather than ship stale def/use data the program
/// declares none (a re-`optimize` then degrades gracefully to the local
/// passes, which are idempotent).
fn run_o1(c: &CompiledRelQuery) -> CompiledRelQuery {
    let steps = passes::peephole_in_set(c.steps.clone(), c.mask_col);
    let steps = passes::valid_elide(steps, c.valid_col);
    let steps = passes::dce(steps, c.mask_col);
    CompiledRelQuery {
        steps,
        spans: Vec::new(),
        ..c.clone()
    }
}

/// `-O2`: virtualize columns (undo LIFO reuse via the compiler's span
/// metadata), run peephole + CSE + valid-elide + DCE in the reuse-free
/// space — then, when a selectivity model is supplied, reorder the
/// commutative AND-chain segments ([`prune::SelectivityModel`]; the
/// virtual space is where segments are naturally column-disjoint) — and
/// finally reallocate columns by live interval. `None` when any stage
/// cannot prove itself safe or the reallocation would not keep the cell
/// peak within the original (the caller then uses `-O1`).
fn run_o2(
    c: &CompiledRelQuery,
    xbar_rows: usize,
    sel: Option<&prune::SelectivityModel<'_>>,
) -> Option<CompiledRelQuery> {
    let virt = alloc::virtualize(c)?;
    let steps = passes::peephole_in_set(virt.steps, virt.mask_col);
    let (steps, mask_col) = passes::cse(steps, virt.mask_col, c.compute_base)?;
    let steps = passes::valid_elide(steps, c.valid_col);
    let steps = passes::dce(steps, mask_col);
    let steps = if sel.is_some() {
        prune::reorder_mask_prefix(&steps, mask_col, xbar_rows, sel).unwrap_or(steps)
    } else {
        steps
    };
    let placed = alloc::realloc(
        steps,
        &virt.blocks,
        mask_col,
        c.compute_base,
        c.peak_inter_cells,
    )?;
    Some(CompiledRelQuery {
        steps: placed.steps,
        mask_col: placed.mask_col,
        peak_inter_cells: placed.peak,
        spans: placed.spans,
        ..c.clone()
    })
}

/// Render a program as a disassembly listing, one instruction per line.
pub fn disasm(steps: &[Step]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for (i, step) in steps.iter().enumerate() {
        writeln!(s, "  {i:>4}: {step}").unwrap();
    }
    s
}

/// `pimdb run --explain`: per-relation disassembly of one query's compiled
/// programs before and after the pass pipeline, with the cycle/cell delta.
pub fn explain_query(
    q: &crate::query::ast::Query,
    layout: &crate::db::layout::DbLayout,
    xbar_cols: usize,
    xbar_rows: usize,
    level: OptLevel,
) -> Result<String, crate::query::compiler::CompileError> {
    use std::fmt::Write;
    use super::compiler::Compiler;
    let mut s = String::new();
    writeln!(s, "== explain {} (-{level}) ==", q.name).unwrap();
    for rq in &q.rels {
        let c = Compiler::compile(rq, layout.rel(rq.rel), xbar_cols)?;
        let (opt, st) = optimize(&c, level, xbar_rows);
        writeln!(
            s,
            "-- {}: before passes ({} steps, {} cycles, {} inter cells) --",
            rq.rel.name(),
            st.steps_before,
            st.cycles_before,
            st.inter_before
        )
        .unwrap();
        s.push_str(&disasm(&c.steps));
        writeln!(
            s,
            "-- {}: after passes ({} steps, {} cycles, {} inter cells, mask c{}) --",
            rq.rel.name(),
            st.steps_after,
            st.cycles_after,
            st.inter_after,
            opt.mask_col
        )
        .unwrap();
        s.push_str(&disasm(&opt.steps));
    }
    Ok(s)
}

/// `pimdb run --explain` for DML: render the compiled statement — the
/// row-write image for INSERT, the filter + mutation instruction stream
/// for UPDATE/DELETE. DML programs bypass the pass pipeline (they are
/// straight-line filter + write streams with nothing to elide), so there
/// is no before/after split.
pub fn explain_dml(
    d: &crate::query::ast::Dml,
    layout: &crate::db::layout::DbLayout,
    xbar_cols: usize,
    xbar_rows: usize,
) -> Result<String, crate::query::compiler::CompileError> {
    use super::compiler::{compile_dml, CompiledDmlOp};
    use std::fmt::Write;
    let mut s = String::new();
    let c = compile_dml(d, layout.rel(d.rel()), xbar_cols)?;
    writeln!(s, "== explain {} on {} ==", d.kind_name(), d.rel().name()).unwrap();
    match &c.op {
        CompiledDmlOp::Insert {
            fields,
            valid_col,
            row_bits,
        } => {
            writeln!(
                s,
                "-- row-wise host write: {row_bits} bits incl. VALID c{valid_col} \
                 (endurance-aware free-row placement) --"
            )
            .unwrap();
            for &(start, bits, value) in fields {
                writeln!(s, "  write [c{start}+{bits}] <- {value}").unwrap();
            }
        }
        CompiledDmlOp::Mask {
            steps,
            mask_col,
            peak_inter_cells,
            deletes,
            ..
        } => {
            writeln!(
                s,
                "-- column-wise {} program ({} steps, {} cycles, {} inter cells, mask c{}) --",
                if *deletes { "delete" } else { "update" },
                steps.len(),
                program_cycles(steps, xbar_rows),
                peak_inter_cells,
                mask_col
            )
            .unwrap();
            s.push_str(&disasm(steps));
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::db::layout::DbLayout;
    use crate::query::compiler::Compiler;
    use crate::query::tpch;

    fn compile_all(level: OptLevel) -> Vec<(String, CompiledRelQuery, OptStats)> {
        let cfg = SystemConfig::default();
        let layout = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        let mut out = Vec::new();
        for q in tpch::all_queries() {
            for rq in &q.rels {
                let c = Compiler::compile(rq, layout.rel(rq.rel), cfg.xbar_cols).unwrap();
                let (o, st) = optimize(&c, level, cfg.xbar_rows);
                out.push((format!("{}/{}", q.name, rq.rel.name()), o, st));
            }
        }
        out
    }

    #[test]
    fn opt_level_parses_all_spellings() {
        for s in ["0", "O0", "o0", "-O0"] {
            assert_eq!(s.parse::<OptLevel>().unwrap(), OptLevel::O0);
        }
        assert_eq!("2".parse::<OptLevel>().unwrap(), OptLevel::O2);
        assert_eq!("-O1".parse::<OptLevel>().unwrap(), OptLevel::O1);
        assert!("3".parse::<OptLevel>().is_err());
        assert!("fast".parse::<OptLevel>().is_err());
        assert_eq!(OptLevel::default(), OptLevel::O2);
        assert_eq!(OptLevel::O2.to_string(), "O2");
    }

    #[test]
    fn o0_is_identity() {
        for (name, _o, st) in compile_all(OptLevel::O0) {
            assert_eq!(st.steps_before, st.steps_after, "{name}");
            assert_eq!(st.cycles_before, st.cycles_after, "{name}");
            assert_eq!(st.inter_before, st.inter_after, "{name}");
        }
    }

    #[test]
    fn o2_never_regresses_cycles_or_cells() {
        for (name, _o, st) in compile_all(OptLevel::O2) {
            assert!(st.cycles_after <= st.cycles_before, "{name}");
            assert!(st.inter_after <= st.inter_before, "{name}");
            assert!(st.steps_after <= st.steps_before, "{name}");
        }
    }

    #[test]
    fn o2_strictly_improves_most_programs() {
        let all = compile_all(OptLevel::O2);
        let improved = all
            .iter()
            .filter(|(_, _, st)| st.cycles_after < st.cycles_before)
            .count();
        // the pipeline must find real waste in the naive streams
        assert!(
            improved * 2 > all.len(),
            "only {improved}/{} programs improved",
            all.len()
        );
    }

    #[test]
    fn q1_group_arithmetic_collapses_at_o2() {
        // the per-group (100-discount)/(100+tax) chains are recomputed 6x
        // by the naive compiler; CSE + DCE must collapse the repeats
        let cfg = SystemConfig::default();
        let layout = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        let q = tpch::query("Q1").unwrap();
        let rq = &q.rels[0];
        let c = Compiler::compile(rq, layout.rel(rq.rel), cfg.xbar_cols).unwrap();
        let (o, st) = optimize(&c, OptLevel::O2, cfg.xbar_rows);
        assert!(
            st.steps_after + 20 < st.steps_before,
            "Q1 {} -> {} steps",
            st.steps_before,
            st.steps_after
        );
        assert!(st.cycles_after < st.cycles_before);
        // reduces are never touched: output geometry intact
        assert_eq!(o.n_reduces, c.n_reduces);
        assert_eq!(o.groups, c.groups);
    }

    #[test]
    fn explain_dml_renders_every_statement_kind() {
        use crate::db::schema::RelId;
        use crate::query::ast::{CmpOp, Dml, Pred};
        let cfg = SystemConfig::default();
        let layout = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        let del = Dml::Delete {
            rel: RelId::Supplier,
            filter: Pred::CmpImm {
                attr: "s_suppkey",
                op: CmpOp::Lt,
                value: 5,
            },
        };
        let text = explain_dml(&del, &layout, cfg.xbar_cols, cfg.xbar_rows).unwrap();
        assert!(text.contains("explain delete on SUPPLIER"), "{text}");
        assert!(text.contains("column-wise delete program"), "{text}");
        assert!(text.contains("lt_imm"), "{text}");
        assert!(text.contains("column_transform"), "{text}");

        let upd = Dml::Update {
            rel: RelId::Supplier,
            filter: Pred::True,
            sets: vec![("s_nationkey", 3)],
        };
        let text = explain_dml(&upd, &layout, cfg.xbar_cols, cfg.xbar_rows).unwrap();
        assert!(text.contains("column-wise update program"), "{text}");

        let ins = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_suppkey", 42)],
        };
        let text = explain_dml(&ins, &layout, cfg.xbar_cols, cfg.xbar_rows).unwrap();
        assert!(text.contains("row-wise host write"), "{text}");
        assert!(text.contains("<- 42"), "{text}");
    }

    #[test]
    fn disasm_lists_every_step() {
        let cfg = SystemConfig::default();
        let layout = DbLayout::build(&cfg, &|r| r.records_at_sf(0.01)).unwrap();
        let q = tpch::query("Q6").unwrap();
        let c = Compiler::compile(&q.rels[0], layout.rel(q.rels[0].rel), cfg.xbar_cols).unwrap();
        let d = disasm(&c.steps);
        assert_eq!(d.lines().count(), c.steps.len());
        assert!(d.contains("reduce_sum"));
        let e = explain_query(&q, &layout, cfg.xbar_cols, cfg.xbar_rows, OptLevel::O2).unwrap();
        assert!(e.contains("before passes"));
        assert!(e.contains("after passes"));
    }
}
