//! Shared-scan analysis: split an optimized program into a *filter
//! prefix* (everything up to and including the last write of the mask
//! column) and a *suffix* (group masks, arithmetic, reduces, read-out),
//! and derive a canonical byte key for the prefix such that **byte
//! equality of keys implies the prefixes compute the identical mask
//! function** over the relation's data and VALID columns.
//!
//! When the [`crate::api::Pimdb`] plan cache holds several prepared
//! queries over one relation whose filter prefixes agree — the same
//! predicate compiled into different plans (different aggregates, or a
//! filter-only twin), possibly with *different* compute-column placement
//! after `-O2` lifetime reallocation — the handle executes the shared
//! prefix once, caches the resulting mask planes per relation, and
//! replays them into every later consumer, executing only its suffix
//! (paper §4: the scan is the dominant phase of every bulk-bitwise
//! query, so sharing it across a prepared workload amortizes the
//! per-query bit-serial compare chains).
//!
//! The key is *renaming-normalized*: compute-area columns (at or above
//! `compute_base`) are mapped to canonical ids in order of first
//! appearance, while data and VALID columns keep their absolute ids.
//! Two prefixes that differ only in scratch-column placement therefore
//! key identically; anything that can change the mask function — opcode,
//! widths, immediates, data columns read, the mask column's role — is in
//! the byte stream. The analysis is conservative: any shape it cannot
//! prove safe yields `None` and the program simply runs unshared.

use crate::pim::isa::{ColRange, Opcode};
use crate::query::compiler::CompiledRelQuery;

use super::passes::accesses;

/// Shared-scan metadata of one compiled relation program, computed once
/// at prepare time and stored alongside the cached plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScanInfo {
    /// Steps `[0, prefix_len)` are the shared filter prefix; the suffix
    /// starts at `prefix_len`.
    pub prefix_len: usize,
    /// Canonical renaming-normalized serialization of the prefix. Equal
    /// bytes (for programs over the same relation) imply the identical
    /// mask function into the mask column.
    pub key: Vec<u8>,
}

/// Analyze one optimized program. `None` when the program has no mask
/// write or any safety condition fails (the caller runs it unshared):
///
/// 1. the prefix contains no side-effecting step (a reduce's output
///    would be lost when the prefix is skipped);
/// 2. the prefix writes only compute-area columns (its mask is then a
///    pure function of data/VALID columns and the zeroed compute area);
/// 3. replaying only the mask planes reproduces what the suffix
///    observes: every suffix read of a prefix-written compute column
///    other than the mask column must be overwritten by the suffix
///    first (compute columns the prefix dirtied are zero on the replay
///    path — `clear_compute` re-zeroes them after every execution);
/// 4. every operand range normalizes contiguously (see [`scan_key`]).
pub fn scan_info(c: &CompiledRelQuery) -> Option<ScanInfo> {
    let prefix_len = split_point(c)?;
    // (1) no side effects inside the prefix
    if c.steps[..prefix_len].iter().any(|s| {
        matches!(
            s.instr.op,
            Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax | Opcode::ColumnTransform
        )
    }) {
        return None;
    }
    let mut prefix_written = vec![false; cols_bound(c)];
    for s in &c.steps[..prefix_len] {
        let (_, write) = accesses(&s.instr);
        if let Some(w) = write {
            // (2) prefix writes stay inside the compute area
            if (w.start as usize) < c.compute_base {
                return None;
            }
            for col in w.start as usize..w.end() {
                prefix_written[col] = true;
            }
        }
    }
    // (3) suffix reads of prefix-written columns: mask only, or
    // written-before-read within the suffix itself
    let mut suffix_written = vec![false; prefix_written.len()];
    for s in &c.steps[prefix_len..] {
        let (reads, write) = accesses(&s.instr);
        for r in &reads {
            for col in r.start as usize..r.end() {
                if col != c.mask_col && prefix_written[col] && !suffix_written[col] {
                    return None;
                }
            }
        }
        if let Some(w) = write {
            for col in w.start as usize..w.end() {
                suffix_written[col] = true;
            }
        }
    }
    let key = scan_key(c, prefix_len)?;
    Some(ScanInfo { prefix_len, key })
}

/// One past the last write to the mask column; `None` when nothing
/// writes it. By construction no suffix step writes the mask column, so
/// the mask planes at program end equal the mask planes at the split —
/// the miss path can capture them after a full run.
fn split_point(c: &CompiledRelQuery) -> Option<usize> {
    let mut last = None;
    for (i, s) in c.steps.iter().enumerate() {
        let (_, write) = accesses(&s.instr);
        if write.is_some_and(|w| (w.start as usize) <= c.mask_col && c.mask_col < w.end()) {
            last = Some(i);
        }
    }
    last.map(|i| i + 1)
}

fn cols_bound(c: &CompiledRelQuery) -> usize {
    let mut m = c.mask_col + 1;
    for s in &c.steps {
        let (reads, write) = accesses(&s.instr);
        for r in reads.iter().chain(write.iter()) {
            m = m.max(r.end());
        }
    }
    m
}

/// Canonical-id assigner: data/VALID columns (below `compute_base`) keep
/// their absolute id; compute-area columns get sequential ids starting
/// at `CANON_BASE` in order of first appearance.
struct Canon {
    compute_base: usize,
    map: Vec<Option<u32>>,
    next: u32,
}

/// Canonical ids of compute-area columns start here — far above any
/// physical column id, so the two id spaces cannot collide in the key.
const CANON_BASE: u32 = 1 << 20;

impl Canon {
    fn new(compute_base: usize, ncols: usize) -> Canon {
        Canon {
            compute_base,
            map: vec![None; ncols],
            next: CANON_BASE,
        }
    }

    fn id(&mut self, col: usize) -> u32 {
        if col < self.compute_base {
            return col as u32;
        }
        // serialized operand ranges are the instructions' raw ranges,
        // which can reach past the clipped-access bound the map was
        // sized from (e.g. a source wider than its read)
        if col >= self.map.len() {
            self.map.resize(col + 1, None);
        }
        *self.map[col].get_or_insert_with(|| {
            let id = self.next;
            self.next += 1;
            id
        })
    }

    /// Canonical (start, len) of a range, `None` when its columns do not
    /// normalize to consecutive ids (a range straddling the data/compute
    /// boundary, or interleaving two previously-seen scratch regions —
    /// such a prefix is not safely renamable, so the program runs
    /// unshared).
    fn range(&mut self, r: ColRange) -> Option<(u32, u16)> {
        let first = self.id(r.start as usize);
        for k in 1..r.len as usize {
            if self.id(r.start as usize + k) != first + k as u32 {
                return None;
            }
        }
        Some((first, r.len as u16))
    }
}

/// Serialize the prefix under first-appearance renaming. The stream
/// covers everything the mask function depends on: per step the opcode,
/// immediate (for immediate-carrying ops), and each operand range as
/// `(canonical start, len)`; the trailer is the canonical id of the
/// mask column, so two prefixes only match when their result lands in
/// the same (renamed) place.
fn scan_key(c: &CompiledRelQuery, prefix_len: usize) -> Option<Vec<u8>> {
    let mut canon = Canon::new(c.compute_base, cols_bound(c));
    let mut buf: Vec<u8> = Vec::with_capacity(prefix_len * 16);
    for s in &c.steps[..prefix_len] {
        let i = &s.instr;
        buf.push(i.op as u8);
        if i.op.has_imm() {
            buf.extend_from_slice(&i.imm.to_le_bytes());
        }
        let mut put = |r: ColRange, canon: &mut Canon| -> Option<()> {
            let (start, len) = canon.range(r)?;
            buf.extend_from_slice(&start.to_le_bytes());
            buf.extend_from_slice(&len.to_le_bytes());
            Some(())
        };
        put(i.src_a, &mut canon)?;
        match i.src_b {
            Some(b) => {
                buf.push(1);
                put(b, &mut canon)?;
            }
            None => buf.push(0),
        }
        put(i.dst, &mut canon)?;
    }
    let mask_id = canon.id(c.mask_col);
    buf.extend_from_slice(&mask_id.to_le_bytes());
    Some(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;
    use crate::pim::isa::PimInstruction;
    use crate::query::compiler::{CompiledRelQuery, ReadKind, Step};

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    /// A minimal program shell: data cols [0, 24), VALID at 24, compute
    /// area from 25.
    fn program(steps: Vec<Step>, mask_col: usize) -> CompiledRelQuery {
        CompiledRelQuery {
            rel: crate::db::schema::RelId::Supplier,
            steps,
            read: ReadKind::FilterMask,
            groups: vec![],
            outputs: vec![],
            n_reduces: 0,
            mask_col,
            peak_inter_cells: 0,
            spans: vec![],
            compute_base: 25,
            valid_col: 24,
        }
    }

    fn filter_steps(mask: usize, tmp: usize) -> Vec<Step> {
        let a = ColRange::new(0, 8);
        vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                a,
                ColRange::new(tmp, 1),
                50,
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(tmp, 1),
                ColRange::new(24, 1),
                ColRange::new(mask, 1),
            )),
        ]
    }

    #[test]
    fn split_covers_last_mask_write_and_key_is_renaming_invariant() {
        let mut p1 = filter_steps(30, 26);
        p1.push(step(PimInstruction::unary(
            Opcode::ReduceSum,
            ColRange::new(30, 1),
            ColRange::new(30, 1),
        )));
        let c1 = program(p1, 30);
        let i1 = scan_info(&c1).expect("shareable");
        assert_eq!(i1.prefix_len, 2);

        // same mask function, every compute column somewhere else
        let mut p2 = filter_steps(41, 33);
        p2.push(step(PimInstruction::unary(
            Opcode::ReduceSum,
            ColRange::new(41, 1),
            ColRange::new(41, 1),
        )));
        let c2 = program(p2, 41);
        let i2 = scan_info(&c2).expect("shareable");
        assert_eq!(i1.key, i2.key, "renaming must not change the key");
    }

    #[test]
    fn key_is_sensitive_to_immediates_data_columns_and_opcodes() {
        let base = scan_info(&program(filter_steps(30, 26), 30)).unwrap();
        // different immediate
        let mut other = filter_steps(30, 26);
        other[0].instr.imm = 51;
        assert_ne!(base.key, scan_info(&program(other, 30)).unwrap().key);
        // different data column
        let mut other = filter_steps(30, 26);
        other[0].instr.src_a = ColRange::new(8, 8);
        assert_ne!(base.key, scan_info(&program(other, 30)).unwrap().key);
        // different opcode
        let mut other = filter_steps(30, 26);
        other[0].instr.op = Opcode::GtImm;
        assert_ne!(base.key, scan_info(&program(other, 30)).unwrap().key);
    }

    #[test]
    fn reduce_inside_prefix_bails() {
        let a = ColRange::new(0, 8);
        let m = ColRange::new(30, 1);
        let p = vec![
            step(PimInstruction::with_imm(Opcode::LtImm, a, m, 50)),
            step(PimInstruction::unary(Opcode::ReduceSum, a, a)),
            // a second mask write pulls the reduce into the prefix
            step(PimInstruction::with_imm(Opcode::LtImm, a, m, 50)),
        ];
        assert!(scan_info(&program(p, 30)).is_none());
    }

    #[test]
    fn suffix_read_of_prefix_temp_bails_unless_rewritten_first() {
        let a = ColRange::new(0, 8);
        let t = ColRange::new(26, 1);
        let m = ColRange::new(30, 1);
        // suffix reads the prefix temp t directly: not replayable
        let p = vec![
            step(PimInstruction::with_imm(Opcode::LtImm, a, t, 50)),
            step(PimInstruction::binary(Opcode::And, t, ColRange::new(24, 1), m)),
            step(PimInstruction::binary(Opcode::And, a, t, ColRange::new(40, 8))),
        ];
        assert!(scan_info(&program(p, 30)).is_none());

        // suffix overwrites t before reading it: replayable
        let p = vec![
            step(PimInstruction::with_imm(Opcode::LtImm, a, t, 50)),
            step(PimInstruction::binary(Opcode::And, t, ColRange::new(24, 1), m)),
            step(PimInstruction::with_imm(Opcode::GtImm, a, t, 3)),
            step(PimInstruction::binary(Opcode::And, a, t, ColRange::new(40, 8))),
        ];
        let info = scan_info(&program(p, 30)).expect("write-before-read is safe");
        assert_eq!(info.prefix_len, 2);
    }

    #[test]
    fn programs_without_mask_writes_are_not_shareable() {
        let a = ColRange::new(0, 8);
        let p = vec![step(PimInstruction::unary(Opcode::ReduceSum, a, a))];
        assert!(scan_info(&program(p, 30)).is_none());
    }
}
