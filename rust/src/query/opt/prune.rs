//! Statistics-driven pruning: per-crossbar skip bitmaps, the runtime
//! all-zero-mask short-circuit schedule, and the cost-based predicate
//! ordering pass.
//!
//! Three cooperating mechanisms, all fed by the zone maps of
//! [`crate::db::stats`]:
//!
//! * **Plan-time skip bitmaps** ([`skip_bitmap`]) — a conservative
//!   decision procedure proves a filter predicate disjoint from a
//!   crossbar's zones, so the crossbar's mask is all-zero and the
//!   executor can skip it entirely: it contributes zero selected rows,
//!   the identity element of every masked aggregate, and an all-zero
//!   cached mask plane.
//! * **Runtime short-circuit schedule** ([`short_circuit`]) — step
//!   indices after which the engine tests the freshly written mask plane
//!   for all-zero ([`crate::util::bits::is_zero_words`], a lane-folded
//!   `U64x4` check) and, on zero, abandons the remaining filter steps by
//!   jumping straight to the post-mask suffix.
//! * **Predicate reordering** ([`SelectivityModel`], run inside `-O2`
//!   when stats are supplied to
//!   [`super::optimize_with_stats`]) — commutative AND-chain segments
//!   are permuted most-selective-then-cheapest-first so the runtime
//!   short-circuit fires as early as possible.
//!
//! Soundness. The executed mask is `filter AND VALID` (the compiler
//! appends the valid-AND, or elides it only after a zero-row abstract
//! interpretation proves the filter already rejects unoccupied rows —
//! and dead rows hold all-zero data by the store invariant), and zones
//! cover exactly the live rows; a predicate disjoint from a crossbar's
//! live zone therefore proves the *final* mask zero. Reordering permutes
//! only whole sub-predicate segments between mask-combine steps — AND is
//! commutative and associative on bit-planes — and bails to the identity
//! unless the segments are pairwise independent, touch the mask only
//! through their final combine, and contain no side-effecting steps.
//! Every decision is mirrored line-by-line in `python/statsmirror.py`
//! and fuzzed against a scan-everything oracle.

use std::collections::BTreeSet;

use crate::db::layout::RelationLayout;
use crate::db::stats::{ColZone, RelStats, XbarStats};
use crate::pim::isa::{ColRange, Opcode, PimInstruction};
use crate::query::ast::{CmpOp, Pred};
use crate::query::compiler::Step;

use super::passes;
use super::program_cycles;

// --- plan-time skip bitmaps -------------------------------------------------

/// Per-crossbar skip bitmap of `filter` under `stats`: `true` at index
/// `x` proves the compiled mask is all-zero on crossbar `x`, so the
/// executor may skip it. Conservative: `false` never lies, `true` is a
/// proof.
pub fn skip_bitmap(filter: &Pred, layout: &RelationLayout, stats: &RelStats) -> Vec<bool> {
    stats
        .xbars
        .iter()
        .map(|x| pred_disjoint(filter, layout, x))
        .collect()
}

/// Whether `p` provably selects no live row of a crossbar with stats
/// `x` — the single-crossbar kernel of [`skip_bitmap`].
pub fn pred_disjoint(p: &Pred, layout: &RelationLayout, x: &XbarStats) -> bool {
    if x.live_rows == 0 {
        return true;
    }
    match p {
        Pred::True => false,
        Pred::CmpImm { attr, op, value } => match zone_of(layout, x, attr) {
            Some(z) => cmp_disjoint(z, *op, *value),
            None => false,
        },
        Pred::InSet { attr, values } => match zone_of(layout, x, attr) {
            // vacuously disjoint when the set is empty (IN () is false)
            Some(z) => values.iter().all(|&v| eq_disjoint(z, v)),
            None => false,
        },
        Pred::Between { attr, lo, hi } => {
            if lo > hi {
                return true;
            }
            match zone_of(layout, x, attr) {
                Some(z) => *hi < z.min || *lo > z.max,
                None => false,
            }
        }
        Pred::And(ps) => ps.iter().any(|p| pred_disjoint(p, layout, x)),
        // vacuously disjoint when empty (the compiler lowers OR () to a
        // Reset mask)
        Pred::Or(ps) => ps.iter().all(|p| pred_disjoint(p, layout, x)),
        // no zone reasoning for negations or column-column compares
        Pred::Not(_) | Pred::CmpCols { .. } => false,
    }
}

/// The zone of `attr` on one crossbar, if the relation has that slot.
fn zone_of<'a>(layout: &RelationLayout, x: &'a XbarStats, attr: &str) -> Option<&'a ColZone> {
    layout
        .slots
        .iter()
        .position(|s| s.attr.name == attr)
        .and_then(|i| x.zones.get(i))
}

/// `attr == v` selects nothing: outside [min, max], or absent from the
/// dictionary presence bitmap.
fn eq_disjoint(z: &ColZone, v: u64) -> bool {
    v < z.min || v > z.max || z.dict.is_some_and(|bm| v < 64 && (bm >> v) & 1 == 0)
}

/// `attr <op> v` selects nothing on a zone of live rows (`min <= max`
/// holds whenever this is consulted: empty crossbars short-circuit in
/// [`pred_disjoint`]).
fn cmp_disjoint(z: &ColZone, op: CmpOp, v: u64) -> bool {
    match op {
        CmpOp::Eq => eq_disjoint(z, v),
        // != v is empty only when every live row holds exactly v
        CmpOp::Ne => z.min == z.max && z.min == v,
        CmpOp::Lt => z.min >= v,
        CmpOp::Le => z.min > v,
        CmpOp::Gt => z.max <= v,
        CmpOp::Ge => z.max < v,
    }
}

// --- runtime all-zero short-circuit schedule --------------------------------

/// Where the engine may test the mask plane for all-zero and what it may
/// skip: computed per execution from a program whose filter prefix was
/// proven side-effect-free by the shared-scan analysis
/// ([`super::sharedscan::scan_info`]), whose `prefix_len` is `resume`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShortCircuit {
    /// Step indices (ascending) after which an all-zero mask plane
    /// proves the remaining prefix cannot set any mask bit.
    pub checks: Vec<usize>,
    /// First step of the post-mask suffix: the jump target when a check
    /// observes an all-zero mask.
    pub resume: usize,
}

/// Compute the short-circuit schedule of a program's filter prefix.
///
/// A check after step `k` is sound iff every later mask write in the
/// prefix is *zero-preserving* — given an all-zero mask it writes an
/// all-zero mask (an AND with the mask as one operand, or a Reset).
/// Then a zero mask at `k` proves the final mask zero, and because the
/// prefix is side-effect-free (the `prefix_len` contract: callers pass
/// [`super::sharedscan::ScanInfo::prefix_len`]), jumping to `resume` is
/// observationally identical. `None` when no useful check exists.
pub fn short_circuit(steps: &[Step], mask_col: usize, prefix_len: usize) -> Option<ShortCircuit> {
    let prefix_len = prefix_len.min(steps.len());
    let mut checks = Vec::new();
    let mut preserved = true; // all mask writes after the cursor preserve zero
    for k in (0..prefix_len).rev() {
        let i = &steps[k].instr;
        let writes_mask = passes::write_span(i).is_some_and(|w| passes::overlaps(w, mask_col, 1));
        if !writes_mask {
            continue;
        }
        // a check directly before `resume` would skip nothing
        if preserved && k + 1 < prefix_len {
            checks.push(k);
        }
        preserved = preserved && zero_preserving(i, mask_col);
    }
    checks.reverse();
    (!checks.is_empty()).then_some(ShortCircuit {
        checks,
        resume: prefix_len,
    })
}

/// Whether an instruction writing the mask column maps an all-zero mask
/// to an all-zero mask.
fn zero_preserving(i: &PimInstruction, mask_col: usize) -> bool {
    match i.op {
        Opcode::Reset => true,
        Opcode::And => is_combine(i, mask_col),
        _ => false,
    }
}

// --- cost-based predicate ordering ------------------------------------------

/// Zone-map selectivity estimates for single compare-immediate filter
/// steps, used to order commutative AND-chain segments.
pub struct SelectivityModel<'a> {
    layout: &'a RelationLayout,
    stats: &'a RelStats,
}

impl<'a> SelectivityModel<'a> {
    /// A model over one relation's layout and its pinned-snapshot stats.
    pub fn new(layout: &'a RelationLayout, stats: &'a RelStats) -> SelectivityModel<'a> {
        SelectivityModel { layout, stats }
    }

    /// Estimated selected fraction of a compare-immediate instruction
    /// whose operand is exactly one attribute slot, assuming values
    /// uniform within each crossbar's zone. `None` when the instruction
    /// is not a recognizable single-slot compare.
    pub fn estimate(&self, i: &PimInstruction) -> Option<f64> {
        if !matches!(
            i.op,
            Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm
        ) {
            return None;
        }
        let slot = self.layout.slots.iter().position(|s| {
            s.start == i.src_a.start as usize && s.attr.bits == i.src_a.len as usize
        })?;
        let bits = i.src_a.len as usize;
        let v = if bits >= 64 {
            i.imm
        } else {
            i.imm & ((1u64 << bits) - 1)
        };
        let mut live = 0.0;
        let mut selected = 0.0;
        for x in &self.stats.xbars {
            if x.live_rows == 0 {
                continue;
            }
            let n = x.live_rows as f64;
            live += n;
            selected += zone_rows(&x.zones[slot], n, i.op, v);
        }
        Some(if live == 0.0 { 0.0 } else { selected / live })
    }
}

/// Estimated rows of one crossbar (live count `n`, zone `z`, so
/// `min <= max`) selected by `<op> v`, zone-uniform interpolation.
fn zone_rows(z: &ColZone, n: f64, op: Opcode, v: u64) -> f64 {
    let span = (z.max - z.min + 1) as f64;
    let eq = if eq_disjoint(z, v) { 0.0 } else { n / span };
    match op {
        Opcode::EqImm => eq,
        Opcode::NeImm => n - eq,
        Opcode::LtImm => {
            if v <= z.min {
                0.0
            } else if v > z.max {
                n
            } else {
                n * ((v - z.min) as f64) / span
            }
        }
        Opcode::GtImm => {
            if v >= z.max {
                0.0
            } else if v < z.min {
                n
            } else {
                n * ((z.max - v) as f64) / span
            }
        }
        _ => 0.0,
    }
}

/// One past the last step that writes the mask column — the filter
/// prefix this module reasons over (same split as the shared-scan
/// analysis).
fn mask_prefix_len(steps: &[Step], mask_col: usize) -> usize {
    let mut n = 0;
    for (i, s) in steps.iter().enumerate() {
        if passes::write_span(&s.instr).is_some_and(|w| passes::overlaps(w, mask_col, 1)) {
            n = i + 1;
        }
    }
    n
}

/// A mask-combine: `And` with a one-column write to exactly the mask
/// column and the mask itself as one operand — the compiler's AND-chain
/// accumulation step.
fn is_combine(i: &PimInstruction, mask_col: usize) -> bool {
    i.op == Opcode::And
        && passes::write_span(i) == Some(ColRange::new(mask_col, 1))
        && (one_col(i.src_a, mask_col) || i.src_b.is_some_and(|b| one_col(b, mask_col)))
}

fn one_col(r: ColRange, c: usize) -> bool {
    r.start as usize == c && r.len == 1
}

/// One permutable AND-chain segment: `steps[lo..=hi]`, ending with its
/// mask-combine at `hi`.
struct SegInfo {
    lo: usize,
    hi: usize,
    /// Non-mask columns the segment writes.
    writes: BTreeSet<usize>,
    /// Non-mask columns the segment reads before writing them itself.
    reads: BTreeSet<usize>,
}

/// Dependence summary of `steps[lo..=hi]`; `None` when the segment is
/// not safely movable (side effects, or a non-final step touching the
/// mask).
fn segment_info(steps: &[Step], lo: usize, hi: usize, mask_col: usize) -> Option<SegInfo> {
    let mut written: BTreeSet<usize> = BTreeSet::new();
    let mut writes = BTreeSet::new();
    let mut reads = BTreeSet::new();
    for k in lo..=hi {
        let i = &steps[k].instr;
        if passes::side_effect(i.op) {
            return None;
        }
        let last = k == hi;
        let (rs, w) = passes::accesses(i);
        for r in rs {
            for c in (r.start as usize)..r.end() {
                if c == mask_col {
                    if !last {
                        return None;
                    }
                } else if !written.contains(&c) {
                    reads.insert(c);
                }
            }
        }
        if let Some(wr) = w {
            for c in (wr.start as usize)..wr.end() {
                if c == mask_col {
                    if !last {
                        return None;
                    }
                } else {
                    written.insert(c);
                    writes.insert(c);
                }
            }
        }
    }
    Some(SegInfo {
        lo,
        hi,
        writes,
        reads,
    })
}

/// The program's permutable AND-chain structure: the head block end
/// (index of the first combine) and each following segment. `None` when
/// there are fewer than two movable segments or any segment is unsafe.
fn and_chain(steps: &[Step], mask_col: usize) -> Option<(usize, Vec<SegInfo>)> {
    let prefix_len = mask_prefix_len(steps, mask_col);
    let combines: Vec<usize> = (0..prefix_len)
        .filter(|&i| is_combine(&steps[i].instr, mask_col))
        .collect();
    if combines.len() < 3 {
        return None;
    }
    let mut segs = Vec::with_capacity(combines.len() - 1);
    for j in 1..combines.len() {
        segs.push(segment_info(steps, combines[j - 1] + 1, combines[j], mask_col)?);
    }
    // pairwise independence: no segment writes a column another reads or
    // writes (CSE-shared temporaries land in `reads` and block the pair)
    for a in 0..segs.len() {
        for b in 0..segs.len() {
            if a != b
                && segs[a]
                    .writes
                    .iter()
                    .any(|c| segs[b].reads.contains(c) || segs[b].writes.contains(c))
            {
                return None;
            }
        }
    }
    Some((combines[0], segs))
}

/// Segment sort key: estimated selectivity (ascending — most selective
/// first maximizes early short-circuits), then per-crossbar cycles,
/// then original position (stability).
fn segment_key(
    steps: &[Step],
    s: &SegInfo,
    xbar_rows: usize,
    sel: Option<&SelectivityModel<'_>>,
) -> (f64, u64) {
    let est = match (sel, s.hi - s.lo) {
        (Some(m), 1) => m.estimate(&steps[s.lo].instr),
        _ => None,
    };
    (
        est.unwrap_or(0.5),
        program_cycles(&steps[s.lo..=s.hi], xbar_rows),
    )
}

/// Reorder the commutative AND-chain segments of a filter prefix
/// most-selective-then-cheapest-first. Returns `None` for the identity
/// permutation or whenever safety cannot be proven — the caller keeps
/// the input stream. The output is a permutation of the input steps
/// (bit-identical final mask: AND is commutative and associative on
/// bit-planes, and segments are pairwise independent), so cycles, wear
/// and the intermediate-cell peak are unchanged.
pub(super) fn reorder_mask_prefix(
    steps: &[Step],
    mask_col: usize,
    xbar_rows: usize,
    sel: Option<&SelectivityModel<'_>>,
) -> Option<Vec<Step>> {
    let (head_end, segs) = and_chain(steps, mask_col)?;
    let keys: Vec<(f64, u64)> = segs
        .iter()
        .map(|s| segment_key(steps, s, xbar_rows, sel))
        .collect();
    let mut order: Vec<usize> = (0..segs.len()).collect();
    order.sort_by(|&a, &b| {
        keys[a]
            .0
            .total_cmp(&keys[b].0)
            .then(keys[a].1.cmp(&keys[b].1))
            .then(a.cmp(&b))
    });
    if order.iter().enumerate().all(|(i, &o)| i == o) {
        return None;
    }
    let mut out: Vec<Step> = steps[..=head_end].to_vec();
    for &o in &order {
        out.extend_from_slice(&steps[segs[o].lo..=segs[o].hi]);
    }
    out.extend_from_slice(&steps[segs.last().expect("segs nonempty").hi + 1..]);
    debug_assert_eq!(out.len(), steps.len());
    Some(out)
}

// --- explain rendering ------------------------------------------------------

/// Render one relation's pruning decisions for `pimdb run --explain`:
/// the per-crossbar skip bitmap (`x` skipped, `.` scanned), the zone
/// ranges the decision consulted, the executed predicate-segment order
/// with selectivity estimates, and the runtime short-circuit schedule.
pub fn explain_pruning(
    filter: &Pred,
    layout: &RelationLayout,
    stats: &RelStats,
    steps: &[Step],
    mask_col: usize,
    xbar_rows: usize,
) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let skip = skip_bitmap(filter, layout, stats);
    let skipped = skip.iter().filter(|&&b| b).count();
    let bitmap: String = skip.iter().map(|&b| if b { 'x' } else { '.' }).collect();
    writeln!(
        s,
        "  skip bitmap    : {bitmap} ({skipped}/{} crossbars skipped)",
        skip.len()
    )
    .unwrap();
    for attr in filter.attrs() {
        let Some(i) = layout.slots.iter().position(|sl| sl.attr.name == attr) else {
            continue;
        };
        write!(s, "  zone {attr:<14}:").unwrap();
        for x in &stats.xbars {
            let z = &x.zones[i];
            if x.live_rows == 0 || z.min > z.max {
                write!(s, " [-]").unwrap();
            } else {
                write!(s, " [{}..{}]", z.min, z.max).unwrap();
            }
        }
        writeln!(s).unwrap();
    }
    let model = SelectivityModel::new(layout, stats);
    match and_chain(steps, mask_col) {
        Some((head_end, segs)) => {
            writeln!(s, "  predicate order: head steps 0..={head_end}").unwrap();
            for seg in &segs {
                let (est, cycles) = segment_key(steps, seg, xbar_rows, Some(&model));
                writeln!(
                    s,
                    "    seg {}..={}: sel~{est:.3} cycles {cycles}: {}",
                    seg.lo, seg.hi, steps[seg.lo]
                )
                .unwrap();
            }
        }
        None => {
            writeln!(
                s,
                "  predicate order: single segment (prefix len {}), not reorderable",
                mask_prefix_len(steps, mask_col)
            )
            .unwrap();
        }
    }
    match short_circuit(steps, mask_col, mask_prefix_len(steps, mask_col)) {
        Some(sc) => writeln!(
            s,
            "  short-circuit  : zero-checks after steps {:?}, resume at {}",
            sc.checks, sc.resume
        )
        .unwrap(),
        None => writeln!(s, "  short-circuit  : no eligible check points").unwrap(),
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::db::layout::DbLayout;
    use crate::db::schema::RelId;
    use crate::exec::engine::{exec_steps_snapshot, XbarState};
    use crate::query::ast::RelQuery;
    use crate::query::compiler::Compiler;
    use crate::query::opt::{optimize, optimize_with_stats, OptLevel};
    use crate::query::tpch;
    use crate::util::rng::Rng;

    fn layouts() -> (SystemConfig, DbLayout) {
        let cfg = SystemConfig::default();
        let layout = DbLayout::build(&cfg, &|rel| rel.records_at_sf(0.002)).unwrap();
        (cfg, layout)
    }

    /// Random full-width crossbar states for `layout`: ~3/4 of the first
    /// 200 rows live with random slot values.
    fn rand_states(layout: &RelationLayout, cols: usize, n: usize, rng: &mut Rng) -> Vec<XbarState> {
        (0..n)
            .map(|_| {
                let mut st = XbarState::new(cols);
                for row in 0..200 {
                    let live = rng.next_u64() % 4 != 0;
                    for s in &layout.slots {
                        let v = rng.next_u64() & mask_of(s.attr.bits);
                        if live {
                            st.write_value(row, ColRange::new(s.start, s.attr.bits), v);
                        }
                    }
                    st.write_value(row, ColRange::new(layout.valid_col, 1), live as u64);
                }
                st
            })
            .collect()
    }

    fn mask_of(bits: usize) -> u64 {
        if bits >= 64 {
            u64::MAX
        } else {
            (1u64 << bits) - 1
        }
    }

    fn rand_pred(layout: &RelationLayout, rng: &mut Rng, depth: usize) -> Pred {
        let slot = &layout.slots[(rng.next_u64() as usize) % layout.slots.len()];
        let attr = slot.attr.name;
        let max = mask_of(slot.attr.bits);
        let v = |rng: &mut Rng| rng.next_u64() % (max.saturating_add(2));
        let ops = [CmpOp::Eq, CmpOp::Ne, CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge];
        match rng.next_u64() % (if depth == 0 { 4 } else { 7 }) {
            0 => Pred::CmpImm {
                attr,
                op: ops[(rng.next_u64() as usize) % ops.len()],
                value: v(rng),
            },
            1 => Pred::InSet {
                attr,
                values: (0..1 + rng.next_u64() % 3).map(|_| v(rng)).collect(),
            },
            2 => {
                let (a, b) = (v(rng), v(rng));
                Pred::Between {
                    attr,
                    lo: a.min(b),
                    hi: a.max(b),
                }
            }
            3 => Pred::True,
            4 => Pred::And(vec![
                rand_pred(layout, rng, depth - 1),
                rand_pred(layout, rng, depth - 1),
            ]),
            5 => Pred::Or(vec![
                rand_pred(layout, rng, depth - 1),
                rand_pred(layout, rng, depth - 1),
            ]),
            _ => Pred::Not(Box::new(rand_pred(layout, rng, depth - 1))),
        }
    }

    #[test]
    fn skip_bitmap_decision_table() {
        let (_, db) = layouts();
        let layout = db.rel(RelId::Supplier).clone();
        let s0 = &layout.slots[0];
        let attr = s0.attr.name;
        let r = ColRange::new(s0.start, s0.attr.bits);
        let valid = ColRange::new(layout.valid_col, 1);
        let mk = |vals: std::ops::RangeInclusive<u64>| {
            let mut st = XbarState::new(layout.compute_base + 1);
            for (row, v) in vals.enumerate() {
                st.write_value(row, r, v);
                st.write_value(row, valid, 1);
            }
            st
        };
        let states = vec![mk(10..=20), XbarState::new(layout.compute_base + 1), mk(30..=40)];
        let stats = crate::db::stats::RelStats::build(&states, &layout);
        let case = |p: Pred| skip_bitmap(&p, &layout, &stats);
        let cmp = |op, value| Pred::CmpImm { attr, op, value };
        // the empty crossbar (index 1) is always skipped
        assert_eq!(case(Pred::True), vec![false, true, false]);
        assert_eq!(case(cmp(CmpOp::Eq, 25)), vec![true, true, true]);
        assert_eq!(case(cmp(CmpOp::Eq, 15)), vec![false, true, true]);
        assert_eq!(case(cmp(CmpOp::Ne, 15)), vec![false, true, false]);
        assert_eq!(case(cmp(CmpOp::Lt, 10)), vec![true, true, true]);
        assert_eq!(case(cmp(CmpOp::Lt, 11)), vec![false, true, true]);
        assert_eq!(case(cmp(CmpOp::Le, 9)), vec![true, true, true]);
        assert_eq!(case(cmp(CmpOp::Gt, 20)), vec![true, true, false]);
        assert_eq!(case(cmp(CmpOp::Ge, 41)), vec![true, true, true]);
        assert_eq!(
            case(Pred::InSet {
                attr,
                values: vec![5, 25, 50]
            }),
            vec![true, true, true]
        );
        assert_eq!(
            case(Pred::InSet {
                attr,
                values: vec![5, 35]
            }),
            vec![true, true, false]
        );
        // IN () is vacuously false everywhere
        assert_eq!(
            case(Pred::InSet {
                attr,
                values: vec![]
            }),
            vec![true, true, true]
        );
        assert_eq!(
            case(Pred::Between {
                attr,
                lo: 21,
                hi: 29
            }),
            vec![true, true, true]
        );
        assert_eq!(
            case(Pred::Between {
                attr,
                lo: 15,
                hi: 35
            }),
            vec![false, true, false]
        );
        // And prunes if any arm does; Or only if all arms do
        assert_eq!(
            case(Pred::And(vec![cmp(CmpOp::Ge, 0), cmp(CmpOp::Eq, 25)])),
            vec![true, true, true]
        );
        assert_eq!(
            case(Pred::Or(vec![cmp(CmpOp::Eq, 25), cmp(CmpOp::Eq, 35)])),
            vec![true, true, false]
        );
        assert_eq!(case(Pred::Or(vec![])), vec![true, true, true]);
        // negation is opaque
        assert_eq!(
            case(Pred::Not(Box::new(cmp(CmpOp::Eq, 25)))),
            vec![false, true, false]
        );
    }

    #[test]
    fn skip_bitmap_is_sound_against_scan_everything_oracle() {
        let (cfg, db) = layouts();
        let mut rng = Rng::new(0x5EED_F00D);
        for rel in [RelId::Supplier, RelId::Lineitem] {
            let layout = db.rel(rel).clone();
            for _ in 0..40 {
                let states = rand_states(&layout, cfg.xbar_cols, 3, &mut rng);
                let stats = crate::db::stats::RelStats::build(&states, &layout);
                let p = rand_pred(&layout, &mut rng, 2);
                let skip = skip_bitmap(&p, &layout, &stats);
                for (x, st) in states.iter().enumerate() {
                    if !skip[x] {
                        continue;
                    }
                    for row in 0..crate::util::bits::XBAR_ROWS {
                        if st.value_at(row, ColRange::new(layout.valid_col, 1)) == 0 {
                            continue;
                        }
                        let get = |name: &str| {
                            let s = layout.slot(name).expect("slot");
                            st.value_at(row, ColRange::new(s.start, s.attr.bits))
                        };
                        assert!(
                            !p.eval(&get),
                            "skip bitmap pruned a crossbar with a matching live row: {p:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn short_circuit_schedule_covers_q6_prefix() {
        let (cfg, db) = layouts();
        let q = tpch::query("Q6").unwrap();
        let c = Compiler::compile(&q.rels[0], db.rel(q.rels[0].rel), cfg.xbar_cols).unwrap();
        let (o, _) = optimize(&c, OptLevel::O2, cfg.xbar_rows);
        let prefix = mask_prefix_len(&o.steps, o.mask_col);
        assert!(prefix > 0);
        let sc = short_circuit(&o.steps, o.mask_col, prefix).expect("Q6 has an AND chain");
        assert_eq!(sc.resume, prefix);
        assert!(sc.checks.windows(2).all(|w| w[0] < w[1]));
        for &k in &sc.checks {
            assert!(k + 1 < prefix);
            let w = passes::write_span(&o.steps[k].instr).expect("check step writes");
            assert!(passes::overlaps(w, o.mask_col, 1));
        }
        assert_eq!(short_circuit(&o.steps, o.mask_col, 0), None);
    }

    #[test]
    fn reorder_moves_selective_segment_first_and_stays_bit_identical() {
        let (cfg, db) = layouts();
        let layout = db.rel(RelId::Lineitem).clone();
        let mut rng = Rng::new(0xBEEF);
        let states = rand_states(&layout, cfg.xbar_cols, 2, &mut rng);
        let stats = crate::db::stats::RelStats::build(&states, &layout);
        // four conjuncts: an unselective cheap head, then two mid ones,
        // then a never-true (maximally selective) compare last
        let a = |i: usize| layout.slots[i].attr.name;
        let rq = RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::And(vec![
                Pred::CmpImm {
                    attr: a(0),
                    op: CmpOp::Ge,
                    value: 0,
                },
                Pred::CmpImm {
                    attr: a(1),
                    op: CmpOp::Le,
                    value: mask_of(layout.slots[1].attr.bits) / 2,
                },
                Pred::CmpImm {
                    attr: a(2),
                    op: CmpOp::Gt,
                    value: mask_of(layout.slots[2].attr.bits) / 2,
                },
                Pred::CmpImm {
                    attr: a(3),
                    op: CmpOp::Eq,
                    value: mask_of(layout.slots[3].attr.bits),
                },
            ]),
            group_by: vec![],
            aggregates: vec![],
        };
        let c = Compiler::compile(&rq, &layout, cfg.xbar_cols).unwrap();
        let (o0, _) = optimize(&c, OptLevel::O0, cfg.xbar_rows);
        let (o2, _) = optimize(&c, OptLevel::O2, cfg.xbar_rows);
        let model = SelectivityModel::new(&layout, &stats);
        let (o2s, st) = optimize_with_stats(&c, OptLevel::O2, cfg.xbar_rows, Some(&model));
        assert!(st.cycles_after <= st.cycles_before);
        // the ordering pass must actually permute this program...
        let ops = |s: &[Step]| s.iter().map(|x| x.instr.op).collect::<Vec<_>>();
        assert_ne!(ops(&o2s.steps), ops(&o2.steps), "no permutation happened");
        let mut sorted_a = ops(&o2s.steps);
        let mut sorted_b = ops(&o2.steps);
        sorted_a.sort_by_key(|o| *o as u8);
        sorted_b.sort_by_key(|o| *o as u8);
        assert_eq!(sorted_a, sorted_b, "reorder must be a permutation");
        // ...and stay bit-identical to the unoptimized program
        let (outs0, masks0) =
            exec_steps_snapshot(&states, layout.compute_base, &o0.steps, o0.mask_col, None, None, None);
        let (outs2, masks2) = exec_steps_snapshot(
            &states,
            layout.compute_base,
            &o2s.steps,
            o2s.mask_col,
            None,
            None,
            None,
        );
        assert_eq!(masks0, masks2);
        assert_eq!(outs0.mask_counts, outs2.mask_counts);
        assert_eq!(outs0.reduces, outs2.reduces);
    }

    #[test]
    fn stats_ordered_programs_stay_bit_identical_under_fuzz() {
        let (cfg, db) = layouts();
        let layout = db.rel(RelId::Lineitem).clone();
        let mut rng = Rng::new(0xC0FFEE);
        for round in 0..15 {
            let states = rand_states(&layout, cfg.xbar_cols, 2, &mut rng);
            let stats = crate::db::stats::RelStats::build(&states, &layout);
            let n = 1 + (rng.next_u64() as usize) % 5;
            let filter = Pred::And((0..n).map(|_| rand_pred(&layout, &mut rng, 1)).collect());
            let rq = RelQuery {
                rel: RelId::Lineitem,
                filter,
                group_by: vec![],
                aggregates: vec![],
            };
            let c = Compiler::compile(&rq, &layout, cfg.xbar_cols).unwrap();
            let (o0, _) = optimize(&c, OptLevel::O0, cfg.xbar_rows);
            let model = SelectivityModel::new(&layout, &stats);
            let (o2s, _) = optimize_with_stats(&c, OptLevel::O2, cfg.xbar_rows, Some(&model));
            let (outs0, masks0) = exec_steps_snapshot(
                &states,
                layout.compute_base,
                &o0.steps,
                o0.mask_col,
                None,
                None,
                None,
            );
            let (outs2, masks2) = exec_steps_snapshot(
                &states,
                layout.compute_base,
                &o2s.steps,
                o2s.mask_col,
                None,
                None,
                None,
            );
            assert_eq!(masks0, masks2, "round {round}");
            assert_eq!(outs0.mask_counts, outs2.mask_counts, "round {round}");
            assert_eq!(outs0.reduces, outs2.reduces, "round {round}");
        }
    }

    #[test]
    fn explain_pruning_renders_every_section() {
        let (cfg, db) = layouts();
        let layout = db.rel(RelId::Supplier).clone();
        let s0 = &layout.slots[0];
        let mut st = XbarState::new(layout.compute_base + 1);
        for row in 0..8 {
            st.write_value(row, ColRange::new(s0.start, s0.attr.bits), 10 + row as u64);
            st.write_value(row, ColRange::new(layout.valid_col, 1), 1);
        }
        let states = vec![st, XbarState::new(layout.compute_base + 1)];
        let stats = crate::db::stats::RelStats::build(&states, &layout);
        let filter = Pred::CmpImm {
            attr: s0.attr.name,
            op: CmpOp::Eq,
            value: 99,
        };
        let rq = RelQuery {
            rel: RelId::Supplier,
            filter: filter.clone(),
            group_by: vec![],
            aggregates: vec![],
        };
        let c = Compiler::compile(&rq, &layout, cfg.xbar_cols).unwrap();
        let (o, _) = optimize(&c, OptLevel::O2, cfg.xbar_rows);
        let text = explain_pruning(&filter, &layout, &stats, &o.steps, o.mask_col, cfg.xbar_rows);
        assert!(text.contains("skip bitmap"), "{text}");
        assert!(text.contains("xx (2/2 crossbars skipped)"), "{text}");
        assert!(text.contains(&format!("zone {:<14}", s0.attr.name)), "{text}");
        assert!(text.contains("[10..17]"), "{text}");
        assert!(text.contains("predicate order"), "{text}");
        assert!(text.contains("short-circuit"), "{text}");
    }
}
