//! Query layer: AST, the 19 evaluated TPC-H queries, and the compiler
//! lowering them to PIM instruction programs.

pub mod ast;
pub mod compiler;
pub mod tpch;
