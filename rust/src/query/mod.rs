//! Query layer: AST, the 19 evaluated TPC-H queries, the PQL text
//! frontend, the compiler lowering them to PIM instruction programs, and
//! the optimizing pass pipeline over those programs.
//!
//! Queries enter through two doors — the hardcoded paper set in [`tpch`]
//! and ad-hoc text parsed by [`lang`] — and meet in the same [`ast`]
//! types, which [`compiler`] lowers to PIM instruction programs; [`opt`]
//! then optimizes the programs (`-O0`..`-O2`) before execution.

pub mod ast;
pub mod compiler;
pub mod lang;
pub mod opt;
pub mod tpch;
