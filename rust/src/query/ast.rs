//! Query AST: filter predicates and aggregate expressions over one
//! relation's PIM copy (the compiler's input; produced by `tpch.rs` or by
//! library users building ad-hoc analytics — see examples/custom_db.rs).

use crate::db::schema::RelId;

/// Comparison operator of an immediate or column-column predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
}

/// Filter predicate tree. Attribute references are by name; the compiler
/// resolves them against the relation layout.
#[derive(Clone, Debug, PartialEq)]
pub enum Pred {
    /// attr <op> constant (already in the attribute's encoding domain).
    CmpImm {
        attr: &'static str,
        op: CmpOp,
        value: u64,
    },
    /// attr IN {values} — dictionary-expanded LIKE and IN lists.
    InSet {
        attr: &'static str,
        values: Vec<u64>,
    },
    /// lo <= attr <= hi (inclusive).
    Between {
        attr: &'static str,
        lo: u64,
        hi: u64,
    },
    /// attr_a <op> attr_b (e.g. l_commitdate < l_receiptdate).
    CmpCols {
        a: &'static str,
        op: CmpOp,
        b: &'static str,
    },
    /// Conjunction of sub-predicates.
    And(Vec<Pred>),
    /// Disjunction of sub-predicates.
    Or(Vec<Pred>),
    /// Negation of a sub-predicate.
    Not(Box<Pred>),
    /// Always true (used for aggregate-only queries).
    True,
}

impl Pred {
    /// Convenience constructor for [`Pred::And`].
    pub fn and(preds: Vec<Pred>) -> Pred {
        Pred::And(preds)
    }

    /// Attributes referenced by this predicate (for the baseline's
    /// access-ordering and width accounting).
    pub fn attrs(&self) -> Vec<&'static str> {
        let mut out = Vec::new();
        self.collect_attrs(&mut out);
        out.dedup();
        out
    }

    fn collect_attrs(&self, out: &mut Vec<&'static str>) {
        match self {
            Pred::CmpImm { attr, .. } | Pred::InSet { attr, .. } | Pred::Between { attr, .. } => {
                out.push(attr)
            }
            Pred::CmpCols { a, b, .. } => {
                out.push(a);
                out.push(b);
            }
            Pred::And(ps) | Pred::Or(ps) => {
                for p in ps {
                    p.collect_attrs(out);
                }
            }
            Pred::Not(p) => p.collect_attrs(out),
            Pred::True => {}
        }
    }

    /// Evaluate on a decoded record (attr lookup closure) — the scalar
    /// oracle used by the baseline executor and by differential tests.
    pub fn eval(&self, get: &dyn Fn(&str) -> u64) -> bool {
        match self {
            Pred::CmpImm { attr, op, value } => cmp(get(attr), *op, *value),
            Pred::InSet { attr, values } => values.contains(&get(attr)),
            Pred::Between { attr, lo, hi } => {
                let v = get(attr);
                *lo <= v && v <= *hi
            }
            Pred::CmpCols { a, op, b } => cmp(get(a), *op, get(b)),
            Pred::And(ps) => ps.iter().all(|p| p.eval(get)),
            Pred::Or(ps) => ps.iter().any(|p| p.eval(get)),
            Pred::Not(p) => !p.eval(get),
            Pred::True => true,
        }
    }
}

fn cmp(a: u64, op: CmpOp, b: u64) -> bool {
    match op {
        CmpOp::Eq => a == b,
        CmpOp::Ne => a != b,
        CmpOp::Lt => a < b,
        CmpOp::Le => a <= b,
        CmpOp::Gt => a > b,
        CmpOp::Ge => a >= b,
    }
}

/// Value expression an aggregate reduces. The PIM arithmetic instructions
/// (Not/AddImm/Mul/Add) compute these in-array before the reduce.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ValExpr {
    /// The attribute itself.
    Attr(&'static str),
    /// Constant 1 per record (COUNT via SUM of the filter column).
    One,
    /// a * b (both attributes).
    MulAttrs(&'static str, &'static str),
    /// attr * (scale - other): e.g. extendedprice * (100 - discount),
    /// the Q1/Q6 revenue terms in scaled integer arithmetic.
    MulComplement {
        attr: &'static str,
        scale: u64,
        other: &'static str,
    },
    /// attr * (scale + other): e.g. ... * (100 + tax).
    MulSum {
        attr: &'static str,
        scale: u64,
        other: &'static str,
    },
    /// attr * (s1 - o1) * (s2 + o2): the Q1 charge term
    /// extendedprice * (100 - discount) * (100 + tax).
    MulComplementSum {
        attr: &'static str,
        scale1: u64,
        other1: &'static str,
        scale2: u64,
        other2: &'static str,
    },
}

impl ValExpr {
    /// Attributes referenced by this expression.
    pub fn attrs(&self) -> Vec<&'static str> {
        match self {
            ValExpr::Attr(a) => vec![a],
            ValExpr::One => vec![],
            ValExpr::MulAttrs(a, b) => vec![a, b],
            ValExpr::MulComplement { attr, other, .. }
            | ValExpr::MulSum { attr, other, .. } => vec![attr, other],
            ValExpr::MulComplementSum {
                attr,
                other1,
                other2,
                ..
            } => vec![attr, other1, other2],
        }
    }

    /// Scalar oracle.
    pub fn eval(&self, get: &dyn Fn(&str) -> u64) -> u128 {
        match self {
            ValExpr::Attr(a) => get(a) as u128,
            ValExpr::One => 1,
            ValExpr::MulAttrs(a, b) => get(a) as u128 * get(b) as u128,
            ValExpr::MulComplement { attr, scale, other } => {
                get(attr) as u128 * (*scale as u128 - get(other) as u128)
            }
            ValExpr::MulSum { attr, scale, other } => {
                get(attr) as u128 * (*scale as u128 + get(other) as u128)
            }
            ValExpr::MulComplementSum {
                attr,
                scale1,
                other1,
                scale2,
                other2,
            } => {
                get(attr) as u128
                    * (*scale1 as u128 - get(other1) as u128)
                    * (*scale2 as u128 + get(other2) as u128)
            }
        }
    }
}

/// Aggregate function reduced in-array (plus host combine, paper §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggKind {
    /// In-array SUM reduction, host addition across crossbars.
    Sum,
    /// COUNT via SUM of the 1-bit filter mask column.
    Count,
    /// In-array MIN reduction, host MIN across crossbars.
    Min,
    /// In-array MAX reduction, host MAX across crossbars.
    Max,
    /// Average = in-PIM SUM + COUNT, divided at the host (paper §4.2).
    Avg,
}

/// One aggregate output of a [`RelQuery`].
#[derive(Clone, Debug, PartialEq)]
pub struct Aggregate {
    /// The reduction applied to `expr`.
    pub kind: AggKind,
    /// The per-record value being reduced.
    pub expr: ValExpr,
    /// Output column label in the query result.
    pub label: &'static str,
}

/// Per-relation query spec: what PIMDB executes on one relation's pages.
#[derive(Clone, Debug, PartialEq)]
pub struct RelQuery {
    /// The relation this program runs on.
    pub rel: RelId,
    /// Filter predicate (use [`Pred::True`] for aggregate-only queries).
    pub filter: Pred,
    /// Group-by attributes (dictionary-encoded, small domains); empty for
    /// plain filters/aggregates.
    pub group_by: Vec<&'static str>,
    /// Aggregates (empty for filter-only relations: the filter result
    /// column is column-transformed and read instead).
    pub aggregates: Vec<Aggregate>,
}

/// How much of a query runs inside the PIM modules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryKind {
    /// Entire query runs in PIMDB (single-relation filter+aggregate).
    Full,
    /// PIMDB performs the filters; the rest executes at the host (out of
    /// the measured scope, as in the paper).
    FilterOnly,
}

/// A TPC-H query as PIMDB sees it.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    /// Query name (e.g. `"Q6"`, or `"adhoc"` for text-frontend queries).
    pub name: &'static str,
    /// Whether the whole query or only its filters run in PIM.
    pub kind: QueryKind,
    /// One program per participating relation.
    pub rels: Vec<RelQuery>,
}

/// One `column = encoded value` assignment of an INSERT row image or an
/// UPDATE SET list (values are already in the attribute's storage
/// encoding, like [`Pred::CmpImm`] literals).
pub type SetClause = (&'static str, u64);

/// A DML statement: the mutable-relation counterpart of [`Query`].
///
/// INSERT writes one encoded record into a free row (row-wise host
/// write, endurance-aware placement); UPDATE and DELETE filter with the
/// same predicate machinery queries use and then mutate the selected
/// rows in place — DELETE clears the VALID bit (and zeroes the row's
/// data columns, preserving the engine's all-zero-dead-row invariant),
/// UPDATE rewrites the SET attributes.
#[derive(Clone, Debug, PartialEq)]
pub enum Dml {
    /// `insert into <rel> (cols...) values (...)`: one new record.
    /// Unlisted attributes encode as 0.
    Insert {
        /// Target relation.
        rel: RelId,
        /// `(attribute, encoded value)` pairs, in written order.
        values: Vec<SetClause>,
    },
    /// `update <rel> set a = v, ... where <pred>`: in-place rewrite of
    /// the SET attributes on every live row the filter selects.
    Update {
        /// Target relation.
        rel: RelId,
        /// Row filter ([`Pred::True`] for an unconditional update).
        filter: Pred,
        /// `(attribute, encoded value)` assignments, in written order.
        sets: Vec<SetClause>,
    },
    /// `delete from <rel> where <pred>`: clear VALID (and the data
    /// columns) of every live row the filter selects.
    Delete {
        /// Target relation.
        rel: RelId,
        /// Row filter ([`Pred::True`] deletes every live row).
        filter: Pred,
    },
}

impl Dml {
    /// The relation this statement mutates.
    pub fn rel(&self) -> RelId {
        match self {
            Dml::Insert { rel, .. } | Dml::Update { rel, .. } | Dml::Delete { rel, .. } => *rel,
        }
    }

    /// Statement kind keyword (`insert` / `update` / `delete`).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Dml::Insert { .. } => "insert",
            Dml::Update { .. } => "update",
            Dml::Delete { .. } => "delete",
        }
    }

    /// The statement's row filter ([`Pred::True`] for INSERT).
    pub fn filter(&self) -> &Pred {
        const TRUE: &Pred = &Pred::True;
        match self {
            Dml::Insert { .. } => TRUE,
            Dml::Update { filter, .. } | Dml::Delete { filter, .. } => filter,
        }
    }
}

/// One executable PQL statement: a read-only [`Query`] or a mutating
/// [`Dml`] (what [`crate::query::lang::parse_statements`] returns).
#[derive(Clone, Debug, PartialEq)]
pub enum Statement {
    /// A query block.
    Query(Query),
    /// A DML statement.
    Dml(Dml),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dml_accessors() {
        let d = Dml::Delete {
            rel: RelId::Part,
            filter: Pred::CmpImm {
                attr: "p_size",
                op: CmpOp::Eq,
                value: 3,
            },
        };
        assert_eq!(d.rel(), RelId::Part);
        assert_eq!(d.kind_name(), "delete");
        assert!(matches!(d.filter(), Pred::CmpImm { .. }));
        let i = Dml::Insert {
            rel: RelId::Supplier,
            values: vec![("s_suppkey", 1)],
        };
        assert_eq!(i.kind_name(), "insert");
        assert_eq!(*i.filter(), Pred::True);
    }

    #[test]
    fn pred_eval_oracle() {
        let p = Pred::And(vec![
            Pred::CmpImm {
                attr: "a",
                op: CmpOp::Ge,
                value: 10,
            },
            Pred::Or(vec![
                Pred::InSet {
                    attr: "b",
                    values: vec![1, 2, 3],
                },
                Pred::Not(Box::new(Pred::Between {
                    attr: "c",
                    lo: 5,
                    hi: 9,
                })),
            ]),
        ]);
        let mk = |a: u64, b: u64, c: u64| move |n: &str| match n {
            "a" => a,
            "b" => b,
            "c" => c,
            _ => 0,
        };
        assert!(p.eval(&mk(10, 2, 7)));
        assert!(p.eval(&mk(10, 9, 4))); // c outside between
        assert!(!p.eval(&mk(9, 2, 7))); // a too small
        assert!(!p.eval(&mk(10, 9, 7))); // both or-arms false
    }

    #[test]
    fn cmp_cols_eval() {
        let p = Pred::CmpCols {
            a: "x",
            op: CmpOp::Lt,
            b: "y",
        };
        assert!(p.eval(&|n| if n == "x" { 3 } else { 4 }));
        assert!(!p.eval(&|_| 3));
    }

    #[test]
    fn attrs_collection_dedups() {
        let p = Pred::And(vec![
            Pred::CmpImm {
                attr: "a",
                op: CmpOp::Eq,
                value: 1,
            },
            Pred::CmpImm {
                attr: "a",
                op: CmpOp::Ne,
                value: 2,
            },
            Pred::CmpCols {
                a: "a",
                op: CmpOp::Lt,
                b: "b",
            },
        ]);
        assert_eq!(p.attrs(), vec!["a", "b"]);
    }

    #[test]
    fn val_expr_oracle() {
        let get = |n: &str| match n {
            "price" => 200u64,
            "disc" => 5,
            "tax" => 8,
            _ => 0,
        };
        assert_eq!(ValExpr::Attr("price").eval(&get), 200);
        assert_eq!(ValExpr::One.eval(&get), 1);
        assert_eq!(
            ValExpr::MulComplement {
                attr: "price",
                scale: 100,
                other: "disc"
            }
            .eval(&get),
            200 * 95
        );
        assert_eq!(
            ValExpr::MulSum {
                attr: "price",
                scale: 100,
                other: "tax"
            }
            .eval(&get),
            200 * 108
        );
    }
}
