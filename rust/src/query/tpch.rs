//! The 19 TPC-H queries PIMDB evaluates (paper Table 2, §5.1).
//!
//! Full queries (Q1, Q6, Q22_sub) run filter **and** aggregation in the
//! PIM modules; filter-only queries run the per-relation filters of the
//! relations listed in Table 2 (the join/rest executes at the host and is
//! out of the measured scope, as in the paper). Q9/Q13/Q18 filter only
//! attributes excluded from the PIM copy and are not evaluated.
//!
//! Predicates follow the TPC-H v3 spec with its validation parameter
//! values; LIKE predicates are expanded over the dictionary (the paper's
//! dictionary encoding "allows equality comparisons"), and region /
//! nation-name predicates are folded to nation-key IN-sets via the
//! DRAM-resident NATION/REGION tables.

use crate::db::schema::{self as s, RelId};

use super::ast::*;

fn eq(attr: &'static str, value: u64) -> Pred {
    Pred::CmpImm {
        attr,
        op: CmpOp::Eq,
        value,
    }
}

fn lt(attr: &'static str, value: u64) -> Pred {
    Pred::CmpImm {
        attr,
        op: CmpOp::Lt,
        value,
    }
}

fn ge(attr: &'static str, value: u64) -> Pred {
    Pred::CmpImm {
        attr,
        op: CmpOp::Ge,
        value,
    }
}

fn gt(attr: &'static str, value: u64) -> Pred {
    Pred::CmpImm {
        attr,
        op: CmpOp::Gt,
        value,
    }
}

fn ne(attr: &'static str, value: u64) -> Pred {
    Pred::CmpImm {
        attr,
        op: CmpOp::Ne,
        value,
    }
}

fn in_set(attr: &'static str, values: Vec<u64>) -> Pred {
    Pred::InSet { attr, values }
}

fn between(attr: &'static str, lo: u64, hi: u64) -> Pred {
    Pred::Between { attr, lo, hi }
}

/// date range [from, to): from <= attr < to.
fn date_range(attr: &'static str, from: u64, to: u64) -> Pred {
    Pred::And(vec![ge(attr, from), lt(attr, to)])
}

fn filter_rel(rel: RelId, filter: Pred) -> RelQuery {
    RelQuery {
        rel,
        filter,
        group_by: vec![],
        aggregates: vec![],
    }
}

fn sum(expr: ValExpr, label: &'static str) -> Aggregate {
    Aggregate {
        kind: AggKind::Sum,
        expr,
        label,
    }
}

/// All evaluated queries in paper order.
pub fn all_queries() -> Vec<Query> {
    vec![
        q1(),
        q2(),
        q3(),
        q4(),
        q5(),
        q6(),
        q7(),
        q8(),
        q10(),
        q11(),
        q12(),
        q14(),
        q15(),
        q16(),
        q17(),
        q19(),
        q20(),
        q21(),
        q22_sub(),
    ]
}

/// Look up one evaluated query by name (case-insensitive).
pub fn query(name: &str) -> Option<Query> {
    all_queries()
        .into_iter()
        .find(|q| q.name.eq_ignore_ascii_case(name))
}

/// The 16 queries whose joins/aggregation run at the host.
pub fn filter_only_queries() -> Vec<Query> {
    all_queries()
        .into_iter()
        .filter(|q| q.kind == QueryKind::FilterOnly)
        .collect()
}

/// The 3 queries that run entirely in PIM (Q1, Q6, Q22_sub).
pub fn full_queries() -> Vec<Query> {
    all_queries()
        .into_iter()
        .filter(|q| q.kind == QueryKind::Full)
        .collect()
}

/// Q1 — pricing summary report (full): LINEITEM where
/// shipdate <= 1998-12-01 - 90 days, grouped by returnflag/linestatus.
/// Money is in cents; the (1-discount)/(1+tax) terms use x100 scaling,
/// divided back at the host (paper §4.2 non-associative host step).
fn q1() -> Query {
    Query {
        name: "Q1",
        kind: QueryKind::Full,
        rels: vec![RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::CmpImm {
                attr: "l_shipdate",
                op: CmpOp::Le,
                value: s::date(1998, 12, 1) - 90,
            },
            group_by: vec!["l_returnflag", "l_linestatus"],
            aggregates: vec![
                sum(ValExpr::Attr("l_quantity"), "sum_qty"),
                sum(ValExpr::Attr("l_extendedprice"), "sum_base_price"),
                sum(
                    ValExpr::MulComplement {
                        attr: "l_extendedprice",
                        scale: 100,
                        other: "l_discount",
                    },
                    "sum_disc_price_x100",
                ),
                sum(
                    ValExpr::MulComplementSum {
                        attr: "l_extendedprice",
                        scale1: 100,
                        other1: "l_discount",
                        scale2: 100,
                        other2: "l_tax",
                    },
                    "sum_charge_x10000",
                ),
                sum(ValExpr::Attr("l_discount"), "sum_disc"),
                Aggregate {
                    kind: AggKind::Count,
                    expr: ValExpr::One,
                    label: "count_order",
                },
            ],
        }],
    }
}

/// Q2 — minimum cost supplier (filter-only): PART (size=15, type %BRASS),
/// SUPPLIER (in EUROPE).
fn q2() -> Query {
    Query {
        name: "Q2",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Part,
                Pred::And(vec![
                    eq("p_size", 15),
                    in_set("p_type", s::type_ids_ending_with("BRASS")),
                ]),
            ),
            filter_rel(
                RelId::Supplier,
                in_set("s_nationkey", s::nations_in_region("EUROPE")),
            ),
        ],
    }
}

/// Q3 — shipping priority (filter-only): CUSTOMER BUILDING,
/// ORDERS before 1995-03-15, LINEITEM after it.
fn q3() -> Query {
    let d = s::date(1995, 3, 15);
    Query {
        name: "Q3",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(RelId::Customer, eq("c_mktsegment", s::segment_id("BUILDING"))),
            filter_rel(RelId::Orders, lt("o_orderdate", d)),
            filter_rel(RelId::Lineitem, gt("l_shipdate", d)),
        ],
    }
}

/// Q4 — order priority checking (filter-only): ORDERS in 1993-Q3,
/// LINEITEM with commitdate < receiptdate (two-column compare).
fn q4() -> Query {
    Query {
        name: "Q4",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Orders,
                date_range("o_orderdate", s::date(1993, 7, 1), s::date(1993, 10, 1)),
            ),
            filter_rel(
                RelId::Lineitem,
                Pred::CmpCols {
                    a: "l_commitdate",
                    op: CmpOp::Lt,
                    b: "l_receiptdate",
                },
            ),
        ],
    }
}

/// Q5 — local supplier volume (filter-only): ASIA suppliers/customers,
/// ORDERS in 1994.
fn q5() -> Query {
    let asia = s::nations_in_region("ASIA");
    Query {
        name: "Q5",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(RelId::Supplier, in_set("s_nationkey", asia.clone())),
            filter_rel(RelId::Customer, in_set("c_nationkey", asia)),
            filter_rel(
                RelId::Orders,
                date_range("o_orderdate", s::date(1994, 1, 1), s::date(1995, 1, 1)),
            ),
        ],
    }
}

/// Q6 — forecasting revenue change (full): LINEITEM in 1994,
/// discount in [0.05, 0.07], quantity < 24; sum(extprice * discount).
fn q6() -> Query {
    Query {
        name: "Q6",
        kind: QueryKind::Full,
        rels: vec![RelQuery {
            rel: RelId::Lineitem,
            filter: Pred::And(vec![
                date_range("l_shipdate", s::date(1994, 1, 1), s::date(1995, 1, 1)),
                between("l_discount", 5, 7),
                lt("l_quantity", 24),
            ]),
            group_by: vec![],
            aggregates: vec![sum(
                ValExpr::MulAttrs("l_extendedprice", "l_discount"),
                "revenue_x100",
            )],
        }],
    }
}

/// Q7 — volume shipping (filter-only): FRANCE/GERMANY suppliers and
/// customers, LINEITEM shipped 1995-1996.
fn q7() -> Query {
    let fr_de = vec![s::nation_id("FRANCE"), s::nation_id("GERMANY")];
    Query {
        name: "Q7",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(RelId::Supplier, in_set("s_nationkey", fr_de.clone())),
            filter_rel(RelId::Customer, in_set("c_nationkey", fr_de)),
            filter_rel(
                RelId::Lineitem,
                between(
                    "l_shipdate",
                    s::date(1995, 1, 1),
                    s::date(1996, 12, 31),
                ),
            ),
        ],
    }
}

/// Q8 — national market share (filter-only): PART of a given type,
/// ORDERS 1995-1996, CUSTOMER in AMERICA.
fn q8() -> Query {
    Query {
        name: "Q8",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Part,
                eq("p_type", s::type_id_of("ECONOMY ANODIZED STEEL")),
            ),
            filter_rel(
                RelId::Orders,
                between(
                    "o_orderdate",
                    s::date(1995, 1, 1),
                    s::date(1996, 12, 31),
                ),
            ),
            filter_rel(
                RelId::Customer,
                in_set("c_nationkey", s::nations_in_region("AMERICA")),
            ),
        ],
    }
}

/// Q10 — returned item reporting (filter-only): ORDERS 1993-Q4,
/// LINEITEM returnflag = 'R'.
fn q10() -> Query {
    Query {
        name: "Q10",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Orders,
                date_range("o_orderdate", s::date(1993, 10, 1), s::date(1994, 1, 1)),
            ),
            filter_rel(
                RelId::Lineitem,
                eq("l_returnflag", s::returnflag_id("R")),
            ),
        ],
    }
}

/// Q11 — important stock identification (filter-only): GERMANY suppliers.
/// The paper notes this is the one slowdown case (small relation, small
/// filter).
fn q11() -> Query {
    Query {
        name: "Q11",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Supplier,
            eq("s_nationkey", s::nation_id("GERMANY")),
        )],
    }
}

/// Q12 — shipping modes and order priority (filter-only): LINEITEM with
/// shipmode in (MAIL, SHIP), commitdate < receiptdate,
/// shipdate < commitdate, receiptdate in 1994.
fn q12() -> Query {
    Query {
        name: "Q12",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Lineitem,
            Pred::And(vec![
                in_set(
                    "l_shipmode",
                    vec![s::shipmode_id("MAIL"), s::shipmode_id("SHIP")],
                ),
                Pred::CmpCols {
                    a: "l_commitdate",
                    op: CmpOp::Lt,
                    b: "l_receiptdate",
                },
                Pred::CmpCols {
                    a: "l_shipdate",
                    op: CmpOp::Lt,
                    b: "l_commitdate",
                },
                date_range("l_receiptdate", s::date(1994, 1, 1), s::date(1995, 1, 1)),
            ]),
        )],
    }
}

/// Q14 — promotion effect (filter-only): LINEITEM shipped 1995-09.
fn q14() -> Query {
    Query {
        name: "Q14",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Lineitem,
            date_range("l_shipdate", s::date(1995, 9, 1), s::date(1995, 10, 1)),
        )],
    }
}

/// Q15 — top supplier (filter-only): LINEITEM shipped 1996-Q1.
fn q15() -> Query {
    Query {
        name: "Q15",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Lineitem,
            date_range("l_shipdate", s::date(1996, 1, 1), s::date(1996, 4, 1)),
        )],
    }
}

/// Q16 — parts/supplier relationship (filter-only): PART with
/// brand <> Brand#45, type not like MEDIUM POLISHED%, size in 8 values.
fn q16() -> Query {
    Query {
        name: "Q16",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Part,
            Pred::And(vec![
                ne("p_brand", s::brand_id("Brand#45")),
                Pred::Not(Box::new(in_set(
                    "p_type",
                    s::type_ids_with_prefix2("MEDIUM", "POLISHED"),
                ))),
                in_set("p_size", vec![49, 14, 23, 45, 19, 3, 36, 9]),
            ]),
        )],
    }
}

/// Q17 — small-quantity-order revenue (filter-only): PART Brand#23,
/// MED BOX containers.
fn q17() -> Query {
    Query {
        name: "Q17",
        kind: QueryKind::FilterOnly,
        rels: vec![filter_rel(
            RelId::Part,
            Pred::And(vec![
                eq("p_brand", s::brand_id("Brand#23")),
                eq("p_container", s::container_id("MED BOX")),
            ]),
        )],
    }
}

/// Q19 — discounted revenue (filter-only): the three-way disjunction over
/// PART (brand/container/size) and LINEITEM (quantity/shipmode/instruct).
fn q19() -> Query {
    let air = vec![s::shipmode_id("AIR"), s::shipmode_id("REG AIR")];
    let sm_containers = vec![
        s::container_id("SM CASE"),
        s::container_id("SM BOX"),
        s::container_id("SM PACK"),
        s::container_id("SM PKG"),
    ];
    let med_containers = vec![
        s::container_id("MED BAG"),
        s::container_id("MED BOX"),
        s::container_id("MED PKG"),
        s::container_id("MED PACK"),
    ];
    let lg_containers = vec![
        s::container_id("LG CASE"),
        s::container_id("LG BOX"),
        s::container_id("LG PACK"),
        s::container_id("LG PKG"),
    ];
    Query {
        name: "Q19",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Part,
                Pred::Or(vec![
                    Pred::And(vec![
                        eq("p_brand", s::brand_id("Brand#12")),
                        in_set("p_container", sm_containers),
                        between("p_size", 1, 5),
                    ]),
                    Pred::And(vec![
                        eq("p_brand", s::brand_id("Brand#23")),
                        in_set("p_container", med_containers),
                        between("p_size", 1, 10),
                    ]),
                    Pred::And(vec![
                        eq("p_brand", s::brand_id("Brand#34")),
                        in_set("p_container", lg_containers),
                        between("p_size", 1, 15),
                    ]),
                ]),
            ),
            filter_rel(
                RelId::Lineitem,
                Pred::And(vec![
                    Pred::Or(vec![
                        between("l_quantity", 1, 11),
                        between("l_quantity", 10, 20),
                        between("l_quantity", 20, 30),
                    ]),
                    in_set("l_shipmode", air),
                    eq("l_shipinstruct", s::instruct_id("DELIVER IN PERSON")),
                ]),
            ),
        ],
    }
}

/// Q20 — potential part promotion (filter-only): CANADA suppliers,
/// LINEITEM shipped in 1994.
fn q20() -> Query {
    Query {
        name: "Q20",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Supplier,
                eq("s_nationkey", s::nation_id("CANADA")),
            ),
            filter_rel(
                RelId::Lineitem,
                date_range("l_shipdate", s::date(1994, 1, 1), s::date(1995, 1, 1)),
            ),
        ],
    }
}

/// Q21 — suppliers who kept orders waiting (filter-only): SAUDI ARABIA
/// suppliers, ORDERS with status F, LINEITEM receipt > commit.
fn q21() -> Query {
    Query {
        name: "Q21",
        kind: QueryKind::FilterOnly,
        rels: vec![
            filter_rel(
                RelId::Supplier,
                eq("s_nationkey", s::nation_id("SAUDI ARABIA")),
            ),
            filter_rel(
                RelId::Orders,
                eq("o_orderstatus", s::orderstatus_id("F")),
            ),
            filter_rel(
                RelId::Lineitem,
                Pred::CmpCols {
                    a: "l_receiptdate",
                    op: CmpOp::Gt,
                    b: "l_commitdate",
                },
            ),
        ],
    }
}

/// Q22_sub — the inner sub-query of global sales opportunity (full):
/// CUSTOMER with acctbal > 0.00 and phone country code in seven values;
/// avg(acctbal) = in-PIM SUM + COUNT, host division.
fn q22_sub() -> Query {
    // country codes are nationkey + 10 in our generator; the spec values
    // 13,31,23,29,30,18,17 are the same ids.
    let codes = vec![13, 31, 23, 29, 30, 18, 17];
    Query {
        name: "Q22_sub",
        kind: QueryKind::Full,
        rels: vec![RelQuery {
            rel: RelId::Customer,
            filter: Pred::And(vec![
                in_set("c_phone_cc", codes),
                // acctbal > 0.00 with the +100000 cent offset
                gt("c_acctbal", 100_000),
            ]),
            group_by: vec![],
            aggregates: vec![Aggregate {
                kind: AggKind::Avg,
                expr: ValExpr::Attr("c_acctbal"),
                label: "avg_acctbal",
            }],
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nineteen_queries_defined() {
        let qs = all_queries();
        assert_eq!(qs.len(), 19);
        assert_eq!(full_queries().len(), 3);
        assert_eq!(filter_only_queries().len(), 16);
        // unique names
        let mut names: Vec<_> = qs.iter().map(|q| q.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 19);
    }

    #[test]
    fn table2_relation_sets() {
        // spot-check against paper Table 2
        let rels = |n: &str| {
            query(n)
                .unwrap()
                .rels
                .iter()
                .map(|r| r.rel)
                .collect::<Vec<_>>()
        };
        assert_eq!(rels("Q2"), vec![RelId::Part, RelId::Supplier]);
        assert_eq!(
            rels("Q3"),
            vec![RelId::Customer, RelId::Orders, RelId::Lineitem]
        );
        assert_eq!(rels("Q11"), vec![RelId::Supplier]);
        assert_eq!(rels("Q1"), vec![RelId::Lineitem]);
        assert_eq!(rels("Q22_sub"), vec![RelId::Customer]);
        assert_eq!(
            rels("Q21"),
            vec![RelId::Supplier, RelId::Orders, RelId::Lineitem]
        );
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(query("q6").is_some());
        assert!(query("Q22_SUB").is_some());
        assert!(query("q13").is_none()); // excluded by the paper
    }

    #[test]
    fn all_filter_attrs_exist_in_schema() {
        for q in all_queries() {
            for rq in &q.rels {
                for a in rq.filter.attrs() {
                    assert!(
                        crate::db::schema::attr(rq.rel, a).is_some(),
                        "{} references missing {:?}.{}",
                        q.name,
                        rq.rel,
                        a
                    );
                }
                for agg in &rq.aggregates {
                    for a in agg.expr.attrs() {
                        assert!(
                            crate::db::schema::attr(rq.rel, a).is_some(),
                            "{} agg references missing {:?}.{}",
                            q.name,
                            rq.rel,
                            a
                        );
                    }
                }
                for g in &rq.group_by {
                    assert!(crate::db::schema::attr(rq.rel, g).is_some());
                }
            }
        }
    }

    #[test]
    fn full_queries_have_aggregates_filter_only_dont() {
        for q in all_queries() {
            match q.kind {
                QueryKind::Full => {
                    assert!(q.rels.iter().all(|r| !r.aggregates.is_empty()))
                }
                QueryKind::FilterOnly => {
                    assert!(q.rels.iter().all(|r| r.aggregates.is_empty()))
                }
            }
        }
    }

    #[test]
    fn q1_has_six_aggregates_four_groups_possible() {
        let q = q1();
        assert_eq!(q.rels[0].aggregates.len(), 6);
        assert_eq!(q.rels[0].group_by.len(), 2);
    }
}
