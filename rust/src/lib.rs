//! # PIMDB — bulk-bitwise processing-in-memory for database analytics
//!
//! A full-system reproduction of *"Understanding Bulk-Bitwise Processing
//! In-Memory Through Database Analytics"* (Perach, Ronen, Kimelfeld,
//! Kvatinsky — IEEE TETC 2022): a memristive stateful-logic (MAGIC NOR)
//! PIM architecture accelerating TPC-H filter and aggregation, compared
//! against an in-memory column-store baseline on the same modelled host.
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas stack (see DESIGN.md): the *functional* value of every PIM
//! instruction can be computed by AOT-compiled XLA executables (lowered
//! from Pallas bit-plane kernels, loaded via PJRT in [`runtime`]), while
//! the *timing/energy/endurance* behaviour comes from the hardware models
//! in [`pim`], [`mem`] and [`host`].
//!
//! Modules:
//! * [`api`] — the embedding surface: an owned, `Arc`-shareable
//!   [`api::Pimdb`] service handle with prepared statements
//!   (`open` → `prepare` → `execute`), a canonical-AST-hash plan cache,
//!   epoch-snapshot reads under group-committed DML (readers never
//!   block on writers), typed [`api::Rows`]/[`api::Value`] result
//!   cursors that decode the schema encodings, and the crate-wide typed
//!   [`error::PimdbError`].
//! * [`pim`] — PIM module hardware model: crossbars, controller FSM
//!   (Table 4), media controller + FR-FCFS, energy/endurance/area/power.
//! * [`mem`] — host memory substrate: address mapping (Fig. 3), huge
//!   pages, L1/L2 cache model, DDR4 DRAM model.
//! * [`host`] — analytic out-of-order core and host power models.
//! * [`db`] — TPC-H substrate: schema, generator, encodings, PIM layout.
//! * [`query`] — filter/aggregate AST, the 19 evaluated TPC-H queries,
//!   the PQL text frontend (`query::lang`, `pimdb run --sql`), compiler
//!   to PIM request programs, and the optimizing pass pipeline
//!   (`query::opt`, `-O0`..`-O2`: IN-set peephole, CSE, valid-AND
//!   elision, dead-step elimination, lifetime column reallocation).
//! * [`exec`] — the PIMDB engine, the sharded parallel execution plan,
//!   and the in-memory column-store baseline.
//! * [`storage`] — the durability subsystem: a checksum-framed
//!   write-ahead log appended by the group-commit leader, versioned
//!   epoch checkpoints of the crossbar bit-planes and wear state, and
//!   crash recovery with torn-tail truncation
//!   (`api::Pimdb::open_durable` / `checkpoint`).
//! * [`runtime`] — PJRT CPU client running the AOT kernel artifacts
//!   (behind the `pjrt` cargo feature; a stub otherwise).
//! * [`report`] — regenerates every evaluation table and figure.
//!
//! ## Host-parallel sharded execution
//!
//! Crossbars are functionally independent, so the engine splits every
//! compiled program into contiguous crossbar shards ([`exec::plan`]) and
//! executes them on a pool of host worker threads sized by
//! `SystemConfig::parallelism` (`--parallelism`; 0 = auto-detect). Query
//! outputs *and* all timing/energy/endurance accounting are bit-identical
//! for every shard and thread count — the knob only changes wall-clock.
//! [`api::Pimdb`] keeps an always-on worker pool with per-shard queues
//! and an admission cap (`SystemConfig::admission`), executing queries
//! against pinned immutable epoch snapshots so readers never block on
//! concurrent DML (group-committed per relation).
//! [`exec::pimdb::PimSession::run_queries`] batches independent queries
//! over the same shards: queries on disjoint relations execute
//! concurrently in waves, queries sharing a relation serialize.

#![warn(missing_docs)]

pub mod api;
pub mod cli;
pub mod config;
pub mod db;
pub mod error;
pub mod exec;
pub mod host;
pub mod mem;
pub mod pim;
pub mod query;
pub mod report;
pub mod runtime;
pub mod storage;
pub mod util;
