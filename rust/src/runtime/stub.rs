//! Stub PJRT backend, compiled when the `pjrt` feature is off.
//!
//! The offline build has no `xla` crate, so the PJRT functional backend
//! cannot link; this stub keeps the public API shape so callers can probe
//! [`runtime_available`] and fall back to the native engine (the
//! differential tests skip themselves exactly as they do when the kernel
//! artifacts are missing at runtime).

use crate::exec::engine::{ExecOutputs, XbarState};
use crate::query::compiler::Step;

/// Always false: the PJRT runtime is not compiled into this build.
pub fn runtime_available() -> bool {
    false
}

/// Always fails: enabling the PJRT functional backend needs both the
/// `pjrt` cargo feature *and* the vendored `xla` crate declared in
/// rust/Cargo.toml (the feature alone does not compile without it).
pub fn exec_steps_pjrt(
    _states: &mut [XbarState],
    _steps: &[Step],
    _mask_col: usize,
) -> Result<ExecOutputs, String> {
    Err("PJRT backend not compiled in (requires the pjrt feature plus the \
         vendored xla crate — see rust/Cargo.toml)"
        .into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_and_errors() {
        assert!(!runtime_available());
        let err = exec_steps_pjrt(&mut [], &[], 0).unwrap_err();
        assert!(err.contains("pjrt"), "{err}");
    }
}
