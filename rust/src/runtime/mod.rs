//! PJRT runtime: loads the AOT-compiled HLO artifacts (lowered from the
//! Layer-1 Pallas kernels by `make artifacts`) and executes PIM
//! instruction semantics through them.
//!
//! The interchange format is HLO *text* — jax >= 0.5 serialized protos use
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).
//!
//! The functional state packs planes as `[u64; 16]` per column (see
//! `util::bits`); the compiled kernels keep their original u32 ABI, so the
//! boundary splits each u64 word into (lo, hi) u32 halves on gather and
//! recombines on scatter: planes `u32[XB, 64, 32]`, masks `u32[XB, 32]`,
//! immediates as `u32[64]` bit vectors.
//!
//! Ops not worth a PJRT round-trip (single-plane Set/Reset/Not/And/Or and
//! result-mask post-processing) run on the host word-wise — they are not
//! the compute hot-spot (paper Table 5: compare/arith/reduce dominate).
//!
//! The whole backend sits behind the `pjrt` cargo feature: the offline
//! build has no `xla` crate, so the default build links the stub instead,
//! which reports the runtime as unavailable. Either way the backend is
//! driven shard-by-shard through [`crate::exec::plan`], the same execution
//! plan the native engine uses, so the two stay differential-testable at
//! any parallelism.

#[cfg(feature = "pjrt")]
pub mod exec;

#[cfg(feature = "pjrt")]
pub use exec::{exec_steps_pjrt, runtime_available, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(not(feature = "pjrt"))]
pub use stub::{exec_steps_pjrt, runtime_available};
