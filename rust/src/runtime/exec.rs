//! Artifact loading and PJRT execution of PIM instruction semantics.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::pim::isa::{ColRange, Opcode, PimInstruction};
use crate::query::compiler::Step;
use crate::util::bits::{KERNEL_WORDS, PLANES, WORDS, XB_TILE};

/// Loaded PJRT executables, keyed by kernel name.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cached all-ones reduce mask (constant across calls; rebuilding it
    /// per reduce showed up in the dispatch profile — EXPERIMENTS §Perf).
    ones_mask: xla::Literal,
}

/// Kernels the instruction interpreter uses.
const KERNELS: [&str; 8] = [
    "cmp_imm",
    "cmp_cols",
    "add_imm",
    "add_cols",
    "mul_cols",
    "reduce_sum",
    "reduce_min",
    "reduce_max",
];

impl Runtime {
    /// Artifact directory: $PIMDB_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("PIMDB_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load every kernel artifact from `dir` and compile via PJRT.
    pub fn load(dir: &Path) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        let mut exes = HashMap::new();
        for name in KERNELS {
            let path = dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                return Err(format!("missing artifact {}", path.display()));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or("bad path")?,
            )
            .map_err(|e| format!("parse {name}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format!("compile {name}: {e}"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(Runtime {
            client,
            exes,
            ones_mask: ones_mask_literal(),
        })
    }

    fn exe(&self, name: &str) -> &xla::PjRtLoadedExecutable {
        &self.exes[name]
    }
}

thread_local! {
    static RUNTIME: RefCell<Option<Result<Rc<Runtime>, String>>> = const { RefCell::new(None) };
}

fn with_runtime<R>(f: impl FnOnce(&Runtime) -> Result<R, String>) -> Result<R, String> {
    RUNTIME.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(Runtime::load(&Runtime::default_dir()).map(Rc::new));
        }
        match slot.as_ref().unwrap() {
            Ok(rt) => f(rt),
            Err(e) => Err(e.clone()),
        }
    })
}

/// True when the artifacts are present and the PJRT client initializes.
pub fn runtime_available() -> bool {
    with_runtime(|_| Ok(())).is_ok()
}

// --- literal packing ---------------------------------------------------------
//
// The engine packs planes as `[u64; WORDS]`; the compiled kernels keep the
// original `u32[.., KERNEL_WORDS]` ABI. Each u64 word splits into (lo, hi)
// u32 halves on gather and recombines on scatter — rows stay in the same
// order because word `w` covers rows `64w..64w+63` and the two halves land
// at kernel words `2w` (rows `64w..`) and `2w+1` (rows `64w+32..`).

fn gather_planes(states: &[XbarState], tile: &[usize], r: ColRange, nplanes: usize) -> xla::Literal {
    let mut flat = vec![0u32; XB_TILE * nplanes * KERNEL_WORDS];
    for (ti, &si) in tile.iter().enumerate() {
        let st = &states[si];
        for i in 0..(r.len as usize).min(nplanes) {
            let p = &st.planes[r.start as usize + i];
            let base = (ti * nplanes + i) * KERNEL_WORDS;
            for w in 0..WORDS {
                flat[base + 2 * w] = p[w] as u32;
                flat[base + 2 * w + 1] = (p[w] >> 32) as u32;
            }
        }
    }
    xla::Literal::vec1(&flat)
        .reshape(&[XB_TILE as i64, nplanes as i64, KERNEL_WORDS as i64])
        .expect("reshape planes")
}

fn imm_literal(imm: u64, n: usize) -> xla::Literal {
    let masked = if n >= 64 { imm } else { imm & ((1u64 << n) - 1) };
    let bits: Vec<u32> = (0..PLANES).map(|i| ((masked >> i) & 1) as u32).collect();
    xla::Literal::vec1(&bits)
}

fn ones_mask_literal() -> xla::Literal {
    let flat = vec![u32::MAX; XB_TILE * KERNEL_WORDS];
    xla::Literal::vec1(&flat)
        .reshape(&[XB_TILE as i64, KERNEL_WORDS as i64])
        .expect("reshape mask")
}

fn scatter_planes(
    out: &[u32],
    states: &mut [XbarState],
    tile: &[usize],
    dst: ColRange,
    nplanes: usize,
) {
    for (ti, &si) in tile.iter().enumerate() {
        for i in 0..dst.len as usize {
            let base = (ti * nplanes + i) * KERNEL_WORDS;
            let p = &mut states[si].planes[dst.start as usize + i];
            for w in 0..WORDS {
                p[w] = (out[base + 2 * w] as u64) | ((out[base + 2 * w + 1] as u64) << 32);
            }
        }
    }
}

fn scatter_mask(out: &[u32], states: &mut [XbarState], tile: &[usize], col: usize, invert: bool) {
    for (ti, &si) in tile.iter().enumerate() {
        for w in 0..WORDS {
            let lo = out[ti * KERNEL_WORDS + 2 * w];
            let hi = out[ti * KERNEL_WORDS + 2 * w + 1];
            let v = (lo as u64) | ((hi as u64) << 32);
            states[si].planes[col][w] = if invert { !v } else { v };
        }
    }
}

fn run(
    rt: &Runtime,
    name: &str,
    args: &[&xla::Literal],
) -> Result<Vec<xla::Literal>, String> {
    let bufs = rt
        .exe(name)
        .execute::<&xla::Literal>(args)
        .map_err(|e| format!("execute {name}: {e}"))?;
    let lit = bufs[0][0]
        .to_literal_sync()
        .map_err(|e| format!("fetch {name}: {e}"))?;
    lit.to_tuple().map_err(|e| format!("untuple {name}: {e}"))
}

fn to_u32s(l: &xla::Literal) -> Result<Vec<u32>, String> {
    l.to_vec::<u32>().map_err(|e| format!("literal to_vec: {e}"))
}

// --- instruction interpreter -------------------------------------------------

fn exec_tile(
    rt: &Runtime,
    states: &mut [XbarState],
    tile: &[usize],
    instr: &PimInstruction,
    reduce_out: &mut [Vec<u128>],
) -> Result<(), String> {
    let a = instr.src_a;
    let d = instr.dst;
    match instr.op {
        Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm => {
            let planes = gather_planes(states, tile, a, PLANES);
            let imm = imm_literal(instr.imm, a.len as usize);
            let outs = run(rt, "cmp_imm", &[&planes, &imm])?;
            let eq = to_u32s(&outs[0])?;
            let lt = to_u32s(&outs[1])?;
            match instr.op {
                Opcode::EqImm => scatter_mask(&eq, states, tile, d.start as usize, false),
                Opcode::NeImm => scatter_mask(&eq, states, tile, d.start as usize, true),
                Opcode::LtImm => scatter_mask(&lt, states, tile, d.start as usize, false),
                Opcode::GtImm => {
                    let ge: Vec<u32> =
                        lt.iter().zip(&eq).map(|(l, e)| !(l | e)).collect();
                    scatter_mask(&ge, states, tile, d.start as usize, false);
                }
                _ => unreachable!(),
            }
        }
        Opcode::Eq | Opcode::Lt => {
            let b = instr.src_b.expect("cmp_cols");
            let pa = gather_planes(states, tile, a, PLANES);
            let pb = gather_planes(states, tile, b, PLANES);
            let outs = run(rt, "cmp_cols", &[&pa, &pb])?;
            let idx = if instr.op == Opcode::Eq { 0 } else { 1 };
            let m = to_u32s(&outs[idx])?;
            scatter_mask(&m, states, tile, d.start as usize, false);
        }
        Opcode::AddImm => {
            let planes = gather_planes(states, tile, a, PLANES);
            let imm = imm_literal(instr.imm, a.len as usize);
            let outs = run(rt, "add_imm", &[&planes, &imm])?;
            let s = to_u32s(&outs[0])?;
            scatter_planes(&s, states, tile, d, PLANES);
        }
        Opcode::Add => {
            let b = instr.src_b.expect("add");
            let pa = gather_planes(states, tile, a, PLANES);
            let pb = gather_planes(states, tile, b, PLANES);
            let outs = run(rt, "add_cols", &[&pa, &pb])?;
            let s = to_u32s(&outs[0])?;
            scatter_planes(&s, states, tile, d, PLANES);
        }
        Opcode::Mul => {
            let b = instr.src_b.expect("mul");
            if a.len > 32 || b.len > 32 {
                return Err(format!(
                    "mul operands exceed the 32x32 kernel: {}x{}",
                    a.len, b.len
                ));
            }
            let pa = gather_planes(states, tile, a, 32);
            let pb = gather_planes(states, tile, b, 32);
            let outs = run(rt, "mul_cols", &[&pa, &pb])?;
            let s = to_u32s(&outs[0])?;
            scatter_planes(&s, states, tile, d, 64);
        }
        Opcode::ReduceSum => {
            let planes = gather_planes(states, tile, a, PLANES);
            let outs = run(rt, "reduce_sum", &[&planes, &rt.ones_mask])?;
            let counts = to_u32s(&outs[0])?; // [XB_TILE, 64]
            for (ti, &si) in tile.iter().enumerate() {
                let mut sum: u128 = 0;
                for i in 0..PLANES {
                    sum += (counts[ti * PLANES + i] as u128) << i;
                }
                reduce_out[si].push(sum);
            }
        }
        Opcode::ReduceMin | Opcode::ReduceMax => {
            let name = if instr.op == Opcode::ReduceMin {
                "reduce_min"
            } else {
                "reduce_max"
            };
            let planes = gather_planes(states, tile, a, PLANES);
            let outs = run(rt, name, &[&planes, &rt.ones_mask])?;
            let lo = to_u32s(&outs[0])?;
            let hi = to_u32s(&outs[1])?;
            for (ti, &si) in tile.iter().enumerate() {
                let v = (lo[ti] as u128) | ((hi[ti] as u128) << 32);
                reduce_out[si].push(v);
            }
        }
        // plane-local logic and data movement: host word ops (see module
        // docs) — same semantics as the native engine.
        Opcode::Set
        | Opcode::Reset
        | Opcode::Not
        | Opcode::And
        | Opcode::Or
        | Opcode::ColumnTransform => {
            let mut scratch = engine::Scratch::new();
            for &si in tile {
                let mut dummy = Vec::new();
                engine::exec_instr(&mut states[si], instr, &mut dummy, &mut scratch);
            }
        }
    }
    Ok(())
}

/// Run a compiled program over crossbar states through the PJRT kernels.
pub fn exec_steps_pjrt(
    states: &mut [XbarState],
    steps: &[Step],
    mask_col: usize,
) -> Result<ExecOutputs, String> {
    with_runtime(|rt| {
        let n = states.len();
        let mut per_state_reduces: Vec<Vec<u128>> = vec![Vec::new(); n];
        let tiles: Vec<Vec<usize>> = (0..n)
            .collect::<Vec<_>>()
            .chunks(XB_TILE)
            .map(|c| c.to_vec())
            .collect();
        for step in steps {
            for tile in &tiles {
                exec_tile(rt, states, tile, &step.instr, &mut per_state_reduces)?;
            }
        }
        let n_reduces = per_state_reduces.first().map(|v| v.len()).unwrap_or(0);
        let mut reduces = vec![Vec::with_capacity(n); n_reduces];
        for sv in &per_state_reduces {
            for (i, &v) in sv.iter().enumerate() {
                reduces[i].push(v);
            }
        }
        let mask_counts = states.iter().map(|s| s.popcount_col(mask_col)).collect();
        Ok(ExecOutputs {
            reduces,
            mask_counts,
            ..ExecOutputs::default()
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::endurance::OpCategory;

    fn step(instr: PimInstruction) -> Step {
        Step {
            instr,
            category: OpCategory::Filter,
        }
    }

    fn load_values(vals: &[u64], start: usize, bits: usize, st: &mut XbarState) {
        for (row, &v) in vals.iter().enumerate() {
            for b in 0..bits {
                if (v >> b) & 1 == 1 {
                    let w = &mut st.planes[start + b][row / 64];
                    *w |= 1u64 << (row % 64);
                }
            }
        }
    }

    /// Differential: PJRT engine == native engine on a mixed program.
    /// Skips (passes vacuously) when artifacts/PJRT are unavailable.
    #[test]
    fn pjrt_matches_native_differential() {
        if !runtime_available() {
            eprintln!("skipping: PJRT runtime/artifacts unavailable");
            return;
        }
        let mut rng = crate::util::rng::Rng::new(99);
        let mut st_a = XbarState::new(256);
        let vals_a: Vec<u64> = (0..1024).map(|_| rng.range_u64(0, (1 << 20) - 1)).collect();
        let vals_b: Vec<u64> = (0..1024).map(|_| rng.range_u64(0, (1 << 20) - 1)).collect();
        load_values(&vals_a, 0, 20, &mut st_a);
        load_values(&vals_b, 20, 20, &mut st_a);
        let mut states_native = vec![st_a.clone(), st_a.clone()];
        let mut states_pjrt = states_native.clone();

        let imm = vals_a[17];
        let steps = vec![
            step(PimInstruction::with_imm(
                Opcode::LtImm,
                ColRange::new(0, 20),
                ColRange::new(100, 1),
                imm,
            )),
            step(PimInstruction::with_imm(
                Opcode::EqImm,
                ColRange::new(0, 20),
                ColRange::new(101, 1),
                imm,
            )),
            step(PimInstruction::binary(
                Opcode::Lt,
                ColRange::new(0, 20),
                ColRange::new(20, 20),
                ColRange::new(102, 1),
            )),
            step(PimInstruction::binary(
                Opcode::Or,
                ColRange::new(100, 1),
                ColRange::new(101, 1),
                ColRange::new(103, 1),
            )),
            step(PimInstruction::binary(
                Opcode::And,
                ColRange::new(0, 20),
                ColRange::new(103, 1),
                ColRange::new(110, 20),
            )),
            step(PimInstruction::binary(
                Opcode::Mul,
                ColRange::new(110, 20),
                ColRange::new(20, 20),
                ColRange::new(130, 40),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceSum,
                ColRange::new(130, 40),
                ColRange::new(130, 40),
            )),
            step(PimInstruction::unary(
                Opcode::ReduceMax,
                ColRange::new(130, 40),
                ColRange::new(130, 40),
            )),
        ];
        let out_n = engine::exec_steps_native(&mut states_native, &steps, 103);
        let out_p = exec_steps_pjrt(&mut states_pjrt, &steps, 103).unwrap();
        assert_eq!(out_n.reduces, out_p.reduces);
        assert_eq!(out_n.mask_counts, out_p.mask_counts);
        // full plane state must match too
        for (a, b) in states_native.iter().zip(&states_pjrt) {
            for c in 0..256 {
                assert_eq!(a.planes[c], b.planes[c], "plane {c} differs");
            }
        }
    }

    #[test]
    fn imm_literal_masks_to_width() {
        let l = imm_literal(u64::MAX, 4);
        let v = l.to_vec::<u32>().unwrap();
        assert_eq!(&v[0..4], &[1, 1, 1, 1]);
        assert!(v[4..].iter().all(|&b| b == 0));
    }

    #[test]
    fn default_dir_env_override() {
        // no env set in tests: default to ./artifacts
        if std::env::var("PIMDB_ARTIFACTS").is_err() {
            assert_eq!(Runtime::default_dir(), PathBuf::from("artifacts"));
        }
    }
}
