//! Cell-accurate memristive crossbar reference model (paper §2.1, Fig. 1).
//!
//! Models a single 1R crossbar executing MAGIC-NOR-class stateful logic
//! under the paper's restrictions (§5.2.3):
//!
//!  * column-wise ops: NOR2 / NOT / single-column SET / RESET, always on
//!    *all* rows in parallel (row exclusion is done in software by masking);
//!  * row-wise ops: NOT or SET of a *single column* at a time, moving a bit
//!    between two rows of the same column.
//!
//! This model is the semantic ground truth the PIM-controller FSM sequences
//! are tested against; the production engine (exec/engine.rs) computes the
//! same functions on packed bit-planes (or via the PJRT executables).

use crate::util::bits::BitMatrix;

/// Operation counters, split the way the paper reports them (Table 5,
/// Table 6): column-wise (all-row-parallel) vs row-wise (single column).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Column-wise (all-row-parallel) operations.
    pub col_ops: u64,
    /// Row-wise (single-column) operations.
    pub row_ops: u64,
}

impl OpCounts {
    /// Column plus row operations.
    pub fn total(&self) -> u64 {
        self.col_ops + self.row_ops
    }
}

/// A cell-accurate crossbar.
pub struct Crossbar {
    cells: BitMatrix,
    counts: OpCounts,
    /// Per-row cell-write counts (endurance accounting, §6.4).
    row_writes: Vec<u64>,
}

impl Crossbar {
    /// An all-zero crossbar of the given geometry.
    pub fn new(rows: usize, cols: usize) -> Self {
        Crossbar {
            cells: BitMatrix::new(rows, cols),
            counts: OpCounts::default(),
            row_writes: vec![0; rows],
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.cells.rows()
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cells.cols()
    }

    /// Operation counters accumulated so far.
    pub fn counts(&self) -> OpCounts {
        self.counts
    }

    /// Per-row cell-write counts (endurance accounting).
    pub fn row_writes(&self) -> &[u64] {
        &self.row_writes
    }

    // --- plain memory access (read/write path, not stateful logic) -------

    /// Read `n` bits at (row, col) as an integer (LSB first).
    pub fn read_bits(&self, row: usize, col: usize, n: usize) -> u64 {
        self.cells.read_bits(row, col, n)
    }

    /// Write `n` bits of `v` at (row, col) (LSB first).
    pub fn write_bits(&mut self, row: usize, col: usize, n: usize, v: u64) {
        self.cells.write_bits(row, col, n, v);
    }

    /// Single cell at (row, col).
    pub fn get(&self, row: usize, col: usize) -> bool {
        self.cells.get(row, col)
    }

    // --- column-wise stateful logic (one cycle each, all rows) -----------

    /// out[r] = NOR(a[r], b[r]) for every row r. MAGIC NOR requires the
    /// output cells to be pre-SET; the model enforces the convention by
    /// overwriting unconditionally (the SET is counted separately by the
    /// FSM sequences that need it).
    pub fn col_nor(&mut self, a: usize, b: usize, out: usize) {
        for r in 0..self.rows() {
            let v = !(self.cells.get(r, a) | self.cells.get(r, b));
            self.cells.set(r, out, v);
            self.row_writes[r] += 1;
        }
        self.counts.col_ops += 1;
    }

    /// out[r] = NOT a[r] (NOR with itself).
    pub fn col_not(&mut self, a: usize, out: usize) {
        for r in 0..self.rows() {
            let v = !self.cells.get(r, a);
            self.cells.set(r, out, v);
            self.row_writes[r] += 1;
        }
        self.counts.col_ops += 1;
    }

    /// SET an entire column to 1.
    pub fn col_set(&mut self, out: usize) {
        for r in 0..self.rows() {
            self.cells.set(r, out, true);
            self.row_writes[r] += 1;
        }
        self.counts.col_ops += 1;
    }

    /// RESET an entire column to 0.
    pub fn col_reset(&mut self, out: usize) {
        for r in 0..self.rows() {
            self.cells.set(r, out, false);
            self.row_writes[r] += 1;
        }
        self.counts.col_ops += 1;
    }

    // --- row-wise stateful logic (single column at a time, §5.2.3) -------

    /// cells[dst_row][col] = NOT cells[src_row][col].
    pub fn row_not(&mut self, col: usize, src_row: usize, dst_row: usize) {
        let v = !self.cells.get(src_row, col);
        self.cells.set(dst_row, col, v);
        self.row_writes[dst_row] += 1;
        self.counts.row_ops += 1;
    }

    /// SET a single cell (row-wise SET of one column).
    pub fn row_set(&mut self, col: usize, row: usize) {
        self.cells.set(row, col, true);
        self.row_writes[row] += 1;
        self.counts.row_ops += 1;
    }

    /// Copy a bit between rows = two row-wise NOTs through a scratch row
    /// cell (double negation, as in the paper's Fig. 6 column-transform).
    pub fn row_copy_via_not(
        &mut self,
        col: usize,
        src_row: usize,
        scratch_row: usize,
        dst_row: usize,
    ) {
        self.row_not(col, src_row, scratch_row);
        self.row_not(col, scratch_row, dst_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn nor_truth_table_all_rows() {
        let mut xb = Crossbar::new(4, 8);
        // rows encode the four (a,b) combinations
        for (r, (a, b)) in [(false, false), (false, true), (true, false), (true, true)]
            .into_iter()
            .enumerate()
        {
            xb.write_bits(r, 0, 1, a as u64);
            xb.write_bits(r, 1, 1, b as u64);
        }
        xb.col_nor(0, 1, 2);
        assert!(xb.get(0, 2));
        assert!(!xb.get(1, 2));
        assert!(!xb.get(2, 2));
        assert!(!xb.get(3, 2));
        assert_eq!(xb.counts(), OpCounts { col_ops: 1, row_ops: 0 });
    }

    #[test]
    fn not_and_set_reset() {
        let mut xb = Crossbar::new(2, 4);
        xb.write_bits(0, 0, 1, 1);
        xb.col_not(0, 1);
        assert!(!xb.get(0, 1) && xb.get(1, 1));
        xb.col_set(2);
        assert!(xb.get(0, 2) && xb.get(1, 2));
        xb.col_reset(2);
        assert!(!xb.get(0, 2) && !xb.get(1, 2));
        assert_eq!(xb.counts().col_ops, 3);
    }

    #[test]
    fn row_ops_move_bits_vertically() {
        let mut xb = Crossbar::new(8, 4);
        xb.write_bits(2, 3, 1, 1);
        xb.row_copy_via_not(3, 2, 6, 7);
        assert!(xb.get(7, 3));
        assert_eq!(xb.counts().row_ops, 2);
        // endurance: writes landed on rows 6 and 7 only
        assert_eq!(xb.row_writes()[6], 1);
        assert_eq!(xb.row_writes()[7], 1);
        assert_eq!(xb.row_writes()[2], 0);
    }

    #[test]
    fn nor_is_functionally_complete_and_via_demorgan() {
        // AND(a,b) == NOR(NOT a, NOT b) on random row data
        check("nor-complete", 50, |g| {
            let mut xb = Crossbar::new(16, 8);
            for r in 0..16 {
                xb.write_bits(r, 0, 1, g.bool() as u64);
                xb.write_bits(r, 1, 1, g.bool() as u64);
            }
            xb.col_not(0, 2);
            xb.col_not(1, 3);
            xb.col_nor(2, 3, 4);
            for r in 0..16 {
                assert_eq!(xb.get(r, 4), xb.get(r, 0) & xb.get(r, 1));
            }
        });
    }

    #[test]
    fn column_writes_hit_every_row_once() {
        let mut xb = Crossbar::new(32, 4);
        xb.col_nor(0, 1, 2);
        xb.col_not(0, 3);
        assert!(xb.row_writes().iter().all(|&w| w == 2));
    }
}
