//! PIM module energy accounting (paper §6.3, Figs. 12–13).
//!
//! The PIM module energy is the sum of stateful (bulk-bitwise) logic,
//! crossbar reads/writes, PIM controller activity, and chip IO. Energy
//! coefficients come from Table 3 ([36] for logic, [37] for read/write).

use crate::config::SystemConfig;

/// Energy ledger for one PIM module (or the aggregate of all modules),
/// all values in picojoules.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EnergyLedger {
    /// Stateful (bulk-bitwise) logic energy.
    pub logic_pj: f64,
    /// Crossbar array read energy.
    pub read_pj: f64,
    /// Crossbar array write energy.
    pub write_pj: f64,
    /// PIM controller energy.
    pub ctrl_pj: f64,
    /// Chip IO energy.
    pub io_pj: f64,
}

impl EnergyLedger {
    /// Sum of all components (pJ).
    pub fn total_pj(&self) -> f64 {
        self.logic_pj + self.read_pj + self.write_pj + self.ctrl_pj + self.io_pj
    }

    /// One column-wise stateful logic cycle on `xbars` crossbars: every
    /// row's output cell switches (81.6 fJ/bit).
    pub fn add_col_logic(&mut self, cfg: &SystemConfig, cycles: u64, xbars: u64) {
        let cells = cycles as f64 * xbars as f64 * cfg.xbar_rows as f64;
        self.logic_pj += cells * cfg.logic_energy_fj_per_bit * 1e-3;
    }

    /// One row-wise stateful logic cycle on `xbars` crossbars: a single
    /// column cell switches per crossbar.
    pub fn add_row_logic(&mut self, cfg: &SystemConfig, cycles: u64, xbars: u64) {
        let cells = cycles as f64 * xbars as f64;
        self.logic_pj += cells * cfg.logic_energy_fj_per_bit * 1e-3;
    }

    /// Crossbar array read of `bits` total bits.
    pub fn add_read_bits(&mut self, cfg: &SystemConfig, bits: u64) {
        self.read_pj += bits as f64 * cfg.read_energy_pj_per_bit;
    }

    /// Crossbar array write of `bits` total bits.
    pub fn add_write_bits(&mut self, cfg: &SystemConfig, bits: u64) {
        self.write_pj += bits as f64 * cfg.write_energy_pj_per_bit;
    }

    /// PIM controller busy time: `ctrls` controllers active for `ps`.
    pub fn add_ctrl_time(&mut self, cfg: &SystemConfig, ctrls: u64, ps: u64) {
        // uW * ps = 1e-6 J/s * 1e-12 s = 1e-18 J = 1e-6 pJ
        self.ctrl_pj += cfg.pim_ctrl_power_uw * ctrls as f64 * ps as f64 * 1e-6;
    }

    /// Chip IO energy for `bytes` moved over the module interface. Uses
    /// the DRAM-style IO coefficient (the paper reuses the gem5 DRAM model
    /// for IO costs).
    pub fn add_io_bytes(&mut self, cfg: &SystemConfig, bytes: u64) {
        // ~4 pJ/bit of IO at DDR-class signalling
        let _ = cfg;
        self.io_pj += bytes as f64 * 8.0 * 4.0;
    }

    /// Commutative component-wise sum (for shard merges).
    pub fn merge(&mut self, other: &EnergyLedger) {
        self.logic_pj += other.logic_pj;
        self.read_pj += other.read_pj;
        self.write_pj += other.write_pj;
        self.ctrl_pj += other.ctrl_pj;
        self.io_pj += other.io_pj;
    }

    /// Breakdown as (label, pJ) pairs for Fig. 13.
    pub fn breakdown(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("stateful-logic", self.logic_pj),
            ("read", self.read_pj),
            ("write", self.write_pj),
            ("pim-ctrl", self.ctrl_pj),
            ("chip-io", self.io_pj),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_logic_counts_all_rows() {
        let cfg = SystemConfig::default();
        let mut e = EnergyLedger::default();
        e.add_col_logic(&cfg, 1, 1);
        // 1024 cells * 81.6 fJ = 83558.4 fJ = 83.5584 pJ
        assert!((e.logic_pj - 1024.0 * 81.6e-3).abs() < 1e-9);
    }

    #[test]
    fn row_logic_counts_one_cell_per_xbar() {
        let cfg = SystemConfig::default();
        let mut e = EnergyLedger::default();
        e.add_row_logic(&cfg, 10, 4);
        assert!((e.logic_pj - 40.0 * 81.6e-3).abs() < 1e-9);
    }

    #[test]
    fn ctrl_energy_unit_conversion() {
        let cfg = SystemConfig::default();
        let mut e = EnergyLedger::default();
        // 1 controller busy for 1 second (1e12 ps) at 126 uW = 126 uJ = 1.26e8 pJ
        e.add_ctrl_time(&cfg, 1, 1_000_000_000_000);
        assert!((e.ctrl_pj - 1.26e8).abs() / 1.26e8 < 1e-9);
    }

    #[test]
    fn merge_accumulates_all_categories() {
        let cfg = SystemConfig::default();
        let mut a = EnergyLedger::default();
        let mut b = EnergyLedger::default();
        a.add_read_bits(&cfg, 100);
        b.add_write_bits(&cfg, 100);
        b.add_io_bytes(&cfg, 64);
        a.merge(&b);
        assert!(a.read_pj > 0.0 && a.write_pj > 0.0 && a.io_pj > 0.0);
        assert_eq!(a.breakdown().len(), 5);
    }
}
