//! Cell endurance accounting (paper §6.4, Fig. 15, Table 6).
//!
//! Tracks cell writes per crossbar row, per operation category. Under the
//! paper's wear-leveling assumption (writes within a row spread uniformly
//! over the row's cells, §6.4), ops-per-cell = row-writes / columns. The
//! ten-year requirement extrapolates back-to-back query execution at 100%
//! duty cycle.

use super::controller::RowWrites;

/// Operation categories as reported in Tables 5 and 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpCategory {
    /// Predicate evaluation.
    Filter,
    /// In-array arithmetic for aggregate value expressions.
    Arith,
    /// Filter-mask column transform for row-oriented read-out.
    ColTransform,
    /// Column-parallel phase of an aggregation reduce.
    AggCol,
    /// Row-sequential phase of an aggregation reduce.
    AggRow,
}

/// All categories, in Table 5/6 reporting order.
pub const CATEGORIES: [OpCategory; 5] = [
    OpCategory::Filter,
    OpCategory::Arith,
    OpCategory::ColTransform,
    OpCategory::AggCol,
    OpCategory::AggRow,
];

impl OpCategory {
    /// Short label used in the report tables.
    pub fn name(&self) -> &'static str {
        match self {
            OpCategory::Filter => "filter",
            OpCategory::Arith => "arith",
            OpCategory::ColTransform => "col-trans",
            OpCategory::AggCol => "agg-col",
            OpCategory::AggRow => "agg-row",
        }
    }

    /// Dense index in [`CATEGORIES`] order.
    pub fn index(&self) -> usize {
        match self {
            OpCategory::Filter => 0,
            OpCategory::Arith => 1,
            OpCategory::ColTransform => 2,
            OpCategory::AggCol => 3,
            OpCategory::AggRow => 4,
        }
    }
}

/// Per-row write counters for the crossbars of one relation (all crossbars
/// of a relation see the same instruction stream, so one profile serves
/// them all).
#[derive(Clone, Debug)]
pub struct EnduranceTracker {
    rows: usize,
    cols: usize,
    /// writes[cat][row]
    writes: Vec<Vec<u64>>,
}

impl EnduranceTracker {
    /// A zeroed tracker for one crossbar geometry.
    pub fn new(rows: usize, cols: usize) -> Self {
        EnduranceTracker {
            rows,
            cols,
            writes: vec![vec![0; rows]; CATEGORIES.len()],
        }
    }

    /// Record one instruction's write profile. For reduce instructions the
    /// caller passes the profile split between [`OpCategory::AggCol`] (the
    /// all-row column component, first prefix entry) and
    /// [`OpCategory::AggRow`] (the move components).
    pub fn record(&mut self, cat: OpCategory, profile: &RowWrites) {
        let w = &mut self.writes[cat.index()];
        match profile {
            RowWrites::AllRows(c) => {
                for x in w.iter_mut() {
                    *x += c;
                }
            }
            RowWrites::Prefix(prefix) => {
                for &(rows_affected, writes_each) in prefix {
                    for x in w.iter_mut().take(rows_affected.min(self.rows)) {
                        *x += writes_each;
                    }
                }
            }
        }
    }

    /// Record a reduce/column-transform with the all-rows head attributed
    /// to `col_cat` and the prefix tail to `row_cat`.
    pub fn record_split(
        &mut self,
        col_cat: OpCategory,
        row_cat: OpCategory,
        profile: &RowWrites,
    ) {
        match profile {
            RowWrites::AllRows(c) => self.record(col_cat, &RowWrites::AllRows(*c)),
            RowWrites::Prefix(prefix) => {
                if let Some(head) = prefix.first() {
                    self.record(col_cat, &RowWrites::Prefix(vec![*head]));
                }
                if prefix.len() > 1 {
                    self.record(row_cat, &RowWrites::Prefix(prefix[1..].to_vec()));
                }
            }
        }
    }

    /// Total writes on row `r` across categories.
    fn row_total(&self, r: usize) -> u64 {
        self.writes.iter().map(|w| w[r]).sum()
    }

    /// Per-row write totals across categories — the per-crossbar profile
    /// the persistent wear counters ([`crate::db::freerows::FreeRowMap`])
    /// accumulate per execution.
    pub fn row_totals(&self) -> Vec<u64> {
        (0..self.rows).map(|r| self.row_total(r)).collect()
    }

    /// The most-written row and its per-category breakdown.
    pub fn max_row(&self) -> (usize, [u64; 5]) {
        let r = (0..self.rows)
            .max_by_key(|&r| self.row_total(r))
            .unwrap_or(0);
        let mut out = [0u64; 5];
        for (i, w) in self.writes.iter().enumerate() {
            out[i] = w[r];
        }
        (r, out)
    }

    /// Max ops-per-cell under uniform in-row wear (writes / columns).
    pub fn max_ops_per_cell(&self) -> f64 {
        let (r, b) = self.max_row();
        let _ = r;
        b.iter().sum::<u64>() as f64 / self.cols as f64
    }

    /// Required endurance (writes/cell) for `years` of back-to-back
    /// execution, given one execution takes `exec_time_s`.
    pub fn required_endurance(&self, exec_time_s: f64, years: f64) -> f64 {
        if exec_time_s <= 0.0 {
            return 0.0;
        }
        let executions = years * 365.25 * 24.0 * 3600.0 / exec_time_s;
        self.max_ops_per_cell() * executions
    }

    /// Fractional contribution of each category at the max row (Table 6).
    pub fn breakdown_fractions(&self) -> [f64; 5] {
        let (_, b) = self.max_row();
        let total: u64 = b.iter().sum();
        let mut out = [0.0; 5];
        if total > 0 {
            for i in 0..5 {
                out[i] = b[i] as f64 / total as f64;
            }
        }
        out
    }

    /// Fold another relation's tracker into this one (see comment).
    pub fn merge_max(&mut self, other: &EnduranceTracker) {
        // relations wear independently; the module requirement is the max
        // profile. Keep whichever tracker has the hotter row per category
        // by summing (conservative upper bound when merging relations that
        // share a module but not pages).
        for (cat, w) in self.writes.iter_mut().enumerate() {
            for (r, x) in w.iter_mut().enumerate() {
                *x = (*x).max(other.writes[cat][r]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_profile_uniform() {
        let mut t = EnduranceTracker::new(16, 512);
        t.record(OpCategory::Filter, &RowWrites::AllRows(7));
        let (_, b) = t.max_row();
        assert_eq!(b[OpCategory::Filter.index()], 7);
        assert!((t.max_ops_per_cell() - 7.0 / 512.0).abs() < 1e-12);
    }

    #[test]
    fn prefix_profile_hits_early_rows_harder() {
        let mut t = EnduranceTracker::new(8, 512);
        t.record(
            OpCategory::AggRow,
            &RowWrites::Prefix(vec![(4, 10), (2, 10), (1, 10)]),
        );
        let (r, b) = t.max_row();
        assert_eq!(r, 0);
        assert_eq!(b[OpCategory::AggRow.index()], 30);
    }

    #[test]
    fn split_reduce_attribution() {
        let mut t = EnduranceTracker::new(8, 512);
        let profile = RowWrites::Prefix(vec![(8, 100), (4, 6), (2, 6)]);
        t.record_split(OpCategory::AggCol, OpCategory::AggRow, &profile);
        let (_, b) = t.max_row();
        assert_eq!(b[OpCategory::AggCol.index()], 100);
        assert_eq!(b[OpCategory::AggRow.index()], 12);
    }

    #[test]
    fn ten_year_extrapolation() {
        let mut t = EnduranceTracker::new(4, 512);
        t.record(OpCategory::Filter, &RowWrites::AllRows(512)); // 1 op/cell
        // 1 second per execution -> ten years = 315,576,000 executions
        let req = t.required_endurance(1.0, 10.0);
        assert!((req - 315_576_000.0).abs() / req < 1e-9);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut t = EnduranceTracker::new(4, 512);
        t.record(OpCategory::Filter, &RowWrites::AllRows(30));
        t.record(OpCategory::ColTransform, &RowWrites::AllRows(10));
        let f = t.breakdown_fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((f[OpCategory::Filter.index()] - 0.75).abs() < 1e-12);
    }
}
