//! PIM module instruction-set architecture (paper §3.1, §4.2, Table 4).
//!
//! A *PIM request* is an address/data pair: the address selects the target
//! huge-page and encodes the result location (column/row index bits of the
//! page offset); the data payload carries the opcode, operand column
//! ranges, and immediate. The host treats requests as opaque writes; only
//! software and the PIM module understand the payload (programming model,
//! paper §3.1).

use std::fmt;

use crate::mem::addr::AddressMap;

/// A range of consecutive crossbar columns (attributes live in consecutive
/// cells — paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColRange {
    /// First column.
    pub start: u16,
    /// Number of columns.
    pub len: u16,
}

impl ColRange {
    /// The range [start, start+len).
    pub fn new(start: usize, len: usize) -> Self {
        ColRange {
            start: start as u16,
            len: len as u16,
        }
    }

    /// One past the last column. Widens *before* adding: `start + len`
    /// can exceed `u16::MAX` for ranges near the top of the column
    /// space, and the former `u16` addition panicked in debug builds.
    pub fn end(&self) -> usize {
        self.start as usize + self.len as usize
    }
}

impl fmt::Display for ColRange {
    /// `[c37]` for a single column, `[c37+8]` for an 8-column range.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.len == 1 {
            write!(f, "[c{}]", self.start)
        } else {
            write!(f, "[c{}+{}]", self.start, self.len)
        }
    }
}

/// PIM opcodes (Table 4). Immediate-operand variants keep the immediate in
/// the request payload and specialize the control sequence on it (§3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// Column range == immediate, into a mask column.
    EqImm = 0,
    /// Column range != immediate.
    NeImm = 1,
    /// Column range < immediate (unsigned).
    LtImm = 2,
    /// Column range > immediate (unsigned).
    GtImm = 3,
    /// Column range += immediate (mod 2^len).
    AddImm = 4,
    /// Two column ranges compared for equality.
    Eq = 5,
    /// Two column ranges compared unsigned-less-than.
    Lt = 6,
    /// Set destination cells to 1.
    Set = 7,
    /// Reset destination cells to 0.
    Reset = 8,
    /// Bitwise NOT.
    Not = 9,
    /// Bitwise AND (1-column second operand broadcasts).
    And = 10,
    /// Bitwise OR (1-column second operand broadcasts).
    Or = 11,
    /// Ripple-carry addition of two column ranges.
    Add = 12,
    /// Shift-add multiplication of two column ranges.
    Mul = 13,
    /// Tree reduction: sum over all rows.
    ReduceSum = 14,
    /// Tree reduction: minimum over all rows.
    ReduceMin = 15,
    /// Tree reduction: maximum over all rows.
    ReduceMax = 16,
    /// Re-orient the filter mask column for row-wise read-out.
    ColumnTransform = 17,
}

impl Opcode {
    /// Decode from the request payload byte.
    pub fn from_u8(v: u8) -> Option<Opcode> {
        use Opcode::*;
        Some(match v {
            0 => EqImm,
            1 => NeImm,
            2 => LtImm,
            3 => GtImm,
            4 => AddImm,
            5 => Eq,
            6 => Lt,
            7 => Set,
            8 => Reset,
            9 => Not,
            10 => And,
            11 => Or,
            12 => Add,
            13 => Mul,
            14 => ReduceSum,
            15 => ReduceMin,
            16 => ReduceMax,
            17 => ColumnTransform,
            _ => return None,
        })
    }

    /// Whether the opcode carries an immediate operand.
    pub fn has_imm(&self) -> bool {
        matches!(
            self,
            Opcode::EqImm | Opcode::NeImm | Opcode::LtImm | Opcode::GtImm | Opcode::AddImm
        )
    }

    /// Whether the opcode takes a second column-range operand.
    pub fn has_src_b(&self) -> bool {
        matches!(
            self,
            Opcode::Eq | Opcode::Lt | Opcode::And | Opcode::Or | Opcode::Add | Opcode::Mul
        )
    }

    /// Mnemonic used by `pimdb inspect`.
    pub fn name(&self) -> &'static str {
        match self {
            Opcode::EqImm => "eq_imm",
            Opcode::NeImm => "ne_imm",
            Opcode::LtImm => "lt_imm",
            Opcode::GtImm => "gt_imm",
            Opcode::AddImm => "add_imm",
            Opcode::Eq => "eq",
            Opcode::Lt => "lt",
            Opcode::Set => "set",
            Opcode::Reset => "reset",
            Opcode::Not => "not",
            Opcode::And => "and",
            Opcode::Or => "or",
            Opcode::Add => "add",
            Opcode::Mul => "mul",
            Opcode::ReduceSum => "reduce_sum",
            Opcode::ReduceMin => "reduce_min",
            Opcode::ReduceMax => "reduce_max",
            Opcode::ColumnTransform => "column_transform",
        }
    }
}

/// Decoded PIM instruction (what a PIM controller executes on all its
/// crossbars in lockstep).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PimInstruction {
    /// The operation.
    pub op: Opcode,
    /// First input operand columns.
    pub src_a: ColRange,
    /// Second input operand (two-operand ALU ops).
    pub src_b: Option<ColRange>,
    /// Result columns (a single column for compare ops / masks).
    pub dst: ColRange,
    /// Immediate value (imm ops); its *control* interpretation uses only
    /// the low `src_a.len` bits.
    pub imm: u64,
}

impl PimInstruction {
    /// Single-operand instruction.
    pub fn unary(op: Opcode, src: ColRange, dst: ColRange) -> Self {
        PimInstruction {
            op,
            src_a: src,
            src_b: None,
            dst,
            imm: 0,
        }
    }

    /// Two-operand instruction.
    pub fn binary(op: Opcode, a: ColRange, b: ColRange, dst: ColRange) -> Self {
        PimInstruction {
            op,
            src_a: a,
            src_b: Some(b),
            dst,
            imm: 0,
        }
    }

    /// Immediate-operand instruction.
    pub fn with_imm(op: Opcode, src: ColRange, dst: ColRange, imm: u64) -> Self {
        PimInstruction {
            op,
            src_a: src,
            src_b: None,
            dst,
            imm,
        }
    }

    /// Operand length n (bits) for the cycle model.
    pub fn n(&self) -> u64 {
        self.src_a.len as u64
    }

    /// Second-operand length m (multiply).
    pub fn m(&self) -> u64 {
        self.src_b.map(|b| b.len as u64).unwrap_or(0)
    }
}

impl fmt::Display for PimInstruction {
    /// One disassembly line: mnemonic, operands, `->` destination, e.g.
    /// `lt_imm [c12+24], #42 -> [c400]` or `and [c400], [c31] -> [c400]`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:<16} {}", self.op.name(), self.src_a)?;
        if let Some(b) = self.src_b {
            write!(f, ", {b}")?;
        }
        if self.op.has_imm() {
            write!(f, ", #{}", self.imm)?;
        }
        write!(f, " -> {}", self.dst)
    }
}

/// Wire format of a PIM request (paper §3.1 "PIM requests"): a virtual
/// address plus a 32-byte data payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PimRequest {
    /// Virtual address: page base | result-location offset bits.
    pub addr: u64,
    /// Payload: opcode, operand ranges, immediate.
    pub data: [u64; 4],
}

/// Encode an instruction for a given page virtual base address.
///
/// The *result column* is carried in the address offset bits (the paper's
/// convention: the request address points at the instruction result); all
/// other fields travel in the data payload.
pub fn encode(instr: &PimInstruction, page_vbase: u64, map: &AddressMap) -> PimRequest {
    let addr = page_vbase | map.encode_cell_offset(0, instr.dst.start as usize);
    let mut d0 = instr.op as u64;
    d0 |= (instr.src_a.start as u64) << 8;
    d0 |= (instr.src_a.len as u64) << 24;
    if let Some(b) = instr.src_b {
        d0 |= 1 << 40;
        d0 |= (b.start as u64) << 41;
        d0 |= (b.len as u64) << 51;
    }
    let d1 = (instr.dst.len as u64) | ((instr.dst.start as u64) << 16);
    PimRequest {
        addr,
        data: [d0, d1, instr.imm, 0],
    }
}

/// Decode a request back to the instruction (media-controller side).
pub fn decode(req: &PimRequest, map: &AddressMap) -> Result<PimInstruction, String> {
    let d0 = req.data[0];
    let op = Opcode::from_u8((d0 & 0xFF) as u8)
        .ok_or_else(|| format!("bad opcode {}", d0 & 0xFF))?;
    let src_a = ColRange {
        start: ((d0 >> 8) & 0xFFFF) as u16,
        len: ((d0 >> 24) & 0xFFFF) as u16,
    };
    let src_b = if (d0 >> 40) & 1 == 1 {
        Some(ColRange {
            start: ((d0 >> 41) & 0x3FF) as u16,
            len: ((d0 >> 51) & 0x3FF) as u16,
        })
    } else {
        None
    };
    let dst = ColRange {
        start: ((req.data[1] >> 16) & 0xFFFF) as u16,
        len: (req.data[1] & 0xFFFF) as u16,
    };
    // cross-check the address-carried result column (the address resolves
    // to byte granularity; the payload carries the exact bit column)
    let (_, col) = map.decode_cell_offset(req.addr & (map.page_bytes() - 1));
    if col != (dst.start as usize) & !7 {
        return Err(format!(
            "address result column {} != payload dst {}",
            col, dst.start
        ));
    }
    Ok(PimInstruction {
        op,
        src_a,
        src_b,
        dst,
        imm: req.data[2],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::AddressMap;
    use crate::util::proptest::check;

    fn map() -> AddressMap {
        AddressMap::paper_default()
    }

    #[test]
    fn encode_decode_roundtrip_simple() {
        let m = map();
        let i = PimInstruction::with_imm(
            Opcode::LtImm,
            ColRange::new(10, 24),
            ColRange::new(400, 1),
            123_456_789,
        );
        let req = encode(&i, 0x40000000, &m);
        assert_eq!(decode(&req, &m).unwrap(), i);
    }

    #[test]
    fn encode_decode_roundtrip_property() {
        check("isa-roundtrip", 200, |g| {
            let ops = [
                Opcode::EqImm,
                Opcode::NeImm,
                Opcode::LtImm,
                Opcode::GtImm,
                Opcode::AddImm,
                Opcode::Eq,
                Opcode::Lt,
                Opcode::Set,
                Opcode::Reset,
                Opcode::Not,
                Opcode::And,
                Opcode::Or,
                Opcode::Add,
                Opcode::Mul,
                Opcode::ReduceSum,
                Opcode::ReduceMin,
                Opcode::ReduceMax,
                Opcode::ColumnTransform,
            ];
            let op = *g.pick(&ops);
            let a = ColRange::new(g.usize(0, 447), g.usize(1, 64));
            let b = if op.has_src_b() {
                Some(ColRange::new(g.usize(0, 447), g.usize(1, 64)))
            } else {
                None
            };
            let i = PimInstruction {
                op,
                src_a: a,
                src_b: b,
                dst: ColRange::new(g.usize(0, 511), g.usize(1, 64)),
                imm: if op.has_imm() { g.skewed_u64() } else { 0 },
            };
            let req = encode(&i, 0x1_0000_0000, &map());
            let back = decode(&req, &map()).unwrap();
            assert_eq!(back, i);
        });
    }

    #[test]
    fn encode_decode_roundtrip_random_geometries() {
        // the wire format must survive any address-map configuration the
        // geometry constructor accepts, not just the paper's default
        check("isa-roundtrip-geometry", 150, |g| {
            let rows = 1usize << g.usize(6, 11); // 64..2048 rows
            let read_bits = 8usize << g.usize(0, 2); // 8/16/32-bit reads
            let cols = read_bits << g.usize(0, 5); // up to 32 units/row
            let unit_bytes_bits = (read_bits / 8).trailing_zeros();
            let min_page_bits = unit_bytes_bits
                + (cols / read_bits).trailing_zeros()
                + rows.trailing_zeros();
            let page_bytes = 1u64 << g.usize(min_page_bits as usize, 30);
            let m = AddressMap::for_geometry(page_bytes, rows, cols, read_bits);

            let op = Opcode::from_u8(g.usize(0, 17) as u8).unwrap();
            let i = PimInstruction {
                op,
                src_a: ColRange::new(g.usize(0, cols - 1), g.usize(1, 64)),
                src_b: op
                    .has_src_b()
                    .then(|| ColRange::new(g.usize(0, cols - 1), g.usize(1, 64))),
                dst: ColRange::new(g.usize(0, cols - 1), g.usize(1, 64)),
                imm: if op.has_imm() { g.skewed_u64() } else { 0 },
            };
            // vbase aligned to any page size up to 2^30
            let req = encode(&i, 1u64 << 40, &m);
            let back = decode(&req, &m).unwrap();
            assert_eq!(back, i, "geometry rows={rows} cols={cols} rb={read_bits}");
        });
    }

    #[test]
    fn col_range_end_survives_u16_overflow() {
        // regression: `start + len` used to add in u16 and panic in debug
        // builds (wrap silently in release) near the top of the column
        // space; end() must widen before adding
        let r = ColRange {
            start: 0xFFF0,
            len: 0x20,
        };
        assert_eq!(r.end(), 0x1_0010);
        let max = ColRange {
            start: u16::MAX,
            len: u16::MAX,
        };
        assert_eq!(max.end(), 2 * u16::MAX as usize);
        // in-range behaviour unchanged
        assert_eq!(ColRange::new(10, 24).end(), 34);
    }

    #[test]
    fn decode_rejects_bad_opcode() {
        let m = map();
        let req = PimRequest {
            addr: 0,
            data: [255, 0, 0, 0],
        };
        assert!(decode(&req, &m).is_err());
    }

    #[test]
    fn opcode_names_unique() {
        let mut seen = std::collections::HashSet::new();
        for v in 0..=17u8 {
            let op = Opcode::from_u8(v).unwrap();
            assert!(seen.insert(op.name()));
        }
        assert!(Opcode::from_u8(18).is_none());
    }
}
