//! PIM controller: instruction → MAGIC NOR micro-sequence (paper §3.3,
//! §5.2.2) and the Table 4 cycle/cell cost model.
//!
//! Two layers live here:
//!
//!  * [`cost`] — the authoritative cycle model. Totals are the paper's
//!    measured closed forms (Table 4, 1024x512 crossbars); the split into
//!    column-wise vs row-wise cycles is structural (derived from the
//!    binary-tree reduce of Fig. 7 and the bit-by-bit row moves of Fig. 6;
//!    see DESIGN.md §4). The split is what Tables 5/6 report.
//!
//!  * [`fsm`] — executable micro-sequences against the cell-accurate
//!    [`Crossbar`] reference model. For NOT/AND/OR/SET/RESET the emitted
//!    sequences match the Table 4 counts *exactly* (tests assert this);
//!    for the remaining ops the sequences validate semantics while the
//!    closed forms remain authoritative for timing (the paper's gate-level
//!    realizations from [36] use library tricks we do not re-derive).

use super::crossbar::Crossbar;
use super::isa::{Opcode, PimInstruction};

/// Cycle/cell cost of one PIM instruction on one crossbar (all crossbars
/// under a PIM controller run the sequence in lockstep, so this is also the
/// controller-level latency).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InstructionCost {
    /// Column-wise (all-rows-parallel) stateful-logic cycles.
    pub col_cycles: u64,
    /// Row-wise (sequential) stateful-logic cycles.
    pub row_cycles: u64,
    /// Cells needed for intermediate results, per crossbar row (Table 4).
    pub intermediate_cells: u64,
}

impl InstructionCost {
    /// Column plus row cycles.
    pub fn total_cycles(&self) -> u64 {
        self.col_cycles + self.row_cycles
    }
}

/// How an instruction's cell writes distribute over crossbar rows
/// (endurance accounting, paper §6.4 / Table 6).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RowWrites {
    /// Every row receives the same number of cell writes (column-wise ops
    /// always operate on all rows — §5.2.3 restriction).
    AllRows(u64),
    /// `prefix[k] = (rows_affected, writes_each)`: the first
    /// `rows_affected` rows receive `writes_each` additional writes
    /// (reduce moves target the surviving half; column-transform targets
    /// the read-out rows).
    Prefix(Vec<(usize, u64)>),
}

fn popcounts(imm: u64, n: u64) -> (u64, u64) {
    let masked = if n >= 64 { imm } else { imm & ((1u64 << n) - 1) };
    let ones = masked.count_ones() as u64;
    (n - ones, ones) // (imm0, imm1)
}

/// Number of tree-reduce levels for `rows` values (Fig. 7).
fn levels(rows: usize) -> u64 {
    rows.trailing_zeros() as u64
}

/// Structural row-wise move cycles of a tree reduce: at level k,
/// rows/2^(k+1) values of width w_k move between rows, 2 row-ops per bit
/// (copy via double negation).
fn reduce_row_cycles(rows: usize, width_at: impl Fn(u64) -> u64) -> u64 {
    let mut total = 0u64;
    for k in 0..levels(rows) {
        let values_moved = (rows as u64) >> (k + 1);
        total += 2 * values_moved * width_at(k);
    }
    total
}

/// Table 4 cost model. `rows` is the crossbar row count (bold-marked
/// entries depend on it; the constants are exact at 1024).
pub fn cost(instr: &PimInstruction, rows: usize) -> InstructionCost {
    let n = instr.n();
    let m = instr.m();
    match instr.op {
        Opcode::EqImm => {
            let (i0, i1) = popcounts(instr.imm, n);
            InstructionCost {
                col_cycles: i0 + 3 * i1 + 1,
                row_cycles: 0,
                intermediate_cells: 1,
            }
        }
        Opcode::NeImm => {
            let (i0, i1) = popcounts(instr.imm, n);
            InstructionCost {
                col_cycles: i0 + 3 * i1 + 3,
                row_cycles: 0,
                intermediate_cells: 2,
            }
        }
        Opcode::LtImm => {
            let (i0, i1) = popcounts(instr.imm, n);
            InstructionCost {
                col_cycles: 11 * i0 + 3 * i1 + 4,
                row_cycles: 0,
                intermediate_cells: 5,
            }
        }
        Opcode::GtImm => {
            let (i0, i1) = popcounts(instr.imm, n);
            InstructionCost {
                col_cycles: 11 * i0 + 3 * i1 + 2,
                row_cycles: 0,
                intermediate_cells: 6,
            }
        }
        Opcode::AddImm => InstructionCost {
            col_cycles: 18 * n + 3,
            row_cycles: 0,
            intermediate_cells: 8,
        },
        Opcode::Eq => InstructionCost {
            col_cycles: 11 * n + 3,
            row_cycles: 0,
            intermediate_cells: 5,
        },
        Opcode::Lt => InstructionCost {
            col_cycles: 16 * n + 2,
            row_cycles: 0,
            intermediate_cells: 6,
        },
        Opcode::Set | Opcode::Reset => InstructionCost {
            col_cycles: n,
            row_cycles: 0,
            intermediate_cells: 0,
        },
        Opcode::Not => InstructionCost {
            col_cycles: 2 * n,
            row_cycles: 0,
            intermediate_cells: 0,
        },
        Opcode::And => InstructionCost {
            col_cycles: 6 * n,
            row_cycles: 0,
            intermediate_cells: 2,
        },
        Opcode::Or => InstructionCost {
            col_cycles: 4 * n,
            row_cycles: 0,
            intermediate_cells: 1,
        },
        Opcode::Add => InstructionCost {
            col_cycles: 18 * n + 1,
            row_cycles: 0,
            intermediate_cells: 6,
        },
        Opcode::Mul => InstructionCost {
            // 24nm - 19n + 2m - 1 (n = in-memory operand, m = 2nd operand)
            col_cycles: (24 * n * m + 2 * m).saturating_sub(19 * n + 1),
            row_cycles: 0,
            intermediate_cells: 6,
        },
        Opcode::ReduceSum => {
            // Total (Table 4, 1024 rows): 2254n + 3006.
            // Row component (structural): sum width grows by 1/level.
            let row = reduce_row_cycles(rows, |k| n + k); // 2046n + 2026 @1024
            let total = scale_reduce_total(2254 * n + 3006, rows);
            InstructionCost {
                col_cycles: total.saturating_sub(row),
                row_cycles: row,
                intermediate_cells: n + 15,
            }
        }
        Opcode::ReduceMin | Opcode::ReduceMax => {
            let row = reduce_row_cycles(rows, |_| n); // 2046n @1024
            let total = scale_reduce_total(2306 * n + 200, rows);
            InstructionCost {
                col_cycles: total.saturating_sub(row),
                row_cycles: row,
                intermediate_cells: n + 7,
            }
        }
        Opcode::ColumnTransform => InstructionCost {
            // 2050 total (Table 4): 2 x 1024 row-wise bit moves + 2 setup.
            col_cycles: 2,
            row_cycles: 2 * rows as u64,
            intermediate_cells: 1,
        },
    }
}

/// Table 4 reduce totals are measured at 1024 rows; for other geometries
/// scale by the ratio of tree levels (tests only rely on the 1024 case and
/// monotonicity).
fn scale_reduce_total(total_at_1024: u64, rows: usize) -> u64 {
    let l = levels(rows);
    (total_at_1024 * l) / 10
}

/// Endurance write profile of one instruction (cell writes per row).
pub fn write_profile(instr: &PimInstruction, rows: usize) -> RowWrites {
    let c = cost(instr, rows);
    match instr.op {
        Opcode::ReduceSum | Opcode::ReduceMin | Opcode::ReduceMax => {
            // column-wise cycles hit every row (the §5.2.3 restriction:
            // reduce steps operate on all rows, participating or not);
            // row-wise moves write only the surviving-half target rows.
            let n = instr.n();
            let mut prefix = vec![(rows, c.col_cycles)];
            for k in 0..levels(rows) {
                let targets = rows >> (k + 1);
                let width = match instr.op {
                    Opcode::ReduceSum => n + k,
                    _ => n,
                };
                prefix.push((targets, 2 * width));
            }
            RowWrites::Prefix(prefix)
        }
        Opcode::ColumnTransform => {
            // 1024 result bits land in rows 0..rows/read_bits as 16-bit
            // groups; every moved bit costs 2 writes in its target row.
            let target_rows = rows / crate::util::bits::XBAR_READ_BITS;
            let writes_per_target = 2 * (rows / target_rows) as u64;
            let mut prefix = vec![(rows, c.col_cycles)];
            prefix.push((target_rows, writes_per_target));
            RowWrites::Prefix(prefix)
        }
        _ => RowWrites::AllRows(c.col_cycles),
    }
}

/// Executable FSM micro-sequences on the cell-accurate crossbar reference.
/// Used by unit tests and the `pimdb inspect-fsm` tool, not by the fast
/// engine.
pub mod fsm {
    use super::*;

    /// Bitwise AND of two column ranges, exactly 6n column ops
    /// (set t1, not a_i, set t2, not b_i, set out, nor): Table 4 row "Bitwise
    /// AND" with 2 intermediate cells.
    pub fn and(xb: &mut Crossbar, instr: &PimInstruction, t1: usize, t2: usize) {
        let b = instr.src_b.expect("and needs src_b");
        for i in 0..instr.n() as usize {
            let (a_i, b_i, o_i) = (
                instr.src_a.start as usize + i,
                b.start as usize + i,
                instr.dst.start as usize + i,
            );
            xb.col_set(t1);
            xb.col_nor(a_i, a_i, t1);
            xb.col_set(t2);
            xb.col_nor(b_i, b_i, t2);
            xb.col_set(o_i);
            xb.col_nor(t1, t2, o_i);
        }
    }

    /// Bitwise OR, exactly 4n column ops with 1 intermediate cell.
    pub fn or(xb: &mut Crossbar, instr: &PimInstruction, t1: usize) {
        let b = instr.src_b.expect("or needs src_b");
        for i in 0..instr.n() as usize {
            let (a_i, b_i, o_i) = (
                instr.src_a.start as usize + i,
                b.start as usize + i,
                instr.dst.start as usize + i,
            );
            xb.col_set(t1);
            xb.col_nor(a_i, b_i, t1);
            xb.col_set(o_i);
            xb.col_nor(t1, t1, o_i);
        }
    }

    /// Bitwise NOT, exactly 2n column ops, no intermediates.
    pub fn not(xb: &mut Crossbar, instr: &PimInstruction) {
        for i in 0..instr.n() as usize {
            let (a_i, o_i) = (
                instr.src_a.start as usize + i,
                instr.dst.start as usize + i,
            );
            xb.col_set(o_i);
            xb.col_nor(a_i, a_i, o_i);
        }
    }

    /// SET/RESET of n columns, exactly n ops.
    pub fn set_reset(xb: &mut Crossbar, instr: &PimInstruction) {
        for i in 0..instr.n() as usize {
            let c = instr.src_a.start as usize + i;
            match instr.op {
                Opcode::Set => xb.col_set(c),
                Opcode::Reset => xb.col_reset(c),
                _ => unreachable!(),
            }
        }
    }

    /// Equality-with-immediate (Algorithm 1) — semantic reference. The
    /// realization below uses plain NOT/NOR idioms and is *not* cycle-exact
    /// vs Table 4 (the paper's count relies on [36]'s optimized cell
    /// mappings); `cost()` stays authoritative for timing.
    pub fn eq_imm(xb: &mut Crossbar, instr: &PimInstruction, t1: usize, t2: usize) {
        let out = instr.dst.start as usize;
        xb.col_set(out); // m_eq <- 1
        for i in 0..instr.n() as usize {
            let v_i = instr.src_a.start as usize + i;
            let bit = (instr.imm >> i) & 1;
            if bit == 1 {
                // m_eq <- v_i AND m_eq
                xb.col_set(t1);
                xb.col_nor(v_i, v_i, t1); // t1 = ~v
                xb.col_set(t2);
                xb.col_nor(out, out, t2); // t2 = ~m_eq
                xb.col_set(out);
                xb.col_nor(t1, t2, out);
            } else {
                // m_eq <- NOT(v_i) AND m_eq
                xb.col_set(t1);
                xb.col_nor(out, out, t1); // t1 = ~m_eq
                xb.col_set(out);
                xb.col_nor(v_i, t1, out); // ~v & m_eq
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::isa::ColRange;
    use crate::util::proptest::check;

    fn instr(op: Opcode, n: usize) -> PimInstruction {
        PimInstruction::unary(op, ColRange::new(0, n), ColRange::new(100, n))
    }

    fn instr_bin(op: Opcode, n: usize, m: usize) -> PimInstruction {
        PimInstruction::binary(
            op,
            ColRange::new(0, n),
            ColRange::new(64, m),
            ColRange::new(128, n.max(m)),
        )
    }

    #[test]
    fn table4_closed_forms_at_1024() {
        // spot values straight from Table 4 with n=32, m=16
        let n = 32u64;
        assert_eq!(cost(&instr(Opcode::AddImm, 32), 1024).total_cycles(), 18 * n + 3);
        assert_eq!(cost(&instr_bin(Opcode::Eq, 32, 32), 1024).total_cycles(), 11 * n + 3);
        assert_eq!(cost(&instr_bin(Opcode::Lt, 32, 32), 1024).total_cycles(), 16 * n + 2);
        assert_eq!(cost(&instr(Opcode::Set, 32), 1024).total_cycles(), n);
        assert_eq!(cost(&instr(Opcode::Not, 32), 1024).total_cycles(), 2 * n);
        assert_eq!(cost(&instr_bin(Opcode::And, 32, 32), 1024).total_cycles(), 6 * n);
        assert_eq!(cost(&instr_bin(Opcode::Or, 32, 32), 1024).total_cycles(), 4 * n);
        assert_eq!(cost(&instr_bin(Opcode::Add, 32, 32), 1024).total_cycles(), 18 * n + 1);
        let m = 16u64;
        assert_eq!(
            cost(&instr_bin(Opcode::Mul, 32, 16), 1024).total_cycles(),
            24 * n * m - 19 * n + 2 * m - 1
        );
        assert_eq!(
            cost(&instr(Opcode::ReduceSum, 32), 1024).total_cycles(),
            2254 * n + 3006
        );
        assert_eq!(
            cost(&instr(Opcode::ReduceMin, 32), 1024).total_cycles(),
            2306 * n + 200
        );
        assert_eq!(
            cost(&instr(Opcode::ColumnTransform, 1), 1024).total_cycles(),
            2050
        );
    }

    #[test]
    fn imm_compare_costs_depend_on_popcount() {
        check("imm-costs", 100, |g| {
            let n = g.usize(1, 64);
            let imm = g.skewed_u64();
            let masked = if n >= 64 { imm } else { imm & ((1 << n) - 1) };
            let i1 = masked.count_ones() as u64;
            let i0 = n as u64 - i1;
            let mk = |op| PimInstruction::with_imm(op, ColRange::new(0, n), ColRange::new(100, 1), imm);
            assert_eq!(cost(&mk(Opcode::EqImm), 1024).total_cycles(), i0 + 3 * i1 + 1);
            assert_eq!(cost(&mk(Opcode::NeImm), 1024).total_cycles(), i0 + 3 * i1 + 3);
            assert_eq!(cost(&mk(Opcode::LtImm), 1024).total_cycles(), 11 * i0 + 3 * i1 + 4);
            assert_eq!(cost(&mk(Opcode::GtImm), 1024).total_cycles(), 11 * i0 + 3 * i1 + 2);
        });
    }

    #[test]
    fn immediate_in_control_path_beats_in_memory_compare() {
        // §3.3: using the immediate in the control path must never be
        // slower than the two-operand compare of the same width.
        check("imm-wins", 100, |g| {
            let n = g.usize(1, 64);
            let imm = g.skewed_u64();
            let ci = cost(
                &PimInstruction::with_imm(Opcode::EqImm, ColRange::new(0, n), ColRange::new(100, 1), imm),
                1024,
            );
            let cc = cost(&instr_bin(Opcode::Eq, n, n), 1024);
            assert!(ci.total_cycles() <= cc.total_cycles());
        });
    }

    #[test]
    fn reduce_split_matches_structural_derivation() {
        // row component at 1024 rows: sum -> 2046n + 2026; min/max -> 2046n
        for n in [1u64, 8, 17, 33, 64] {
            let cs = cost(&instr(Opcode::ReduceSum, n as usize), 1024);
            assert_eq!(cs.row_cycles, 2046 * n + 2026);
            assert_eq!(cs.col_cycles, 2254 * n + 3006 - (2046 * n + 2026));
            let cm = cost(&instr(Opcode::ReduceMin, n as usize), 1024);
            assert_eq!(cm.row_cycles, 2046 * n);
            assert_eq!(cm.col_cycles, 260 * n + 200);
        }
    }

    #[test]
    fn reduce_cost_monotone_in_rows() {
        let i = instr(Opcode::ReduceSum, 32);
        let c256 = cost(&i, 256).total_cycles();
        let c1024 = cost(&i, 1024).total_cycles();
        assert!(c256 < c1024);
    }

    #[test]
    fn fsm_and_or_not_are_cycle_exact() {
        for n in [1usize, 7, 32] {
            let mut xb = Crossbar::new(64, 256);
            let i = instr_bin(Opcode::And, n, n);
            fsm::and(&mut xb, &i, 200, 201);
            assert_eq!(xb.counts().col_ops, cost(&i, 64).col_cycles);

            let mut xb = Crossbar::new(64, 256);
            let i = instr_bin(Opcode::Or, n, n);
            fsm::or(&mut xb, &i, 200);
            assert_eq!(xb.counts().col_ops, cost(&i, 64).col_cycles);

            let mut xb = Crossbar::new(64, 256);
            let i = instr(Opcode::Not, n);
            fsm::not(&mut xb, &i);
            assert_eq!(xb.counts().col_ops, cost(&i, 64).col_cycles);

            let mut xb = Crossbar::new(64, 256);
            let i = instr(Opcode::Set, n);
            fsm::set_reset(&mut xb, &i);
            assert_eq!(xb.counts().col_ops, cost(&i, 64).col_cycles);
        }
    }

    #[test]
    fn fsm_semantics_match_integer_ops() {
        check("fsm-semantics", 30, |g| {
            let n = g.usize(1, 16);
            let rows = 64;
            let mut xb = Crossbar::new(rows, 256);
            let mut a_vals = Vec::new();
            let mut b_vals = Vec::new();
            for r in 0..rows {
                let a = g.u64(0, (1 << n) - 1);
                let b = g.u64(0, (1 << n) - 1);
                xb.write_bits(r, 0, n, a);
                xb.write_bits(r, 64, n, b);
                a_vals.push(a);
                b_vals.push(b);
            }
            let i = instr_bin(Opcode::And, n, n);
            fsm::and(&mut xb, &i, 200, 201);
            for r in 0..rows {
                assert_eq!(xb.read_bits(r, 128, n), a_vals[r] & b_vals[r]);
            }
            let i = instr_bin(Opcode::Or, n, n);
            fsm::or(&mut xb, &i, 202);
            for r in 0..rows {
                assert_eq!(xb.read_bits(r, 128, n), a_vals[r] | b_vals[r]);
            }
        });
    }

    #[test]
    fn fsm_eq_imm_algorithm1_semantics() {
        check("alg1-eq", 30, |g| {
            let n = g.usize(1, 20);
            let rows = 64;
            let mut xb = Crossbar::new(rows, 256);
            let imm = g.u64(0, (1u64 << n) - 1);
            let mut vals = Vec::new();
            for r in 0..rows {
                // half the rows get the immediate itself
                let v = if g.bool() { imm } else { g.u64(0, (1 << n) - 1) };
                xb.write_bits(r, 0, n, v);
                vals.push(v);
            }
            let i = PimInstruction::with_imm(
                Opcode::EqImm,
                ColRange::new(0, n),
                ColRange::new(128, 1),
                imm,
            );
            fsm::eq_imm(&mut xb, &i, 200, 201);
            for r in 0..rows {
                assert_eq!(xb.get(r, 128), vals[r] == imm, "row {r}");
            }
        });
    }

    #[test]
    fn write_profile_reduce_prefix_shape() {
        let i = instr(Opcode::ReduceSum, 8);
        match write_profile(&i, 1024) {
            RowWrites::Prefix(p) => {
                assert_eq!(p[0].0, 1024); // col ops hit all rows
                assert_eq!(p.len(), 1 + 10); // 10 tree levels
                // surviving halves shrink: 512, 256, ...
                assert_eq!(p[1].0, 512);
                assert_eq!(p[10].0, 1);
            }
            _ => panic!("expected prefix profile"),
        }
    }

    #[test]
    fn write_profile_simple_ops_uniform() {
        let i = instr_bin(Opcode::And, 16, 16);
        assert_eq!(write_profile(&i, 1024), RowWrites::AllRows(96));
    }
}
