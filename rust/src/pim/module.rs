//! PIM module + media controller timing simulation (paper §3.2).
//!
//! Each PIM module is one memory rank on a private OpenCAPI channel. The
//! media controller schedules reads, writes, and PIM requests with an
//! FR-FCFS-class policy: requests are considered in arrival order, but a
//! request only waits on *its own* resources (channel, destination bank,
//! destination page's PIM controllers), so later requests to free banks
//! overtake earlier requests to busy ones — the "first-ready" part —
//! while same-resource requests keep arrival order — the "first-come"
//! part. Dependencies between PIM requests and reads to the same page are
//! enforced by page/bank serialization plus the issue-time fences the
//! executor inserts between computation and read phases.

use crate::config::SystemConfig;

use super::timing::Timing;

/// Physical placement of a huge-page (assigned to a single bank of a
/// single module — paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PageLoc {
    /// PIM module (rank) index.
    pub module: usize,
    /// Bank within the module.
    pub bank: usize,
    /// Dense page index (unique across the system).
    pub page: usize,
}

/// What a media-controller request does.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReqKind {
    /// A PIM instruction: `cycles` stateful-logic cycles executed by all
    /// the page's PIM controllers in lockstep.
    Pim { cycles: u64 },
    /// Result read-out of `bytes` from the page's bank arrays.
    ReadBurst { bytes: u64 },
    /// Bulk write of `bytes` into the page (database load path).
    WriteBurst { bytes: u64 },
}

/// One request to a PIM module's media controller.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Destination page placement.
    pub loc: PageLoc,
    /// Operation kind and size.
    pub kind: ReqKind,
    /// Earliest start (program order / fences).
    pub issue_ps: u64,
}

/// Scheduling result of one request.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Completion {
    /// When the request started occupying its resources (ps).
    pub start_ps: u64,
    /// When the request finished (ps).
    pub end_ps: u64,
    /// Interval during which the page's PIM controllers were busy (for
    /// power deposits); zero-length for non-PIM requests.
    pub pim_busy: (u64, u64),
}

/// Scheduler state across all modules. Resource timestamps are dense
/// vectors (page/bank ids are small and dense) — this function is the
/// timing simulation's inner loop (~100k requests for Q1).
pub struct MediaScheduler {
    timing: Timing,
    banks_per_module: usize,
    channel_free: Vec<u64>, // per module
    bank_free: Vec<u64>,    // [module * banks + bank]
    page_free: Vec<u64>,    // grown on demand
}

impl MediaScheduler {
    /// A scheduler with all resources free at time zero.
    pub fn new(cfg: &SystemConfig) -> Self {
        MediaScheduler {
            timing: Timing::new(cfg),
            banks_per_module: cfg.banks_per_module,
            channel_free: vec![0; cfg.pim_modules],
            bank_free: vec![0; cfg.pim_modules * cfg.banks_per_module],
            page_free: Vec::new(),
        }
    }

    /// The derived interface timing parameters.
    pub fn timing(&self) -> &Timing {
        &self.timing
    }

    /// Schedule one request; returns its completion. Requests must be fed
    /// in arrival order per requester; cross-bank reordering happens
    /// naturally (see module docs).
    pub fn schedule(&mut self, req: &Request) -> Completion {
        let t = &self.timing;
        let ch = &mut self.channel_free[req.loc.module];
        let bank = &mut self.bank_free[req.loc.module * self.banks_per_module + req.loc.bank];
        if self.page_free.len() <= req.loc.page {
            self.page_free.resize(req.loc.page + 1, 0);
        }
        let page = &mut self.page_free[req.loc.page];
        match req.kind {
            ReqKind::Pim { cycles } => {
                // request packet crosses the channel (32 B payload)
                let ch_start = req.issue_ps.max(*ch);
                let ch_occ = t.channel_occupancy_ps(32);
                *ch = ch_start + ch_occ;
                // PIM controllers start once the packet lands and the page
                // is free (previous instruction retired)
                let start = (ch_start + ch_occ + t.channel_latency_ps).max(*page);
                let end = start + t.pim_exec_ps(cycles);
                *page = end;
                // the page's bank is NOT blocked: other subarrays keep
                // serving reads (paper §3.2) — bank_free untouched.
                Completion {
                    start_ps: ch_start,
                    end_ps: end,
                    pim_busy: (start, end),
                }
            }
            ReqKind::ReadBurst { bytes } => {
                // must observe prior PIM results on this page
                let ready = req.issue_ps.max(*page).max(*bank);
                let bank_done = ready + t.bank_read_ps(bytes);
                *bank = bank_done;
                // data streams over the channel once beats appear
                let ch_start = ready.max(*ch);
                let ch_done = ch_start + t.channel_occupancy_ps(bytes);
                *ch = ch_done;
                let end = bank_done.max(ch_done) + t.channel_latency_ps;
                Completion {
                    start_ps: ready,
                    end_ps: end,
                    pim_busy: (ready, ready),
                }
            }
            ReqKind::WriteBurst { bytes } => {
                let ch_start = req.issue_ps.max(*ch);
                let ch_done = ch_start + t.channel_occupancy_ps(bytes);
                *ch = ch_done;
                let ready = (ch_start + t.channel_latency_ps).max(*bank).max(*page);
                let end = ready.max(ch_done) + t.bank_write_ps(bytes);
                *bank = end;
                *page = end;
                Completion {
                    start_ps: ch_start,
                    end_ps: end,
                    pim_busy: (ch_start, ch_start),
                }
            }
        }
    }

    /// Latest completion seen by any resource (simulation end time).
    pub fn horizon_ps(&self) -> u64 {
        let ch = self.channel_free.iter().copied().max().unwrap_or(0);
        let bk = self.bank_free.iter().copied().max().unwrap_or(0);
        let pg = self.page_free.iter().copied().max().unwrap_or(0);
        ch.max(bk).max(pg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(module: usize, bank: usize, page: usize) -> PageLoc {
        PageLoc { module, bank, page }
    }

    fn sched() -> MediaScheduler {
        MediaScheduler::new(&SystemConfig::default())
    }

    #[test]
    fn pim_requests_to_same_page_serialize() {
        let mut s = sched();
        let r = Request {
            loc: loc(0, 0, 0),
            kind: ReqKind::Pim { cycles: 100 },
            issue_ps: 0,
        };
        let c1 = s.schedule(&r);
        let c2 = s.schedule(&r);
        assert!(c2.pim_busy.0 >= c1.pim_busy.1);
    }

    #[test]
    fn pim_requests_to_different_pages_overlap() {
        let mut s = sched();
        let mk = |page| Request {
            loc: loc(0, page % 64, page),
            kind: ReqKind::Pim { cycles: 10_000 },
            issue_ps: 0,
        };
        let c1 = s.schedule(&mk(0));
        let c2 = s.schedule(&mk(1));
        // exec windows overlap even though the channel serialized packets
        assert!(c2.pim_busy.0 < c1.pim_busy.1);
    }

    #[test]
    fn read_after_pim_same_page_waits() {
        let mut s = sched();
        let c1 = s.schedule(&Request {
            loc: loc(0, 0, 0),
            kind: ReqKind::Pim { cycles: 1000 },
            issue_ps: 0,
        });
        let c2 = s.schedule(&Request {
            loc: loc(0, 0, 0),
            kind: ReqKind::ReadBurst { bytes: 64 },
            issue_ps: 0,
        });
        assert!(c2.start_ps >= c1.end_ps);
    }

    #[test]
    fn read_overtakes_busy_unrelated_page_fr_fcfs() {
        let mut s = sched();
        let c_pim = s.schedule(&Request {
            loc: loc(0, 0, 0),
            kind: ReqKind::Pim { cycles: 1_000_000 },
            issue_ps: 0,
        });
        // read to a different bank/page must not wait for the long PIM op
        let c_rd = s.schedule(&Request {
            loc: loc(0, 1, 1),
            kind: ReqKind::ReadBurst { bytes: 4096 },
            issue_ps: 0,
        });
        assert!(c_rd.end_ps < c_pim.end_ps);
    }

    #[test]
    fn reads_same_bank_serialize_but_channel_pipelines() {
        let mut s = sched();
        let mk = |bank| Request {
            loc: loc(0, bank, bank),
            kind: ReqKind::ReadBurst { bytes: 1 << 20 },
            issue_ps: 0,
        };
        let a = s.schedule(&mk(0));
        let b = s.schedule(&mk(0)); // same bank: serial
        assert!(b.end_ps >= a.end_ps);
        let mut s2 = sched();
        let a2 = s2.schedule(&mk(0));
        let b2 = s2.schedule(&mk(1)); // different bank: overlapping arrays
        assert!(b2.start_ps < a2.end_ps);
    }

    #[test]
    fn modules_are_independent_channels() {
        let mut s = sched();
        let mk = |m| Request {
            loc: loc(m, 0, m * 1000),
            kind: ReqKind::ReadBurst { bytes: 1 << 20 },
            issue_ps: 0,
        };
        let a = s.schedule(&mk(0));
        let b = s.schedule(&mk(1));
        // both start immediately: separate channels
        assert_eq!(a.start_ps, b.start_ps);
    }

    #[test]
    fn issue_fence_respected() {
        let mut s = sched();
        let c = s.schedule(&Request {
            loc: loc(0, 0, 0),
            kind: ReqKind::Pim { cycles: 1 },
            issue_ps: 12345678,
        });
        assert!(c.start_ps >= 12345678);
        assert!(s.horizon_ps() >= c.end_ps);
    }
}
