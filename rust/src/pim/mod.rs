//! PIM module hardware model (paper §3, §5.2): crossbars with MAGIC NOR
//! stateful logic, PIM controllers, media controller with FR-FCFS
//! scheduling, and the energy / endurance / area / power accounting.

pub mod area;
pub mod controller;
pub mod crossbar;
pub mod endurance;
pub mod energy;
pub mod isa;
pub mod module;
pub mod power;
pub mod timing;
