//! Interface timing models: OpenCAPI channel and R-DDR array access
//! (paper §3.2, §5.2.1, Table 3).

use crate::config::SystemConfig;

/// Picoseconds helper.
pub const PS_PER_NS: u64 = 1000;

/// Derived interface timing parameters (all picoseconds).
#[derive(Clone, Debug)]
pub struct Timing {
    /// Channel byte time (ps/byte) including protocol header amortization
    /// for streaming transfers.
    pub channel_ps_per_byte: f64,
    /// One-way channel latency (ps).
    pub channel_latency_ps: u64,
    /// Stateful logic cycle (ps).
    pub logic_cycle_ps: u64,
    /// Bank array read throughput (ps/byte): an R-DDR access retrieves
    /// 16 bits from each of 32 lockstep crossbars (64 B) per array cycle.
    pub bank_read_ps_per_byte: f64,
    /// Fixed array access latency for the first beat (ps).
    pub bank_access_ps: u64,
    /// Bank array write throughput (ps/byte).
    pub bank_write_ps_per_byte: f64,
}

impl Timing {
    /// Derive the interface timings from the system configuration.
    pub fn new(cfg: &SystemConfig) -> Self {
        let line = 64.0;
        let header = cfg.opencapi_header_bytes as f64;
        // effective channel rate accounts for per-line header overhead
        let eff_bw = cfg.opencapi_bw_bps * line / (line + header);
        // R-DDR: one 64 B array beat per logic-class array cycle (30 ns).
        let beat_ps = cfg.logic_cycle_ps as f64;
        Timing {
            channel_ps_per_byte: 1e12 / eff_bw,
            channel_latency_ps: cfg.opencapi_latency_ns * PS_PER_NS,
            logic_cycle_ps: cfg.logic_cycle_ps,
            bank_read_ps_per_byte: beat_ps / 64.0,
            bank_access_ps: cfg.rram_read_ns * PS_PER_NS,
            bank_write_ps_per_byte: beat_ps / 64.0 * 3.0,
        }
    }

    /// Time to stream `bytes` over the channel (occupancy, no latency).
    pub fn channel_occupancy_ps(&self, bytes: u64) -> u64 {
        (bytes as f64 * self.channel_ps_per_byte).ceil() as u64
    }

    /// Time for a bank to produce `bytes` of array reads (occupancy).
    pub fn bank_read_ps(&self, bytes: u64) -> u64 {
        self.bank_access_ps + (bytes as f64 * self.bank_read_ps_per_byte).ceil() as u64
    }

    /// Time for a bank to absorb `bytes` of array writes.
    pub fn bank_write_ps(&self, bytes: u64) -> u64 {
        self.bank_access_ps + (bytes as f64 * self.bank_write_ps_per_byte).ceil() as u64
    }

    /// PIM instruction execution time for `cycles` stateful-logic cycles.
    pub fn pim_exec_ps(&self, cycles: u64) -> u64 {
        cycles * self.logic_cycle_ps
    }

    /// Effective per-bank read bandwidth in bytes/s (for sanity checks).
    pub fn bank_read_bw_bps(&self) -> f64 {
        1e12 / self.bank_read_ps_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_slower_than_raw_bw_due_to_headers() {
        let cfg = SystemConfig::default();
        let t = Timing::new(&cfg);
        let raw_ps_per_byte = 1e12 / cfg.opencapi_bw_bps;
        assert!(t.channel_ps_per_byte > raw_ps_per_byte);
    }

    #[test]
    fn bank_read_bw_is_ddr_class() {
        let t = Timing::new(&SystemConfig::default());
        let bw = t.bank_read_bw_bps();
        // 64 B / 30 ns ≈ 2.1 GB/s per bank
        assert!(bw > 1e9 && bw < 5e9, "bw {bw}");
    }

    #[test]
    fn pim_exec_time_scales_with_cycles() {
        let t = Timing::new(&SystemConfig::default());
        assert_eq!(t.pim_exec_ps(100), 100 * 30_000);
    }

    #[test]
    fn occupancy_monotone() {
        let t = Timing::new(&SystemConfig::default());
        assert!(t.channel_occupancy_ps(128) > t.channel_occupancy_ps(64));
        assert!(t.bank_read_ps(4096) > t.bank_read_ps(64));
    }
}
