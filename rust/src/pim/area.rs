//! PIM module chip area model (paper §6.2, Fig. 10).
//!
//! The paper synthesized the PIM controller in TSMC 28 nm and ran a
//! modified NVSim for the chip; we substitute a first-order analytic model
//! calibrated to Fig. 10's reported breakdown: the memory mat (crossbars)
//! plus crossbar peripherals (row decoders, column muxes, sense amps,
//! write drivers) dominate, bank/chip interconnect and IO follow, and the
//! PIM controllers consume only ~0.17% of chip area.

use crate::config::SystemConfig;

/// F = feature size (m). RRAM 1R crossbar cell = 4F^2.
const FEATURE_M: f64 = 28e-9;
const CELL_AREA_F2: f64 = 4.0;

/// Chip area components in mm^2.
#[derive(Clone, Debug, PartialEq)]
pub struct ChipArea {
    /// RRAM crossbar arrays.
    pub crossbars_mm2: f64,
    /// Row decoders, column muxes, sense amps, write drivers.
    pub xbar_peripherals_mm2: f64,
    /// Bank/chip interconnect.
    pub bank_interconnect_mm2: f64,
    /// IO circuitry and pads.
    pub io_and_pads_mm2: f64,
    /// Synthesized PIM controllers (paper: ~0.17% of the chip).
    pub pim_controllers_mm2: f64,
}

impl ChipArea {
    /// Sum of all components (mm^2).
    pub fn total_mm2(&self) -> f64 {
        self.crossbars_mm2
            + self.xbar_peripherals_mm2
            + self.bank_interconnect_mm2
            + self.io_and_pads_mm2
            + self.pim_controllers_mm2
    }

    /// (component, mm^2) pairs in Fig. 10 order.
    pub fn breakdown(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("crossbar arrays", self.crossbars_mm2),
            ("crossbar peripherals", self.xbar_peripherals_mm2),
            ("bank interconnect", self.bank_interconnect_mm2),
            ("io + pads", self.io_and_pads_mm2),
            ("pim controllers", self.pim_controllers_mm2),
        ]
    }

    /// Fraction of the chip taken by PIM controllers (paper: 0.17%).
    pub fn pim_ctrl_fraction(&self) -> f64 {
        self.pim_controllers_mm2 / self.total_mm2()
    }
}

/// Synthesized PIM controller area (TSMC 28nm, paper §6.2): a small FSM +
/// sequencer of tens of kilo-gates, ~1600 um^2 per controller, which lands
/// the chip fraction at the reported ~0.17% for the default geometry
/// (each 16 GB chip carries thousands of controllers, one per 256
/// crossbars).
pub const PIM_CTRL_MM2: f64 = 0.0016;

/// Compute the chip-level area breakdown for one PIM memory chip.
/// A module has `chips_per_module` chips sharing the capacity.
pub fn chip_area(cfg: &SystemConfig) -> ChipArea {
    let chip_bytes = cfg.module_capacity as f64 / cfg.chips_per_module as f64;
    let cells = chip_bytes * 8.0;
    let cell_mm2 = CELL_AREA_F2 * FEATURE_M * FEATURE_M * 1e6; // m^2 -> mm^2
    let crossbars = cells * cell_mm2;

    // Peripherals (decoders, muxes, SAs, drivers) per crossbar: NVSim-class
    // overhead for small mats is comparable to the mat itself; with the
    // paper's extra logic voltage drivers we take 95% of the array area.
    let peripherals = crossbars * 0.95;

    // Bank-level interconnect + global decoding: ~12% of mat+peripherals.
    let interconnect = (crossbars + peripherals) * 0.12;

    // IO, pads, media-controller interface share per chip: ~6 mm^2.
    let io = 6.0;

    let xbars_per_chip = cells / (cfg.xbar_rows * cfg.xbar_cols) as f64;
    let ctrls = xbars_per_chip
        / (cfg.subarrays_per_pim_ctrl * cfg.xbars_per_subarray) as f64;
    let pim_ctrls = ctrls * PIM_CTRL_MM2;

    ChipArea {
        crossbars_mm2: crossbars,
        xbar_peripherals_mm2: peripherals,
        bank_interconnect_mm2: interconnect,
        io_and_pads_mm2: io,
        pim_controllers_mm2: pim_ctrls,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pim_controller_fraction_near_paper() {
        let a = chip_area(&SystemConfig::default());
        let f = a.pim_ctrl_fraction();
        // paper: 0.17% — allow [0.05%, 0.5%] for the analytic substitute
        assert!(f > 0.0005 && f < 0.005, "fraction {f}");
    }

    #[test]
    fn crossbars_dominate() {
        let a = chip_area(&SystemConfig::default());
        assert!(a.crossbars_mm2 > a.bank_interconnect_mm2);
        assert!(a.crossbars_mm2 + a.xbar_peripherals_mm2 > 0.5 * a.total_mm2());
    }

    #[test]
    fn breakdown_sums_to_total() {
        let a = chip_area(&SystemConfig::default());
        let sum: f64 = a.breakdown().iter().map(|(_, v)| v).sum();
        assert!((sum - a.total_mm2()).abs() < 1e-9);
    }

    #[test]
    fn area_scales_with_capacity() {
        let mut cfg = SystemConfig::default();
        let a1 = chip_area(&cfg).total_mm2();
        cfg.module_capacity /= 2;
        let a2 = chip_area(&cfg).total_mm2();
        assert!(a2 < a1);
    }
}
