//! PIM module power sampling (paper §6.3, Fig. 14).
//!
//! Power is sampled as the average over 100 ns windows. Deposits are O(1)
//! per event (a rate difference array, finalized once), so the tracker
//! absorbs millions of events. Chip power = module power / chips (a bank
//! is distributed across the module's chips in lockstep).

use crate::config::SystemConfig;

/// Power-averaging window (ps): 100 ns, as in the paper's Fig. 14.
pub const WINDOW_PS: u64 = 100_000;

/// Per-module power trace built from (start, end, energy) deposits.
pub struct PowerTrace {
    /// rate change marks per module: (window index, dPower[W])
    marks: Vec<Vec<(usize, f64)>>,
    total_pj: Vec<f64>,
    end_ps: u64,
}

impl PowerTrace {
    /// An empty trace for `modules` PIM modules.
    pub fn new(modules: usize) -> Self {
        PowerTrace {
            marks: vec![Vec::new(); modules],
            total_pj: vec![0.0; modules],
            end_ps: 0,
        }
    }

    /// Deposit `energy_pj` uniformly over [start_ps, end_ps) on `module`.
    pub fn deposit(&mut self, module: usize, start_ps: u64, end_ps: u64, energy_pj: f64) {
        if energy_pj <= 0.0 {
            return;
        }
        let end = end_ps.max(start_ps + 1);
        let w0 = (start_ps / WINDOW_PS) as usize;
        let w1 = ((end - 1) / WINDOW_PS + 1) as usize;
        // rate in W over the covered whole windows (window-quantized)
        let rate = energy_pj / ((w1 - w0) as f64 * WINDOW_PS as f64);
        self.marks[module].push((w0, rate));
        self.marks[module].push((w1, -rate));
        self.total_pj[module] += energy_pj;
        self.end_ps = self.end_ps.max(end);
    }

    /// (peak W, average W) per module over the observed span.
    pub fn finalize(&self) -> Vec<(f64, f64)> {
        let span_ps = self.end_ps.max(1) as f64;
        self.marks
            .iter()
            .enumerate()
            .map(|(m, marks)| {
                let mut sorted = marks.clone();
                sorted.sort_by_key(|&(w, _)| w);
                let mut rate = 0.0f64;
                let mut peak = 0.0f64;
                for &(_, d) in &sorted {
                    rate += d;
                    peak = peak.max(rate);
                }
                (peak, self.total_pj[m] / span_ps)
            })
            .collect()
    }

    /// Peak chip power (W): max over modules / chips per module.
    pub fn peak_chip_w(&self, cfg: &SystemConfig) -> f64 {
        self.finalize()
            .iter()
            .fold(0.0f64, |a, &(p, _)| a.max(p))
            / cfg.chips_per_module as f64
    }

    /// Average chip power (W) of the busiest module.
    pub fn avg_chip_w(&self, cfg: &SystemConfig) -> f64 {
        self.finalize()
            .iter()
            .fold(0.0f64, |a, &(_, avg)| a.max(avg))
            / cfg.chips_per_module as f64
    }

    /// Latest deposit end seen so far (ps).
    pub fn end_ps(&self) -> u64 {
        self.end_ps
    }
}

/// Theoretical peak chip power if *all crossbars* of a module execute a
/// column-wise stateful-logic cycle simultaneously (paper: ~730 W/chip).
pub fn theoretical_peak_all_xbars_chip_w(cfg: &SystemConfig) -> f64 {
    let xbars = cfg.module_capacity as f64 * 8.0
        / (cfg.xbar_rows * cfg.xbar_cols) as f64;
    let cells_per_cycle = xbars * cfg.xbar_rows as f64;
    let energy_j = cells_per_cycle * cfg.logic_energy_fj_per_bit * 1e-15;
    let cycle_s = cfg.logic_cycle_ps as f64 * 1e-12;
    energy_j / cycle_s / cfg.chips_per_module as f64
}

/// Theoretical peak chip power when all `pages_accessed` pages of the
/// busiest module operate in parallel (paper Fig. 14 "theoretical": up to
/// ~330 W for the largest query footprint).
pub fn theoretical_peak_query_chip_w(cfg: &SystemConfig, pages_in_max_module: u64) -> f64 {
    let pages_per_module = cfg.module_capacity / cfg.page_bytes;
    theoretical_peak_all_xbars_chip_w(cfg) * pages_in_max_module as f64
        / pages_per_module as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theoretical_peak_matches_paper_scale() {
        let w = theoretical_peak_all_xbars_chip_w(&SystemConfig::default());
        // paper: ~730 W per chip
        assert!((w - 730.0).abs() / 730.0 < 0.05, "{w}");
    }

    #[test]
    fn query_peak_scales_with_pages() {
        let cfg = SystemConfig::default();
        let full = theoretical_peak_all_xbars_chip_w(&cfg);
        let half = theoretical_peak_query_chip_w(&cfg, 64); // 64 of 128 pages
        assert!((half - full / 2.0).abs() / full < 1e-9);
    }

    #[test]
    fn trace_peak_and_avg() {
        let cfg = SystemConfig::default();
        let mut t = PowerTrace::new(1);
        // 1 W for exactly one window: 100 ns * 1 W = 1e5 pJ
        t.deposit(0, 0, WINDOW_PS, 1e5);
        // quiet second window
        t.deposit(0, WINDOW_PS, 2 * WINDOW_PS, 0.0);
        let f = t.finalize();
        assert!((f[0].0 - 1.0).abs() < 1e-9);
        // average over the 100 ns span (end_ps = WINDOW_PS since the
        // zero-energy deposit is skipped)
        assert!((f[0].1 - 1.0).abs() < 1e-9);
        assert!(t.peak_chip_w(&cfg) > 0.0);
    }

    #[test]
    fn overlapping_deposits_sum() {
        let mut t = PowerTrace::new(1);
        t.deposit(0, 0, WINDOW_PS, 1e5);
        t.deposit(0, 0, WINDOW_PS, 1e5);
        let f = t.finalize();
        assert!((f[0].0 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn modules_tracked_independently() {
        let mut t = PowerTrace::new(2);
        t.deposit(0, 0, WINDOW_PS, 1e5);
        t.deposit(1, 0, WINDOW_PS, 3e5);
        let f = t.finalize();
        assert!(f[1].0 > f[0].0);
    }
}
