//! Versioned binary checkpoints: the crossbar bit-planes, row liveness/
//! wear state and epoch of every DML-tracked relation, plus the one-time
//! base image of the generated database.
//!
//! Two file kinds live in a data directory:
//!
//! * `base.img` — the deterministic dbgen output, written once at
//!   initialization so reopen never re-runs the generator (ROADMAP item
//!   4). DML never mutates the load image (the PIM copy is the mutable
//!   one), so one copy is enough forever.
//! * `ckpt-NNNNNNNN.pim` — generation-numbered checkpoints. Each holds,
//!   per tracked relation: the epoch, the full bit-plane state of its
//!   crossbars, the committed [`crate::db::freerows::FreeRowMap`]
//!   liveness + wear vectors, and the unfolded reader-wear ledger.
//!   Untracked relations (never touched by DML) are omitted — recovery
//!   rematerializes them lazily from the base image, exactly like a
//!   fresh open.
//!
//! Every file is `[magic | fingerprint | body | fnv1a-digest]`, written
//! to a temp name, synced, then atomically renamed — so a crash never
//! leaves a half-written file under a valid name, and any bit rot is
//! caught by the whole-file digest before a single field is trusted.

use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::api::cache::fnv1a;
use crate::db::dbgen::{intern_column, Database, Relation};
use crate::db::schema::RelId;
use crate::error::PimdbError;
use crate::exec::engine::XbarState;
use crate::storage::wal::De;
use crate::util::bits::{WORDS, XBAR_ROWS};

/// First 8 bytes of a checkpoint file.
pub(crate) const CKPT_MAGIC: [u8; 8] = *b"PIMCKP01";
/// First 8 bytes of the base image.
pub(crate) const BASE_MAGIC: [u8; 8] = *b"PIMBAS01";

/// Fixed-size checkpoint header following the magic bytes. Kept as its
/// own tiny codec so the round-trip property tests can fuzz it directly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct CkptHeader {
    /// Schema/geometry fingerprint the checkpoint was taken under.
    pub fingerprint: u64,
    /// Generation number (matches the `ckpt-NNNNNNNN.pim` file name).
    pub generation: u64,
    /// Tracked relations serialized in the body.
    pub n_rels: u32,
}

impl CkptHeader {
    /// Serialize (magic included).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(28);
        b.extend_from_slice(&CKPT_MAGIC);
        b.extend_from_slice(&self.fingerprint.to_le_bytes());
        b.extend_from_slice(&self.generation.to_le_bytes());
        b.extend_from_slice(&self.n_rels.to_le_bytes());
        b
    }

    /// Decode from the reader (positioned at the magic).
    pub fn decode(d: &mut De<'_>) -> Result<CkptHeader, PimdbError> {
        let mut magic = [0u8; 8];
        for m in &mut magic {
            *m = d.u8()?;
        }
        if magic != CKPT_MAGIC {
            return Err(PimdbError::Corrupt("checkpoint header: bad magic".into()));
        }
        Ok(CkptHeader {
            fingerprint: d.u64()?,
            generation: d.u64()?,
            n_rels: d.u32()?,
        })
    }
}

/// Borrowed view of one relation's durable state, as captured under the
/// relation gate at checkpoint time.
pub(crate) struct CkptRelSnapshot<'a> {
    /// The relation.
    pub rel: RelId,
    /// Its committed epoch.
    pub epoch: u64,
    /// Published crossbar bit-plane states at that epoch.
    pub states: &'a [XbarState],
    /// Committed row liveness (capacity-long).
    pub live: Vec<bool>,
    /// Committed per-row wear (capacity-long).
    pub wear: Vec<u64>,
    /// Reader-wear ledger not yet folded into the committed map.
    pub ledger: Vec<u64>,
}

/// One relation's durable state as read back from a checkpoint.
pub(crate) struct CkptRel {
    /// The relation.
    pub rel: RelId,
    /// Its committed epoch.
    pub epoch: u64,
    /// Crossbar bit-plane states at that epoch.
    pub states: Vec<XbarState>,
    /// Committed row liveness.
    pub live: Vec<bool>,
    /// Committed per-row wear.
    pub wear: Vec<u64>,
    /// Reader-wear ledger not yet folded into the committed map.
    pub ledger: Vec<u64>,
}

/// Path of checkpoint `generation` under `dir`.
pub(crate) fn ckpt_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("ckpt-{generation:08}.pim"))
}

/// Path of the base image under `dir`.
pub(crate) fn base_path(dir: &Path) -> PathBuf {
    dir.join("base.img")
}

fn pack_bools(flags: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; flags.len().div_ceil(64)];
    for (i, &f) in flags.iter().enumerate() {
        if f {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

fn unpack_bools(d: &mut De<'_>, n: usize) -> Result<Vec<bool>, PimdbError> {
    let mut flags = Vec::with_capacity(n);
    let mut word = 0u64;
    for i in 0..n {
        if i % 64 == 0 {
            word = d.u64()?;
        }
        flags.push((word >> (i % 64)) & 1 == 1);
    }
    Ok(flags)
}

/// Serialize a checkpoint body and write it atomically as generation
/// `generation`. Returns the file size in bytes.
pub(crate) fn write_checkpoint(
    dir: &Path,
    fingerprint: u64,
    generation: u64,
    rels: &[CkptRelSnapshot<'_>],
) -> std::io::Result<u64> {
    let header = CkptHeader {
        fingerprint,
        generation,
        n_rels: rels.len() as u32,
    };
    let mut b = header.encode();
    for r in rels {
        b.push(super::wal::WalRecord::tag_of(r.rel));
        b.extend_from_slice(&r.epoch.to_le_bytes());
        b.extend_from_slice(&(r.states.len() as u32).to_le_bytes());
        let cols = r.states.first().map(|s| s.planes.len()).unwrap_or(0);
        b.extend_from_slice(&(cols as u32).to_le_bytes());
        for s in r.states {
            debug_assert_eq!(s.planes.len(), cols, "ragged crossbar state");
            for plane in &s.planes {
                for w in plane {
                    b.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
        debug_assert_eq!(r.live.len(), r.wear.len());
        b.extend_from_slice(&(r.live.len() as u64).to_le_bytes());
        for w in pack_bools(&r.live) {
            b.extend_from_slice(&w.to_le_bytes());
        }
        for w in &r.wear {
            b.extend_from_slice(&w.to_le_bytes());
        }
        debug_assert_eq!(r.ledger.len(), XBAR_ROWS);
        for w in &r.ledger {
            b.extend_from_slice(&w.to_le_bytes());
        }
    }
    let digest = fnv1a(&b);
    b.extend_from_slice(&digest.to_le_bytes());
    write_atomic(&ckpt_path(dir, generation), &b)?;
    Ok(b.len() as u64)
}

/// Read and fully verify checkpoint `generation`: magic, fingerprint,
/// whole-file digest, and per-relation shape invariants (state capacity
/// must equal the row-map capacity).
pub(crate) fn read_checkpoint(
    dir: &Path,
    generation: u64,
    fingerprint: u64,
) -> Result<Vec<CkptRel>, PimdbError> {
    let path = ckpt_path(dir, generation);
    let buf = fs::read(&path).map_err(|e| PimdbError::Io(format!("{}: {e}", path.display())))?;
    let body = verify_digest(&buf, "checkpoint")?;
    let mut d = De::new(body, "checkpoint");
    let header = CkptHeader::decode(&mut d)?;
    if header.fingerprint != fingerprint {
        return Err(PimdbError::Corrupt(format!(
            "checkpoint fingerprint {:#018x} does not match this schema/geometry ({fingerprint:#018x})",
            header.fingerprint
        )));
    }
    if header.generation != generation {
        return Err(PimdbError::Corrupt(format!(
            "checkpoint names generation {} but lives in slot {generation}",
            header.generation
        )));
    }
    let mut rels = Vec::with_capacity((header.n_rels as usize).min(64));
    for _ in 0..header.n_rels {
        let rel = super::wal::rel_from_tag(d.u8()?)?;
        let epoch = d.u64()?;
        let n_xbars = d.u32()? as usize;
        let cols = d.u32()? as usize;
        // a corrupt shape field must not drive allocation: the planes
        // the shape declares have to actually be present in the body
        if n_xbars.saturating_mul(cols).saturating_mul(WORDS * 8) > body.len() {
            return Err(PimdbError::Corrupt(format!(
                "checkpoint {rel:?}: {n_xbars} crossbars x {cols} planes exceed the file size"
            )));
        }
        let mut states = Vec::with_capacity(n_xbars);
        for _ in 0..n_xbars {
            let mut s = XbarState::new(cols);
            for plane in &mut s.planes {
                for w in plane.iter_mut() {
                    *w = d.u64()?;
                }
            }
            states.push(s);
        }
        let capacity = d.u64()? as usize;
        if capacity != states.len() * XBAR_ROWS {
            return Err(PimdbError::Corrupt(format!(
                "checkpoint {rel:?}: row-map capacity {capacity} does not cover {} crossbars",
                states.len()
            )));
        }
        let live = unpack_bools(&mut d, capacity)?;
        let mut wear = Vec::with_capacity(capacity);
        for _ in 0..capacity {
            wear.push(d.u64()?);
        }
        let mut ledger = Vec::with_capacity(XBAR_ROWS);
        for _ in 0..XBAR_ROWS {
            ledger.push(d.u64()?);
        }
        rels.push(CkptRel {
            rel,
            epoch,
            states,
            live,
            wear,
            ledger,
        });
    }
    d.finish()?;
    Ok(rels)
}

/// Write the one-time base image of the generated database.
pub(crate) fn write_base(dir: &Path, fingerprint: u64, db: &Database) -> std::io::Result<u64> {
    let mut b = Vec::new();
    b.extend_from_slice(&BASE_MAGIC);
    b.extend_from_slice(&fingerprint.to_le_bytes());
    b.extend_from_slice(&db.sf.to_bits().to_le_bytes());
    b.extend_from_slice(&db.seed.to_le_bytes());
    let rels: Vec<&Relation> = db.relations().collect();
    b.extend_from_slice(&(rels.len() as u32).to_le_bytes());
    for r in rels {
        let name = r.id.name();
        b.extend_from_slice(&(name.len() as u32).to_le_bytes());
        b.extend_from_slice(name.as_bytes());
        b.extend_from_slice(&(r.records as u64).to_le_bytes());
        let valid: Vec<bool> = (0..r.records).map(|i| r.live(i)).collect();
        for w in pack_bools(&valid) {
            b.extend_from_slice(&w.to_le_bytes());
        }
        let cols: Vec<(&'static str, &[u64])> = r.columns().collect();
        b.extend_from_slice(&(cols.len() as u32).to_le_bytes());
        for (cname, values) in cols {
            b.extend_from_slice(&(cname.len() as u32).to_le_bytes());
            b.extend_from_slice(cname.as_bytes());
            for v in values {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    let digest = fnv1a(&b);
    b.extend_from_slice(&digest.to_le_bytes());
    write_atomic(&base_path(dir), &b)?;
    Ok(b.len() as u64)
}

/// Read and fully verify the base image.
pub(crate) fn read_base(dir: &Path, fingerprint: u64) -> Result<Database, PimdbError> {
    let path = base_path(dir);
    let buf = fs::read(&path).map_err(|e| PimdbError::Io(format!("{}: {e}", path.display())))?;
    let body = verify_digest(&buf, "base image")?;
    let mut d = De::new(body, "base image");
    let mut magic = [0u8; 8];
    for m in &mut magic {
        *m = d.u8()?;
    }
    if magic != BASE_MAGIC {
        return Err(PimdbError::Corrupt("base image: bad magic".into()));
    }
    let fp = d.u64()?;
    if fp != fingerprint {
        return Err(PimdbError::Corrupt(format!(
            "base image fingerprint {fp:#018x} does not match this schema/geometry \
             ({fingerprint:#018x})"
        )));
    }
    let sf = f64::from_bits(d.u64()?);
    let seed = d.u64()?;
    let n_rels = d.count(13)?;
    let mut relations = Vec::with_capacity(n_rels);
    for _ in 0..n_rels {
        let name = d.str()?.to_owned();
        let id = rel_by_name(&name)?;
        let records = d.u64()? as usize;
        if records > body.len() {
            return Err(PimdbError::Corrupt(format!(
                "base image {name}: record count {records} exceeds file size"
            )));
        }
        let valid = unpack_bools(&mut d, records)?;
        let n_cols = d.count(4)?;
        let mut columns = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            let cname = d.str()?;
            let interned = intern_column(id, cname).ok_or_else(|| {
                PimdbError::Corrupt(format!("base image {name}: unknown column '{cname}'"))
            })?;
            let mut values = Vec::with_capacity(records);
            for _ in 0..records {
                values.push(d.u64()?);
            }
            columns.push((interned, values));
        }
        relations.push(Relation::from_parts(id, columns, valid));
    }
    d.finish()?;
    Ok(Database::from_parts(sf, seed, relations))
}

fn rel_by_name(name: &str) -> Result<RelId, PimdbError> {
    const ALL: [RelId; 8] = [
        RelId::Part,
        RelId::Supplier,
        RelId::Partsupp,
        RelId::Customer,
        RelId::Orders,
        RelId::Lineitem,
        RelId::Nation,
        RelId::Region,
    ];
    ALL.iter()
        .copied()
        .find(|r| r.name() == name)
        .ok_or_else(|| PimdbError::Corrupt(format!("base image: unknown relation '{name}'")))
}

/// Split a `[body | digest]` file and verify the trailing FNV-1a digest
/// covers the body exactly.
fn verify_digest<'a>(buf: &'a [u8], what: &str) -> Result<&'a [u8], PimdbError> {
    if buf.len() < 8 {
        return Err(PimdbError::Corrupt(format!("{what}: shorter than its digest")));
    }
    let (body, tail) = buf.split_at(buf.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    if fnv1a(body) != stored {
        return Err(PimdbError::Corrupt(format!(
            "{what}: whole-file digest mismatch (bit rot or a partial write)"
        )));
    }
    Ok(body)
}

/// Write `bytes` to `path` crash-atomically: temp file, sync, rename,
/// directory sync — a reader never observes a half-written file.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        // direct the rename itself to stable storage (best effort on
        // platforms where directories cannot be opened)
        if let Ok(d) = File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pimdb-snap-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn prop_header_round_trips() {
        check("ckpt-header-roundtrip", 200, |g| {
            let h = CkptHeader {
                fingerprint: g.u64(0, u64::MAX),
                generation: g.u64(0, u64::MAX),
                n_rels: g.u64(0, u32::MAX as u64) as u32,
            };
            let bytes = h.encode();
            let mut d = De::new(&bytes, "ckpt header");
            assert_eq!(CkptHeader::decode(&mut d).unwrap(), h);
            d.finish().unwrap();
            // every strict prefix is refused, never mis-decoded
            for cut in 0..bytes.len() {
                let mut d = De::new(&bytes[..cut], "ckpt header");
                assert!(CkptHeader::decode(&mut d).is_err(), "prefix {cut}");
            }
        });
    }

    #[test]
    fn checkpoint_round_trips_and_detects_bit_rot() {
        let dir = tmpdir("ckpt");
        let fp = 0xFEED;
        let mut s0 = XbarState::new(8);
        s0.planes[3][2] = 0xDEAD_BEEF;
        let mut s1 = XbarState::new(8);
        s1.planes[0][15] = 7;
        let snap = CkptRelSnapshot {
            rel: RelId::Lineitem,
            epoch: 5,
            states: &[s0.clone(), s1.clone()],
            live: (0..2 * XBAR_ROWS).map(|i| i % 3 != 0).collect(),
            wear: (0..2 * XBAR_ROWS as u64).map(|i| i * i % 97).collect(),
            ledger: (0..XBAR_ROWS as u64).collect(),
        };
        write_checkpoint(&dir, fp, 3, &[snap]).unwrap();

        let rels = read_checkpoint(&dir, 3, fp).unwrap();
        assert_eq!(rels.len(), 1);
        let r = &rels[0];
        assert_eq!((r.rel, r.epoch), (RelId::Lineitem, 5));
        assert_eq!(r.states.len(), 2);
        assert_eq!(r.states[0].planes, s0.planes);
        assert_eq!(r.states[1].planes, s1.planes);
        assert_eq!(r.live.len(), 2 * XBAR_ROWS);
        assert!(!r.live[0] && r.live[1]);
        assert_eq!(r.wear[10], 100 % 97);
        assert_eq!(r.ledger[1023], 1023);

        // wrong fingerprint and wrong generation slot are refused
        assert!(matches!(
            read_checkpoint(&dir, 3, fp ^ 1),
            Err(PimdbError::Corrupt(_))
        ));
        let renamed = ckpt_path(&dir, 9);
        fs::copy(ckpt_path(&dir, 3), &renamed).unwrap();
        assert!(matches!(
            read_checkpoint(&dir, 9, fp),
            Err(PimdbError::Corrupt(_))
        ));

        // a single flipped bit anywhere fails the whole-file digest
        let path = ckpt_path(&dir, 3);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_checkpoint(&dir, 3, fp),
            Err(PimdbError::Corrupt(_))
        ));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn base_image_round_trips_a_generated_database() {
        let dir = tmpdir("base");
        let fp = 0xBA5E;
        let mut db = Database::generate(0.001, 7);
        db.rel_mut(RelId::Part).set_valid(1, false);
        db.rel_mut(RelId::Part).zero_row(1);
        write_base(&dir, fp, &db).unwrap();
        let back = read_base(&dir, fp).unwrap();
        assert_eq!(back.sf, db.sf);
        assert_eq!(back.seed, db.seed);
        for r in db.relations() {
            let b = back.rel(r.id);
            assert_eq!(b.records, r.records);
            assert_eq!(b.live_count(), r.live_count());
            for (n, c) in r.columns() {
                assert_eq!(b.col(n), c, "{:?}.{n}", r.id);
            }
        }
        assert!(!back.rel(RelId::Part).live(1));
        assert!(matches!(
            read_base(&dir, fp ^ 1),
            Err(PimdbError::Corrupt(_))
        ));
        let _ = fs::remove_dir_all(&dir);
    }
}
