//! Durability subsystem: write-ahead log, epoch checkpoints, recovery.
//!
//! PIMDB's in-memory state is rebuilt from three on-disk artifacts in a
//! data directory (see `ARCHITECTURE.md`, *Durability and recovery*):
//!
//! * **`base.img`** — the immutable dbgen load image, written once when
//!   the directory is initialized. DML mutates the PIM copy of a
//!   relation, never the base image, so it is a pure function of
//!   `(sim_sf, seed)` and doubles as a consistency check on reopen.
//! * **`wal-NNNNNNNN.log`** — the write-ahead log ([`wal`]). The
//!   group-commit leader appends exactly one checksum-framed record per
//!   committed batch *before* publishing the batch's epoch, carrying the
//!   relation tag, the new epoch, the reader-wear ledger fold profile,
//!   and the batch's canonical DML AST bytes (the same byte format the
//!   plan cache hashes).
//! * **`ckpt-NNNNNNNN.pim`** — versioned checkpoints: each relation's
//!   crossbar bit-planes, row liveness/wear state, and epoch, under a
//!   whole-file digest. [`crate::api::Pimdb::checkpoint`] writes
//!   generation *g+1* atomically, rotates the WAL to a fresh segment,
//!   and prunes generations older than *g* (the previous generation is
//!   kept as the corruption fallback).
//!
//! Recovery (`recover`, driven by [`crate::api::Pimdb::open_durable`])
//! loads the newest digest-valid checkpoint, truncates a torn WAL tail
//! at the last record boundary, and replays the epoch suffix of logged
//! batches through the normal DML execution path — deterministic because
//! group commit is serial per relation. Complete-but-mangled records are
//! refused with [`crate::error::PimdbError::Corrupt`] rather than
//! guessed at; only *incomplete* tail frames (the signature of a crash
//! mid-append) are silently truncated.
//!
//! The WAL record codec and the torn-tail truncation decision are
//! mirrored line-by-line in `python/walmirror.py`; both sides pin the
//! same golden digest over a crash-point sweep ([`wal::golden_wal_digest`]).

pub(crate) mod recover;
pub(crate) mod snapshot;
pub mod wal;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::config::DurabilityConfig;
use crate::error::PimdbError;
use wal::{WalRecord, WalWriter};

/// Counters describing everything the durability layer has done for one
/// [`crate::api::Pimdb`] handle, returned by
/// [`crate::api::Pimdb::durability_stats`]. Monotonic over the handle's
/// lifetime; replay counters are populated by `open_durable` itself.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DurabilityStats {
    /// WAL records appended (one per committed DML batch).
    pub wal_records_appended: u64,
    /// Bytes appended to the WAL, frames included.
    pub wal_bytes_appended: u64,
    /// WAL records replayed during the `open_durable` that produced this
    /// handle.
    pub wal_records_replayed: u64,
    /// Torn WAL tails truncated at a record boundary during recovery.
    pub torn_tails_truncated: u64,
    /// Checkpoint generations skipped during recovery because their
    /// digest failed (the fallback path).
    pub checkpoints_skipped: u64,
    /// Checkpoints written by this handle via
    /// [`crate::api::Pimdb::checkpoint`].
    pub checkpoints_written: u64,
    /// Highest relation epoch captured by the most recent checkpoint
    /// (recovered or written); 0 before any DML is checkpointed.
    pub last_checkpoint_epoch: u64,
}

fn lock_plain<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Runtime durability state attached to a [`crate::api::Pimdb`] opened
/// with `open_durable`: the config, the current WAL writer, and the
/// stats counters. The writer mutex is a leaf lock — the group-commit
/// leader takes it while already holding its relation gate, and
/// `checkpoint` takes it while holding every gate, so the two can never
/// deadlock against each other.
pub(crate) struct Durability {
    /// The opening configuration (data dir, fsync policy, dbgen seed).
    pub cfg: DurabilityConfig,
    /// Plan-cache fingerprint stamped into every on-disk artifact.
    pub fingerprint: u64,
    writer: Mutex<WalWriter>,
    records_appended: AtomicU64,
    bytes_appended: AtomicU64,
    records_replayed: AtomicU64,
    torn_tails: AtomicU64,
    checkpoints_skipped: AtomicU64,
    checkpoints_written: AtomicU64,
    last_checkpoint_epoch: AtomicU64,
}

impl Durability {
    /// Wrap the writer produced by recovery, seeding the recovery-side
    /// counters.
    pub fn new(
        cfg: DurabilityConfig,
        fingerprint: u64,
        writer: WalWriter,
        torn_tails: u64,
        checkpoints_skipped: u64,
        last_checkpoint_epoch: u64,
    ) -> Durability {
        Durability {
            cfg,
            fingerprint,
            writer: Mutex::new(writer),
            records_appended: AtomicU64::new(0),
            bytes_appended: AtomicU64::new(0),
            records_replayed: AtomicU64::new(0),
            torn_tails: AtomicU64::new(torn_tails),
            checkpoints_skipped: AtomicU64::new(checkpoints_skipped),
            checkpoints_written: AtomicU64::new(0),
            last_checkpoint_epoch: AtomicU64::new(last_checkpoint_epoch),
        }
    }

    /// Append one committed-batch record, honouring the fsync policy.
    /// Called by the group-commit leader after the batch executed but
    /// before its epoch publishes; an error aborts the batch.
    pub fn append(&self, record: &WalRecord) -> Result<(), PimdbError> {
        let mut writer = lock_plain(&self.writer);
        let bytes = writer
            .append(record, self.cfg.fsync)
            .map_err(|e| PimdbError::Io(format!("wal append: {e}")))?;
        self.records_appended.fetch_add(1, Ordering::Relaxed);
        self.bytes_appended.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Current WAL generation (the checkpoint being written is this +1).
    pub fn generation(&self) -> u64 {
        lock_plain(&self.writer).generation()
    }

    /// Swap in the fresh segment created by a checkpoint and record the
    /// checkpoint's high epoch.
    pub fn rotate(&self, writer: WalWriter, checkpoint_epoch: u64) {
        *lock_plain(&self.writer) = writer;
        self.checkpoints_written.fetch_add(1, Ordering::Relaxed);
        self.last_checkpoint_epoch
            .store(checkpoint_epoch, Ordering::Relaxed);
    }

    /// Count records replayed by recovery.
    pub fn note_replayed(&self, n: u64) {
        self.records_replayed.fetch_add(n, Ordering::Relaxed);
    }

    /// Consistent point-in-time snapshot of the counters.
    pub fn stats(&self) -> DurabilityStats {
        DurabilityStats {
            wal_records_appended: self.records_appended.load(Ordering::Relaxed),
            wal_bytes_appended: self.bytes_appended.load(Ordering::Relaxed),
            wal_records_replayed: self.records_replayed.load(Ordering::Relaxed),
            torn_tails_truncated: self.torn_tails.load(Ordering::Relaxed),
            checkpoints_skipped: self.checkpoints_skipped.load(Ordering::Relaxed),
            checkpoints_written: self.checkpoints_written.load(Ordering::Relaxed),
            last_checkpoint_epoch: self.last_checkpoint_epoch.load(Ordering::Relaxed),
        }
    }
}
