//! Write-ahead log: checksum-framed, length-prefixed batch records.
//!
//! The group-commit leader ([`crate::api::Pimdb`]) appends exactly one
//! record per committed DML batch, *before* the batch's epoch publishes.
//! A record carries everything replay needs to reproduce the commit
//! bit-identically through the normal `exec_dml_on_states` path:
//!
//! ```text
//! file   := header frame*
//! header := magic "PIMWAL01" (8)  fingerprint u64le (8)
//! frame  := len u32le  checksum u64le (FNV-1a of payload)  payload[len]
//! payload:= rel_tag u8            -- index into schema::PIM_RELATIONS
//!           epoch u64le           -- epoch this batch commits
//!           fold_n u32le  (idx u32le, wear u64le)*fold_n
//!                                 -- reader wear folded at batch begin
//!           stmt_n u32le  (len u32le, dml_bytes)*stmt_n
//!                                 -- canonical api::cache::dml_bytes
//! ```
//!
//! The torn-tail/corruption split is the recovery contract: a frame cut
//! short by a crash (fewer than 12 bytes left, or `len` past EOF) is a
//! **torn tail** — silently truncated at the last record boundary — while
//! a *complete* frame whose checksum or payload does not verify is
//! **corruption** and refused with [`PimdbError::Corrupt`]. Pure
//! truncation (a crash mid-append) can only produce the former, so crash
//! recovery always lands on a batch boundary; bit rot always produces the
//! latter. `python/walmirror.py` mirrors this decision line by line and
//! [`golden_wal_digest`] pins both sides to one constant.

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::api::cache::{fnv1a, FORMAT_VERSION};
use crate::config::FsyncPolicy;
use crate::db::schema::{self, RelId, PIM_RELATIONS};
use crate::error::PimdbError;
use crate::query::ast::{CmpOp, Dml, Pred};

/// First 8 bytes of every WAL segment.
pub(crate) const WAL_MAGIC: [u8; 8] = *b"PIMWAL01";
/// Header: magic + schema/geometry fingerprint.
pub(crate) const WAL_HEADER: usize = 16;
/// Frame prefix: u32 payload length + u64 payload checksum.
pub(crate) const FRAME_PREFIX: usize = 12;
/// Predicate trees deeper than this are refused at decode (a corrupt
/// count field must not become unbounded recursion).
const MAX_PRED_DEPTH: usize = 64;

/// One committed DML batch, as logged.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct WalRecord {
    /// Index of the target relation in [`PIM_RELATIONS`].
    pub rel_tag: u8,
    /// The epoch this batch commits (predecessor state is `epoch - 1`).
    pub epoch: u64,
    /// Sparse reader-wear profile folded into the committed map at batch
    /// begin (`(crossbar row, cell writes)`; empty when no reader wear
    /// was pending).
    pub fold: Vec<(u32, u64)>,
    /// Canonical [`crate::api::cache`] `dml_bytes` per statement, in
    /// batch order.
    pub stmts: Vec<Vec<u8>>,
}

/// Resolve a stored relation tag back to its [`RelId`].
pub(crate) fn rel_from_tag(tag: u8) -> Result<RelId, PimdbError> {
    PIM_RELATIONS.get(tag as usize).copied().ok_or_else(|| {
        PimdbError::Corrupt(format!("relation tag {tag} out of range"))
    })
}

impl WalRecord {
    /// The target relation; `Corrupt` when the tag is out of range.
    pub fn rel(&self) -> Result<RelId, PimdbError> {
        rel_from_tag(self.rel_tag)
    }

    /// Tag of `rel` in [`PIM_RELATIONS`] (the inverse of [`WalRecord::rel`]).
    pub fn tag_of(rel: RelId) -> u8 {
        PIM_RELATIONS
            .iter()
            .position(|&r| r == rel)
            .expect("DML targets a PIM relation") as u8
    }

    /// Serialize the payload (no frame prefix).
    pub fn encode_payload(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.push(self.rel_tag);
        b.extend_from_slice(&self.epoch.to_le_bytes());
        b.extend_from_slice(&(self.fold.len() as u32).to_le_bytes());
        for &(idx, wear) in &self.fold {
            b.extend_from_slice(&idx.to_le_bytes());
            b.extend_from_slice(&wear.to_le_bytes());
        }
        b.extend_from_slice(&(self.stmts.len() as u32).to_le_bytes());
        for s in &self.stmts {
            b.extend_from_slice(&(s.len() as u32).to_le_bytes());
            b.extend_from_slice(s);
        }
        b
    }

    /// Serialize the full frame (`len`, checksum, payload).
    pub fn encode_frame(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut b = Vec::with_capacity(FRAME_PREFIX + payload.len());
        b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        b.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        b.extend_from_slice(&payload);
        b
    }

    /// Decode a checksum-verified payload. Any mismatch between the
    /// declared counts and the actual bytes is corruption.
    pub fn decode_payload(payload: &[u8]) -> Result<WalRecord, PimdbError> {
        let mut d = De::new(payload, "wal record");
        let rel_tag = d.u8()?;
        let epoch = d.u64()?;
        let fold_n = d.count(12)?;
        let mut fold = Vec::with_capacity(fold_n);
        for _ in 0..fold_n {
            let idx = d.u32()?;
            let wear = d.u64()?;
            fold.push((idx, wear));
        }
        let stmt_n = d.count(4)?;
        let mut stmts = Vec::with_capacity(stmt_n);
        for _ in 0..stmt_n {
            stmts.push(d.bytes()?.to_vec());
        }
        d.finish()?;
        Ok(WalRecord {
            rel_tag,
            epoch,
            fold,
            stmts,
        })
    }
}

/// Bounded little-endian reader over untrusted bytes; every overrun is a
/// typed [`PimdbError::Corrupt`], never a panic.
pub(crate) struct De<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> De<'a> {
    pub fn new(buf: &'a [u8], what: &'static str) -> De<'a> {
        De { buf, pos: 0, what }
    }

    fn corrupt(&self, why: &str) -> PimdbError {
        PimdbError::Corrupt(format!("{}: {why} at byte {}", self.what, self.pos))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PimdbError> {
        if self.buf.len() - self.pos < n {
            return Err(self.corrupt("truncated field"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, PimdbError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, PimdbError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, PimdbError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u32` element count whose elements occupy at least
    /// `min_elem_bytes` each — rejected up front when the remaining bytes
    /// cannot possibly hold it (so corrupt counts never drive allocation).
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize, PimdbError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes) > self.buf.len() - self.pos {
            return Err(self.corrupt("element count exceeds remaining bytes"));
        }
        Ok(n)
    }

    /// A `u32`-length-prefixed byte string.
    pub fn bytes(&mut self) -> Result<&'a [u8], PimdbError> {
        let n = self.count(1)?;
        self.take(n)
    }

    /// A `u32`-length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<&'a str, PimdbError> {
        let bs = self.bytes()?;
        std::str::from_utf8(bs).map_err(|_| self.corrupt("non-UTF-8 string"))
    }

    /// Assert full consumption — trailing garbage is corruption.
    pub fn finish(&self) -> Result<(), PimdbError> {
        if self.pos != self.buf.len() {
            return Err(self.corrupt("trailing bytes after decode"));
        }
        Ok(())
    }
}

/// Decode a canonical `dml_bytes` stream back to the AST — the exact
/// inverse of [`crate::api::cache`]'s serializer, including the trailing
/// schema/geometry fingerprint check. Attribute and relation names are
/// interned against the static schema so the decoded AST is
/// indistinguishable from a parsed one.
pub(crate) fn decode_dml(bytes: &[u8], fingerprint: u64) -> Result<Dml, PimdbError> {
    let mut d = De::new(bytes, "wal dml statement");
    let version = d.u8()?;
    if version != FORMAT_VERSION {
        return Err(PimdbError::Corrupt(format!(
            "wal dml statement: format version {version} (expected {FORMAT_VERSION})"
        )));
    }
    let kind = d.u8()?;
    let rel = decode_rel(&mut d)?;
    let dml = match kind {
        2 => {
            let n = d.count(12)?;
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                values.push(decode_set(&mut d, rel)?);
            }
            Dml::Insert { rel, values }
        }
        3 => {
            let filter = decode_pred(&mut d, rel, 0)?;
            let n = d.count(12)?;
            let mut sets = Vec::with_capacity(n);
            for _ in 0..n {
                sets.push(decode_set(&mut d, rel)?);
            }
            Dml::Update { rel, filter, sets }
        }
        4 => {
            let filter = decode_pred(&mut d, rel, 0)?;
            Dml::Delete { rel, filter }
        }
        other => {
            return Err(PimdbError::Corrupt(format!(
                "wal dml statement: kind byte {other} (expected 2..=4)"
            )))
        }
    };
    let fp = d.u64()?;
    if fp != fingerprint {
        return Err(PimdbError::Corrupt(format!(
            "wal dml statement: fingerprint {fp:#018x} does not match this \
             schema/geometry ({fingerprint:#018x})"
        )));
    }
    d.finish()?;
    Ok(dml)
}

fn decode_rel(d: &mut De<'_>) -> Result<RelId, PimdbError> {
    let name = d.str()?;
    PIM_RELATIONS
        .iter()
        .copied()
        .find(|r| r.name() == name)
        .ok_or_else(|| PimdbError::Corrupt(format!("wal dml statement: unknown relation '{name}'")))
}

/// Intern a decoded attribute name to the schema's `&'static str`.
fn decode_attr(d: &mut De<'_>, rel: RelId) -> Result<&'static str, PimdbError> {
    let name = d.str()?;
    schema::attr(rel, name).map(|a| a.name).ok_or_else(|| {
        PimdbError::Corrupt(format!(
            "wal dml statement: {rel:?} has no attribute '{name}'"
        ))
    })
}

fn decode_set(d: &mut De<'_>, rel: RelId) -> Result<(&'static str, u64), PimdbError> {
    let attr = decode_attr(d, rel)?;
    let v = d.u64()?;
    Ok((attr, v))
}

fn decode_cmp(d: &mut De<'_>) -> Result<CmpOp, PimdbError> {
    Ok(match d.u8()? {
        0 => CmpOp::Eq,
        1 => CmpOp::Ne,
        2 => CmpOp::Lt,
        3 => CmpOp::Le,
        4 => CmpOp::Gt,
        5 => CmpOp::Ge,
        t => return Err(PimdbError::Corrupt(format!("wal dml statement: cmp tag {t}"))),
    })
}

fn decode_pred(d: &mut De<'_>, rel: RelId, depth: usize) -> Result<Pred, PimdbError> {
    if depth > MAX_PRED_DEPTH {
        return Err(PimdbError::Corrupt(
            "wal dml statement: predicate nesting exceeds limit".into(),
        ));
    }
    Ok(match d.u8()? {
        0 => Pred::CmpImm {
            attr: decode_attr(d, rel)?,
            op: decode_cmp(d)?,
            value: d.u64()?,
        },
        1 => {
            let attr = decode_attr(d, rel)?;
            let n = d.count(8)?;
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                values.push(d.u64()?);
            }
            Pred::InSet { attr, values }
        }
        2 => Pred::Between {
            attr: decode_attr(d, rel)?,
            lo: d.u64()?,
            hi: d.u64()?,
        },
        3 => {
            let a = decode_attr(d, rel)?;
            let op = decode_cmp(d)?;
            let b = decode_attr(d, rel)?;
            Pred::CmpCols { a, op, b }
        }
        4 => {
            let n = d.count(1)?;
            let mut ps = Vec::with_capacity(n);
            for _ in 0..n {
                ps.push(decode_pred(d, rel, depth + 1)?);
            }
            Pred::And(ps)
        }
        5 => {
            let n = d.count(1)?;
            let mut ps = Vec::with_capacity(n);
            for _ in 0..n {
                ps.push(decode_pred(d, rel, depth + 1)?);
            }
            Pred::Or(ps)
        }
        6 => Pred::Not(Box::new(decode_pred(d, rel, depth + 1)?)),
        7 => Pred::True,
        t => {
            return Err(PimdbError::Corrupt(format!(
                "wal dml statement: predicate tag {t}"
            )))
        }
    })
}

/// The scan of one WAL segment: the cleanly framed records, how many
/// bytes of the file they (plus the header) occupy, and whether the tail
/// past `valid_len` was torn.
#[derive(Debug)]
pub(crate) struct WalScan {
    /// Every record whose frame was complete and checksum-valid.
    pub records: Vec<WalRecord>,
    /// File offset of the last record boundary (header included) — the
    /// truncation point when `torn`.
    pub valid_len: usize,
    /// Whether bytes past `valid_len` form an incomplete frame (a crash
    /// mid-append). A checksum mismatch in a *complete* frame is not
    /// torn — it is an error.
    pub torn: bool,
}

/// Scan a full WAL segment image (header included). Incomplete tail
/// frames report torn; complete frames failing checksum or payload
/// decode are [`PimdbError::Corrupt`]; a wrong magic or fingerprint
/// refuses the whole file. A file shorter than its header is treated as
/// torn at offset 0 (the header is rewritten on reopen).
///
/// This function *is* the recovery decision procedure — `python/
/// walmirror.py::scan_records` mirrors it line by line.
pub(crate) fn scan_records(buf: &[u8], fingerprint: u64) -> Result<WalScan, PimdbError> {
    if buf.len() < WAL_HEADER {
        return Ok(WalScan {
            records: Vec::new(),
            valid_len: 0,
            torn: true,
        });
    }
    if buf[..8] != WAL_MAGIC {
        return Err(PimdbError::Corrupt("wal header: bad magic".into()));
    }
    let fp = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    if fp != fingerprint {
        return Err(PimdbError::Corrupt(format!(
            "wal header: fingerprint {fp:#018x} does not match this schema/geometry \
             ({fingerprint:#018x})"
        )));
    }
    let mut records = Vec::new();
    let mut off = WAL_HEADER;
    let mut torn = false;
    while off < buf.len() {
        let rem = buf.len() - off;
        if rem < FRAME_PREFIX {
            torn = true;
            break;
        }
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
        if rem - FRAME_PREFIX < len {
            torn = true;
            break;
        }
        let crc = u64::from_le_bytes(buf[off + 4..off + 12].try_into().unwrap());
        let payload = &buf[off + FRAME_PREFIX..off + FRAME_PREFIX + len];
        if fnv1a(payload) != crc {
            return Err(PimdbError::Corrupt(format!(
                "wal record {}: checksum mismatch at byte {off}",
                records.len()
            )));
        }
        records.push(WalRecord::decode_payload(payload)?);
        off += FRAME_PREFIX + len;
    }
    Ok(WalScan {
        records,
        valid_len: if torn { off } else { buf.len() },
        torn,
    })
}

/// Path of WAL segment `generation` under `dir`.
pub(crate) fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation:08}.log"))
}

/// An open WAL segment positioned for appends.
pub(crate) struct WalWriter {
    file: File,
    generation: u64,
}

impl WalWriter {
    /// Create (truncate) segment `generation`, write its header and sync
    /// it — a segment must never exist without a valid header.
    pub fn create(dir: &Path, generation: u64, fingerprint: u64) -> std::io::Result<WalWriter> {
        let mut file = File::create(wal_path(dir, generation))?;
        file.write_all(&WAL_MAGIC)?;
        file.write_all(&fingerprint.to_le_bytes())?;
        file.sync_all()?;
        Ok(WalWriter { file, generation })
    }

    /// Reopen segment `generation` for appends after a scan: truncate the
    /// torn tail at `valid_len` (rewriting the header when even that was
    /// cut short) and seek to the end.
    pub fn open_truncated(
        dir: &Path,
        generation: u64,
        valid_len: usize,
        fingerprint: u64,
    ) -> std::io::Result<WalWriter> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(wal_path(dir, generation))?;
        if valid_len < WAL_HEADER {
            file.set_len(0)?;
            file.write_all(&WAL_MAGIC)?;
            file.write_all(&fingerprint.to_le_bytes())?;
        } else {
            file.set_len(valid_len as u64)?;
        }
        file.sync_all()?;
        file.seek(SeekFrom::End(0))?;
        Ok(WalWriter { file, generation })
    }

    /// The segment's generation number.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Append one framed record under `policy`; returns the frame size.
    pub fn append(&mut self, record: &WalRecord, policy: FsyncPolicy) -> std::io::Result<u64> {
        let frame = record.encode_frame();
        self.file.write_all(&frame)?;
        match policy {
            FsyncPolicy::Always => self.file.sync_all()?,
            FsyncPolicy::GroupCommit => self.file.sync_data()?,
            FsyncPolicy::Off => {}
        }
        Ok(frame.len() as u64)
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_fold(mut state: u64, value: u64) -> u64 {
    for byte in value.to_le_bytes() {
        state = (state ^ byte as u64).wrapping_mul(FNV_PRIME);
    }
    state
}

/// Cross-language golden pin: `python/walmirror.py` builds the identical
/// scripted WAL image, scans it truncated at the same set of offsets plus
/// a bit-flipped variant, and folds the identical observations into the
/// same constant (`GOLDEN_WAL_DIGEST`). The digest covers the frame
/// layout, the payload codec, *and* the torn-vs-corrupt decision — a
/// one-sided change to any of them breaks exactly one of the two suites.
pub fn golden_wal_digest() -> u64 {
    let fingerprint: u64 = 0x51AE_77C0_DE01_F00D;
    let mut x: u64 = 9;
    let mut next = move || {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        x
    };
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(&WAL_MAGIC);
    buf.extend_from_slice(&fingerprint.to_le_bytes());
    let mut boundaries = vec![0usize, WAL_HEADER];
    for i in 0..5u64 {
        let rel_tag = ((next() >> 4) % 6) as u8;
        let fold_n = next() % 4;
        let fold: Vec<(u32, u64)> = (0..fold_n)
            .map(|_| (((next() >> 8) % 1024) as u32, next() % 100 + 1))
            .collect();
        let stmt_n = next() % 3 + 1;
        let stmts: Vec<Vec<u8>> = (0..stmt_n)
            .map(|_| {
                let len = next() % 40;
                (0..len).map(|_| ((next() >> 16) & 0xFF) as u8).collect()
            })
            .collect();
        let rec = WalRecord {
            rel_tag,
            epoch: i + 1,
            fold,
            stmts,
        };
        buf.extend_from_slice(&rec.encode_frame());
        boundaries.push(buf.len());
    }
    let mut cuts: Vec<usize> = Vec::new();
    for &b in &boundaries {
        cuts.push(b);
        if b > 0 {
            cuts.push(b - 1);
        }
        if b + 5 <= buf.len() {
            cuts.push(b + 5);
        }
    }
    let mut state = FNV_OFFSET;
    let observe = |state: &mut u64, bytes: &[u8]| match scan_records(bytes, fingerprint) {
        Err(_) => *state = fnv1a_fold(*state, 0xDEAD),
        Ok(scan) => {
            *state = fnv1a_fold(*state, 1);
            *state = fnv1a_fold(*state, scan.records.len() as u64);
            *state = fnv1a_fold(*state, scan.valid_len as u64);
            *state = fnv1a_fold(*state, scan.torn as u64);
            for rec in &scan.records {
                *state = fnv1a_fold(*state, rec.rel_tag as u64);
                *state = fnv1a_fold(*state, rec.epoch);
                *state = fnv1a_fold(*state, rec.fold.len() as u64);
                for &(idx, wear) in &rec.fold {
                    *state = fnv1a_fold(*state, idx as u64);
                    *state = fnv1a_fold(*state, wear);
                }
                *state = fnv1a_fold(*state, rec.stmts.len() as u64);
                for s in &rec.stmts {
                    *state = fnv1a_fold(*state, fnv1a(s));
                }
            }
        }
    };
    for &t in &cuts {
        observe(&mut state, &buf[..t]);
    }
    // a bit flip inside the first record's complete payload must be
    // refused as corruption, not truncated as a torn tail
    let mut flipped = buf.clone();
    flipped[WAL_HEADER + FRAME_PREFIX + 2] ^= 0x04;
    observe(&mut state, &flipped);
    // ...and a flip in a frame length field must never surface a record
    // that was not cleanly framed
    let mut flipped_len = buf.clone();
    flipped_len[WAL_HEADER] ^= 0x80;
    observe(&mut state, &flipped_len);
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::cache::dml_bytes;
    use crate::util::proptest::check;

    #[test]
    fn golden_wal_digest_matches_the_python_mirror_pin() {
        // regenerate with `python3 python/walmirror.py`
        assert_eq!(golden_wal_digest(), 0xD482_6F2D_77DE_BD67);
    }

    fn sample_record() -> WalRecord {
        WalRecord {
            rel_tag: 4,
            epoch: 7,
            fold: vec![(3, 12), (1000, 1)],
            stmts: vec![vec![1, 2, 3], vec![], vec![0xFF; 40]],
        }
    }

    #[test]
    fn payload_round_trips() {
        let rec = sample_record();
        let back = WalRecord::decode_payload(&rec.encode_payload()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.rel().unwrap(), PIM_RELATIONS[4]);
    }

    #[test]
    fn scan_accepts_clean_files_and_truncates_torn_tails() {
        let fp = 0xABCD;
        let mut buf = Vec::new();
        buf.extend_from_slice(&WAL_MAGIC);
        buf.extend_from_slice(&fp.to_le_bytes());
        let r1 = sample_record();
        let mut r2 = sample_record();
        r2.epoch = 8;
        buf.extend_from_slice(&r1.encode_frame());
        let boundary = buf.len();
        buf.extend_from_slice(&r2.encode_frame());

        let scan = scan_records(&buf, fp).unwrap();
        assert_eq!(scan.records, vec![r1.clone(), r2.clone()]);
        assert!(!scan.torn);
        assert_eq!(scan.valid_len, buf.len());

        // every truncation inside the tail record lands on the boundary
        for cut in boundary..buf.len() {
            let scan = scan_records(&buf[..cut], fp).unwrap();
            assert_eq!(scan.records, vec![r1.clone()], "cut at {cut}");
            assert!(scan.torn);
            assert_eq!(scan.valid_len, boundary);
        }
    }

    #[test]
    fn scan_refuses_flips_wrong_magic_and_wrong_fingerprint() {
        let fp = 0xABCD;
        let mut buf = Vec::new();
        buf.extend_from_slice(&WAL_MAGIC);
        buf.extend_from_slice(&fp.to_le_bytes());
        buf.extend_from_slice(&sample_record().encode_frame());

        // payload flip: complete frame, checksum mismatch -> Corrupt
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() ^= 1;
        assert!(matches!(
            scan_records(&bad, fp),
            Err(PimdbError::Corrupt(_))
        ));
        // checksum-field flip is equally corrupt
        let mut bad = buf.clone();
        bad[WAL_HEADER + 5] ^= 1;
        assert!(matches!(
            scan_records(&bad, fp),
            Err(PimdbError::Corrupt(_))
        ));
        let mut bad_magic = buf.clone();
        bad_magic[0] ^= 1;
        assert!(matches!(
            scan_records(&bad_magic, fp),
            Err(PimdbError::Corrupt(_))
        ));
        assert!(matches!(
            scan_records(&buf, fp ^ 2),
            Err(PimdbError::Corrupt(_))
        ));
        // shorter than the header: torn at 0, not corrupt
        let scan = scan_records(&buf[..7], fp).unwrap();
        assert!(scan.torn && scan.records.is_empty() && scan.valid_len == 0);
    }

    #[test]
    fn dml_codec_round_trips_through_canonical_bytes() {
        use crate::db::schema::RelId;
        let fp = 0x1234_5678;
        let stmts = [
            Dml::Insert {
                rel: RelId::Lineitem,
                values: vec![("l_quantity", 5), ("l_tax", 2)],
            },
            Dml::Update {
                rel: RelId::Orders,
                filter: Pred::And(vec![
                    Pred::CmpImm {
                        attr: "o_orderdate",
                        op: CmpOp::Ge,
                        value: 100,
                    },
                    Pred::Or(vec![
                        Pred::Between {
                            attr: "o_totalprice",
                            lo: 10,
                            hi: 20,
                        },
                        Pred::Not(Box::new(Pred::InSet {
                            attr: "o_orderstatus",
                            values: vec![1, 2, 3],
                        })),
                    ]),
                ]),
                sets: vec![("o_shippriority", 1)],
            },
            Dml::Delete {
                rel: RelId::Lineitem,
                filter: Pred::CmpCols {
                    a: "l_commitdate",
                    op: CmpOp::Lt,
                    b: "l_receiptdate",
                },
            },
            Dml::Delete {
                rel: RelId::Part,
                filter: Pred::True,
            },
        ];
        for dml in &stmts {
            let bytes = dml_bytes(dml, fp);
            let back = decode_dml(&bytes, fp).unwrap();
            assert_eq!(&back, dml);
            // re-encoding the decoded AST is byte-identical — the codec
            // is an exact inverse, so replayed statements hit the same
            // plan-cache entries the live path compiled
            assert_eq!(dml_bytes(&back, fp), bytes);
        }
    }

    #[test]
    fn dml_decode_refuses_mangled_streams_with_typed_errors() {
        use crate::db::schema::RelId;
        let fp = 9;
        let dml = Dml::Delete {
            rel: RelId::Lineitem,
            filter: Pred::CmpImm {
                attr: "l_quantity",
                op: CmpOp::Lt,
                value: 24,
            },
        };
        let bytes = dml_bytes(&dml, fp);
        // wrong fingerprint
        assert!(matches!(
            decode_dml(&bytes, fp ^ 1),
            Err(PimdbError::Corrupt(_))
        ));
        // every strict prefix is refused (truncated field or fingerprint)
        for cut in 0..bytes.len() {
            assert!(
                matches!(decode_dml(&bytes[..cut], fp), Err(PimdbError::Corrupt(_))),
                "prefix {cut} not refused"
            );
        }
        // trailing garbage is refused
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(decode_dml(&long, fp), Err(PimdbError::Corrupt(_))));
        // unknown relation / attribute / tags are refused
        let mut bad_rel = bytes.clone();
        bad_rel[6] = b'X'; // inside the relation name
        assert!(matches!(
            decode_dml(&bad_rel, fp),
            Err(PimdbError::Corrupt(_))
        ));
    }

    #[test]
    fn prop_record_codec_round_trips_arbitrary_payloads() {
        check("wal-record-roundtrip", 200, |g| {
            let rec = WalRecord {
                rel_tag: g.u64(0, 5) as u8,
                epoch: g.u64(0, u64::MAX),
                fold: (0..g.usize(0, 8))
                    .map(|_| (g.u64(0, 1023) as u32, g.u64(0, 1 << 40)))
                    .collect(),
                stmts: (0..g.usize(0, 6))
                    .map(|_| {
                        let n = g.usize(0, 50);
                        (0..n).map(|_| g.u64(0, 255) as u8).collect()
                    })
                    .collect(),
            };
            let payload = rec.encode_payload();
            assert_eq!(WalRecord::decode_payload(&payload).unwrap(), rec);
            // framed and concatenated, the scan returns it intact
            let fp = g.u64(0, u64::MAX);
            let mut buf = Vec::new();
            buf.extend_from_slice(&WAL_MAGIC);
            buf.extend_from_slice(&fp.to_le_bytes());
            buf.extend_from_slice(&rec.encode_frame());
            let scan = scan_records(&buf, fp).unwrap();
            assert!(!scan.torn);
            assert_eq!(scan.records, vec![rec]);
        });
    }

    #[test]
    fn prop_truncation_never_yields_a_partial_batch() {
        // the crash-safety property: cutting a WAL image at *any* offset
        // either reproduces a record-boundary prefix or is refused —
        // never a record that was not fully appended
        check("wal-truncation-prefix", 60, |g| {
            let fp = g.u64(0, u64::MAX);
            let mut buf = Vec::new();
            buf.extend_from_slice(&WAL_MAGIC);
            buf.extend_from_slice(&fp.to_le_bytes());
            let mut boundaries = vec![buf.len()];
            let mut records = Vec::new();
            for e in 0..g.usize(1, 5) {
                let rec = WalRecord {
                    rel_tag: g.u64(0, 5) as u8,
                    epoch: e as u64 + 1,
                    fold: (0..g.usize(0, 3))
                        .map(|_| (g.u64(0, 1023) as u32, g.u64(1, 99)))
                        .collect(),
                    stmts: (0..g.usize(1, 3))
                        .map(|_| {
                            let n = g.usize(0, 30);
                            (0..n).map(|_| g.u64(0, 255) as u8).collect()
                        })
                        .collect(),
                };
                buf.extend_from_slice(&rec.encode_frame());
                boundaries.push(buf.len());
                records.push(rec);
            }
            let cut = g.usize(0, buf.len());
            let scan = scan_records(&buf[..cut], fp).unwrap();
            if cut < WAL_HEADER {
                assert!(scan.torn && scan.records.is_empty() && scan.valid_len == 0);
                return;
            }
            // the scan lands exactly on the last record boundary <= cut
            let k = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(scan.records, records[..k], "cut {cut}");
            assert_eq!(scan.torn, cut != boundaries[k]);
            assert_eq!(scan.valid_len, boundaries[k]);
        });
    }
}
