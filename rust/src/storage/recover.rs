//! Crash recovery: open a data directory, pick the newest valid
//! checkpoint, truncate a torn WAL tail at the record boundary, and hand
//! the epoch-suffix of logged batches to the API layer for replay.
//!
//! The decision procedure (mirrored by the fault-injection battery in
//! `rust/tests/recovery_equivalence.rs`):
//!
//! 1. `base.img` must verify (magic, fingerprint, whole-file digest) and
//!    its scale factor must match the configured `sim_sf` — a mismatch
//!    is a configuration error, not corruption.
//! 2. Checkpoints are tried newest-first; a generation that fails its
//!    digest is skipped (the previous generation is kept on disk for
//!    exactly this fallback) — only when *no* generation verifies is the
//!    directory refused as corrupt.
//! 3. Every WAL segment of a generation >= the chosen checkpoint is
//!    scanned. Incomplete tail frames are torn tails: truncated at the
//!    last record boundary and counted. Complete frames that fail their
//!    checksum are corruption and refuse the open with
//!    [`PimdbError::Corrupt`].
//! 4. The surviving records replay in file order through the normal
//!    `exec_dml_on_states` path (see [`crate::api::Pimdb::open_durable`]),
//!    each batch's epoch checked contiguous against the recovering
//!    relation — so a lost intermediate segment can never be papered
//!    over silently.

use std::fs;
use std::path::Path;

use crate::config::{DurabilityConfig, SystemConfig};
use crate::db::dbgen::Database;
use crate::error::PimdbError;
use crate::storage::snapshot::{self, CkptRel};
use crate::storage::wal::{self, WalRecord, WalWriter};

/// Everything the API layer needs to finish a durable open: the load
/// image, the checkpointed relation states, the logged batches still to
/// replay, and the writer positioned for the next append.
pub(crate) struct Prepared {
    /// The base load image (read back, never regenerated).
    pub db: Database,
    /// Checkpointed relation states at the chosen generation.
    pub ckpt: Vec<CkptRel>,
    /// Logged batches from every segment >= the chosen generation, in
    /// file order; the caller replays the epoch suffix.
    pub wal_batches: Vec<WalRecord>,
    /// The current segment, torn tail truncated, positioned at its end.
    pub writer: WalWriter,
    /// Torn tails truncated across the scanned segments.
    pub torn_tails: u64,
    /// Older checkpoint generations skipped because their digest failed.
    pub checkpoints_skipped: u64,
    /// Highest relation epoch in the chosen checkpoint (0 when none).
    pub last_checkpoint_epoch: u64,
    /// Chosen checkpoint generation.
    pub generation: u64,
    /// Whether the directory was freshly initialized by this open.
    pub initialized: bool,
}

fn io_err(path: &Path, e: std::io::Error) -> PimdbError {
    PimdbError::Io(format!("{}: {e}", path.display()))
}

/// Open-or-initialize `data_dir`. A directory without a base image is
/// initialized from scratch (dbgen at `dcfg.seed`, an empty generation-0
/// checkpoint, an empty WAL segment); anything else is recovered.
pub(crate) fn prepare(
    cfg: &SystemConfig,
    dcfg: &DurabilityConfig,
    fingerprint: u64,
) -> Result<Prepared, PimdbError> {
    let dir = &dcfg.data_dir;
    fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;

    if !snapshot::base_path(dir).exists() {
        let db = Database::generate(cfg.sim_sf, dcfg.seed);
        snapshot::write_base(dir, fingerprint, &db).map_err(|e| io_err(dir, e))?;
        snapshot::write_checkpoint(dir, fingerprint, 0, &[]).map_err(|e| io_err(dir, e))?;
        let writer = WalWriter::create(dir, 0, fingerprint).map_err(|e| io_err(dir, e))?;
        return Ok(Prepared {
            db,
            ckpt: Vec::new(),
            wal_batches: Vec::new(),
            writer,
            torn_tails: 0,
            checkpoints_skipped: 0,
            last_checkpoint_epoch: 0,
            generation: 0,
            initialized: true,
        });
    }

    let db = snapshot::read_base(dir, fingerprint)?;
    if db.sf != cfg.sim_sf {
        return Err(PimdbError::Config(format!(
            "data dir {} was initialized at sim_sf {}, configured sim_sf is {}",
            dir.display(),
            db.sf,
            cfg.sim_sf
        )));
    }

    // newest digest-valid checkpoint wins; invalid ones are skipped
    let mut ckpt_gens = list_generations(dir, "ckpt-", ".pim")?;
    ckpt_gens.sort_unstable_by(|a, b| b.cmp(a));
    let mut chosen: Option<(u64, Vec<CkptRel>)> = None;
    let mut checkpoints_skipped = 0u64;
    for &g in &ckpt_gens {
        match snapshot::read_checkpoint(dir, g, fingerprint) {
            Ok(rels) => {
                chosen = Some((g, rels));
                break;
            }
            Err(PimdbError::Corrupt(_)) => checkpoints_skipped += 1,
            Err(e) => return Err(e),
        }
    }
    let (generation, ckpt) = chosen.ok_or_else(|| {
        PimdbError::Corrupt(format!(
            "data dir {}: no checkpoint generation verifies",
            dir.display()
        ))
    })?;
    let last_checkpoint_epoch = ckpt.iter().map(|r| r.epoch).max().unwrap_or(0);

    // scan every segment at or past the chosen generation, oldest first
    let mut wal_gens: Vec<u64> = list_generations(dir, "wal-", ".log")?
        .into_iter()
        .filter(|&g| g >= generation)
        .collect();
    wal_gens.sort_unstable();
    let mut wal_batches = Vec::new();
    let mut torn_tails = 0u64;
    let mut newest: Option<(u64, usize)> = None;
    for &g in &wal_gens {
        let path = wal::wal_path(dir, g);
        let buf = fs::read(&path).map_err(|e| io_err(&path, e))?;
        let scan = wal::scan_records(&buf, fingerprint)?;
        if scan.torn {
            torn_tails += 1;
        }
        wal_batches.extend(scan.records);
        newest = Some((g, scan.valid_len));
    }

    // reopen (or create) the current segment for appends. The current
    // segment is the newest scanned one; a checkpoint that crashed
    // between its rename and the segment rotation leaves the new
    // generation without a WAL file — created empty here.
    let writer = match newest {
        Some((g, valid_len)) if g >= generation => {
            WalWriter::open_truncated(dir, g, valid_len, fingerprint)
                .map_err(|e| io_err(&wal::wal_path(dir, g), e))?
        }
        _ => WalWriter::create(dir, generation, fingerprint)
            .map_err(|e| io_err(&wal::wal_path(dir, generation), e))?,
    };

    Ok(Prepared {
        db,
        ckpt,
        wal_batches,
        writer,
        torn_tails,
        checkpoints_skipped,
        last_checkpoint_epoch,
        generation,
        initialized: false,
    })
}

/// Generation numbers of every `<prefix>NNNNNNNN<suffix>` file in `dir`.
fn list_generations(dir: &Path, prefix: &str, suffix: &str) -> Result<Vec<u64>, PimdbError> {
    let mut gens = Vec::new();
    let entries = fs::read_dir(dir).map_err(|e| io_err(dir, e))?;
    for entry in entries {
        let entry = entry.map_err(|e| io_err(dir, e))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix(prefix) else {
            continue;
        };
        let Some(digits) = rest.strip_suffix(suffix) else {
            continue;
        };
        if let Ok(g) = digits.parse::<u64>() {
            gens.push(g);
        }
    }
    Ok(gens)
}

/// Delete checkpoint + WAL generations strictly older than `keep_from`
/// (best effort; the previous generation is the corruption fallback, so
/// callers pass `current - 1`).
pub(crate) fn prune_generations(dir: &Path, keep_from: u64) {
    for (prefix, suffix) in [("ckpt-", ".pim"), ("wal-", ".log")] {
        if let Ok(gens) = list_generations(dir, prefix, suffix) {
            for g in gens.into_iter().filter(|&g| g < keep_from) {
                let path = if prefix == "ckpt-" {
                    snapshot::ckpt_path(dir, g)
                } else {
                    wal::wal_path(dir, g)
                };
                let _ = fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "pimdb-recover-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn dcfg(dir: &Path) -> DurabilityConfig {
        DurabilityConfig {
            data_dir: dir.to_path_buf(),
            ..DurabilityConfig::new(dir)
        }
    }

    fn small_cfg() -> SystemConfig {
        SystemConfig {
            sim_sf: 0.001,
            ..SystemConfig::default()
        }
    }

    #[test]
    fn fresh_dir_initializes_then_reopens_without_regenerating() {
        let dir = tmpdir("init");
        let cfg = small_cfg();
        let fp = 0xF00D;
        let p = prepare(&cfg, &dcfg(&dir), fp).unwrap();
        assert!(p.initialized);
        assert_eq!(p.generation, 0);
        assert!(p.ckpt.is_empty() && p.wal_batches.is_empty());
        assert!(snapshot::base_path(&dir).exists());
        assert!(snapshot::ckpt_path(&dir, 0).exists());
        assert!(wal::wal_path(&dir, 0).exists());

        let p2 = prepare(&cfg, &dcfg(&dir), fp).unwrap();
        assert!(!p2.initialized);
        assert_eq!(p2.torn_tails, 0);
        assert_eq!(
            p2.db.rel(crate::db::schema::RelId::Lineitem).records,
            p.db.rel(crate::db::schema::RelId::Lineitem).records
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sf_mismatch_is_a_config_error_not_corruption() {
        let dir = tmpdir("sf");
        let cfg = small_cfg();
        let fp = 0xF00D;
        prepare(&cfg, &dcfg(&dir), fp).unwrap();
        let other = SystemConfig {
            sim_sf: 0.002,
            ..cfg
        };
        assert!(matches!(
            prepare(&other, &dcfg(&dir), fp),
            Err(PimdbError::Config(_))
        ));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_checkpoint_falls_back_to_the_previous_generation() {
        let dir = tmpdir("fallback");
        let cfg = small_cfg();
        let fp = 0xF00D;
        prepare(&cfg, &dcfg(&dir), fp).unwrap();
        // a second, newer checkpoint generation...
        snapshot::write_checkpoint(&dir, fp, 1, &[]).unwrap();
        WalWriter::create(&dir, 1, fp).unwrap();
        // ...that rots on disk
        let path = snapshot::ckpt_path(&dir, 1);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        fs::write(&path, &bytes).unwrap();

        let p = prepare(&cfg, &dcfg(&dir), fp).unwrap();
        assert_eq!(p.generation, 0);
        assert_eq!(p.checkpoints_skipped, 1);
        // the fallback still appends to the newest segment
        assert_eq!(p.writer.generation(), 1);

        // with generation 0 also rotten, the directory is refused
        let path0 = snapshot::ckpt_path(&dir, 0);
        let mut bytes0 = fs::read(&path0).unwrap();
        let last = bytes0.len() - 1;
        bytes0[last] ^= 1;
        fs::write(&path0, &bytes0).unwrap();
        assert!(matches!(
            prepare(&cfg, &dcfg(&dir), fp),
            Err(PimdbError::Corrupt(_))
        ));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keeps_the_fallback_generation() {
        let dir = tmpdir("prune");
        let cfg = small_cfg();
        let fp = 0xF00D;
        prepare(&cfg, &dcfg(&dir), fp).unwrap();
        for g in 1..4 {
            snapshot::write_checkpoint(&dir, fp, g, &[]).unwrap();
            WalWriter::create(&dir, g, fp).unwrap();
        }
        prune_generations(&dir, 2);
        for g in 0..2 {
            assert!(!snapshot::ckpt_path(&dir, g).exists(), "ckpt {g}");
            assert!(!wal::wal_path(&dir, g).exists(), "wal {g}");
        }
        for g in 2..4 {
            assert!(snapshot::ckpt_path(&dir, g).exists(), "ckpt {g}");
            assert!(wal::wal_path(&dir, g).exists(), "wal {g}");
        }
        let _ = fs::remove_dir_all(&dir);
    }
}
