//! Hand-rolled CLI argument parsing (no `clap` in the offline vendor set).
//!
//! Grammar: `pimdb <command> [--flag value]... [--set key=value]...`
//! Boolean flags take no value (`--baseline`). Unknown flags are errors.

use std::collections::BTreeMap;

use crate::config::{DurabilityConfig, SystemConfig};

/// Parsed command line: the command word plus `--flag value` pairs.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The command word (`run`, `report`, ...).
    pub command: String,
    flags: BTreeMap<String, String>,
    sets: Vec<(String, String)>,
}

/// Flags that are boolean (present/absent, no value).
const BOOL_FLAGS: [&str; 5] = ["baseline", "verbose", "help", "explain", "checkpoint"];

impl Args {
    /// Parse `argv` (without the program name) into command + flags.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter();
        let command = it.next().unwrap_or_else(|| "help".into());
        let mut args = Args {
            command,
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            // compiler-style short form: -O0 / -O1 / -O2
            if let Some(level) = tok.strip_prefix("-O").filter(|_| !tok.starts_with("--")) {
                if level.parse::<crate::query::opt::OptLevel>().is_err() {
                    return Err(format!("bad opt level '{tok}' (use -O0, -O1 or -O2)"));
                }
                args.flags.insert("opt-level".into(), level.to_string());
                continue;
            }
            let name = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{tok}'"))?
                .to_string();
            if name == "set" {
                let kv = it.next().ok_or("--set needs key=value")?;
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("--set expects key=value, got '{kv}'"))?;
                args.sets.push((k.trim().into(), v.trim().into()));
            } else if BOOL_FLAGS.contains(&name.as_str()) {
                args.flags.insert(name, "true".into());
            } else {
                let v = it
                    .next()
                    .ok_or_else(|| format!("--{name} needs a value"))?;
                args.flags.insert(name, v);
            }
        }
        Ok(args)
    }

    /// The value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Whether `--name` was given.
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// `--name` parsed as f64 (None when absent, Err on malformed).
    pub fn parse_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    /// `--name` parsed as u64 (None when absent, Err on malformed).
    pub fn parse_u64(&self, name: &str) -> Result<Option<u64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|e| format!("--{name}: {e}")))
            .transpose()
    }

    /// Build the system config: defaults, then --config file, then --sf /
    /// --threads conveniences, then --set overrides (highest precedence).
    pub fn build_config(&self) -> Result<SystemConfig, String> {
        let mut cfg = SystemConfig::default();
        if let Some(path) = self.get("config") {
            let body = std::fs::read_to_string(path)
                .map_err(|e| format!("config {path}: {e}"))?;
            cfg.apply_file(&body)?;
        }
        if let Some(sf) = self.parse_f64("sf")? {
            cfg.sim_sf = sf;
        }
        if let Some(t) = self.parse_u64("threads")? {
            cfg.exec_threads = t as usize;
        }
        if let Some(p) = self.parse_u64("parallelism")? {
            cfg.parallelism = p as usize;
        }
        if let Some(l) = self.get("opt-level") {
            cfg.opt_level = l.parse()?;
        }
        for (k, v) in &self.sets {
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// The durability configuration selected by `--data-dir` (plus
    /// `--fsync` and `--seed`), or `None` for an in-memory run.
    /// `--fsync` without `--data-dir` is a contradiction and an error.
    pub fn durability(&self) -> Result<Option<DurabilityConfig>, String> {
        let Some(dir) = self.get("data-dir") else {
            if self.has("fsync") {
                return Err("--fsync needs --data-dir".into());
            }
            return Ok(None);
        };
        let mut dcfg = DurabilityConfig::new(dir);
        if let Some(policy) = self.get("fsync") {
            dcfg.fsync = policy.parse()?;
        }
        if let Some(seed) = self.parse_u64("seed")? {
            dcfg.seed = seed;
        }
        Ok(Some(dcfg))
    }

    /// The functional backend selected by `--engine`.
    pub fn engine(&self) -> Result<crate::exec::pimdb::EngineKind, String> {
        match self.get_or("engine", "native") {
            "native" => Ok(crate::exec::pimdb::EngineKind::Native),
            "pjrt" => Ok(crate::exec::pimdb::EngineKind::Pjrt),
            other => Err(format!("unknown engine '{other}' (native|pjrt)")),
        }
    }

    /// Resolve the `run` command's statements (queries *and* DML) from
    /// exactly one of: `--query` (comma-separated TPC-H names, always
    /// queries), `--sql` (inline PQL text), or `--sql-file` (PQL text
    /// file, e.g. a `tests/pql/*.pql` fixture). Parse errors come back
    /// rendered with their source line and caret.
    pub fn statements(&self) -> Result<Vec<crate::query::ast::Statement>, String> {
        use crate::query::ast::Statement;
        let sources =
            [self.has("query"), self.has("sql"), self.has("sql-file")]
                .iter()
                .filter(|b| **b)
                .count();
        if sources == 0 {
            return Err("run needs --query, --sql or --sql-file".into());
        }
        if sources > 1 {
            return Err("--query, --sql and --sql-file are mutually exclusive".into());
        }
        if let Some(spec) = self.get("query") {
            return spec
                .split(',')
                .map(|n| {
                    let n = n.trim();
                    crate::query::tpch::query(n)
                        .map(Statement::Query)
                        .ok_or_else(|| format!("unknown query '{n}'"))
                })
                .collect();
        }
        let src: String = match self.get("sql") {
            Some(text) => text.to_string(),
            None => {
                let path = self.get("sql-file").expect("checked above");
                std::fs::read_to_string(path)
                    .map_err(|e| format!("--sql-file {path}: {e}"))?
            }
        };
        crate::query::lang::parse_statements(&src).map_err(|d| d.render(&src))
    }

    /// Like [`Args::statements`] but query-only: DML statements are an
    /// error (legacy entry point; `run` executes mixed programs).
    pub fn queries(&self) -> Result<Vec<crate::query::ast::Query>, String> {
        use crate::query::ast::Statement;
        self.statements()?
            .into_iter()
            .map(|s| match s {
                Statement::Query(q) => Ok(q),
                Statement::Dml(d) => Err(format!(
                    "'{}' is a DML statement; this entry point is query-only",
                    d.kind_name()
                )),
            })
            .collect()
    }
}

/// The `pimdb help` text.
pub const USAGE: &str = "\
pimdb — bulk-bitwise processing-in-memory database accelerator (PIMDB reproduction)

USAGE: pimdb <command> [flags]

COMMANDS:
  run        --query <Q1|Q2|...|Q22_sub>[,Q6,...] [--engine native|pjrt] [--baseline]
             run TPC-H queries on PIMDB (comma list batches them through
             the shard pool; optionally compare against the baseline)
             --sql \"from lineitem | filter l_quantity < 24 | aggregate count()\"
             run an ad-hoc PQL text query instead (--sql-file FILE reads
             the text, e.g. a .pql fixture, from disk); see README
             \"Query language\" for the grammar
             --sql also accepts DML statements, executed in source order
             against the resident PIM copy: \"insert into T (c,..) values
             (v,..)\", \"update T set c = v where ...\", \"delete from T
             where ...\"
             --explain     dump each statement's compiled PIM program
             (queries: disassembly before/after the optimizer passes;
             DML: the row-write image or filter+mutation stream)
  report     --exp <table1..6|fig8..15|ablation-rowpar|calibration|all>
             regenerate a paper table/figure
  gen-data   [--sf F] [--seed N]    generate + summarize the TPC-H data
  addrmap    print the Fig. 3 physical-address/cell mapping
  inspect    --op <name> [--n BITS] [--imm V]   instruction cost details
  help       this text

COMMON FLAGS:
  --sf F            simulated scale factor (default 0.01)
  --seed N          generator seed (default 42)
  --threads N       simulated executor threads (default 4)
  --parallelism N   host worker threads for functional execution
                    (0 = auto-detect cores; default 1; results identical)
  --engine E        functional backend: native | pjrt
  -O0|-O1|-O2       PIM-program optimization level (default -O2; also
                    --opt-level N / --set opt_level=N); results are
                    bit-identical at every level
  --config FILE     key=value config file (see `report --exp table3`)
  --set key=value   override one config key (repeatable)

DURABILITY (run command):
  --data-dir DIR    open a durable handle rooted at DIR: first use writes
                    a base image + checkpoint, later runs recover (WAL
                    replay) and DML statements append to the write-ahead
                    log before committing
  --fsync P         WAL fsync policy: always | group-commit | off
                    (default group-commit; requires --data-dir)
  --checkpoint      write a checkpoint after the statements run
                    (bounds future recovery replay; requires --data-dir)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args, String> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic_command_and_flags() {
        let a = parse("run --query Q6 --engine pjrt --baseline").unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("query"), Some("Q6"));
        assert!(a.has("baseline"));
        assert_eq!(a.engine().unwrap(), crate::exec::pimdb::EngineKind::Pjrt);
    }

    #[test]
    fn set_overrides_apply_to_config() {
        let a = parse("run --sf 0.5 --set exec_threads=8 --set dram_standby_w=2.5").unwrap();
        let cfg = a.build_config().unwrap();
        assert_eq!(cfg.sim_sf, 0.5);
        assert_eq!(cfg.exec_threads, 8);
        assert_eq!(cfg.dram_standby_w, 2.5);
    }

    #[test]
    fn parallelism_flag_and_set_override() {
        let a = parse("run --parallelism 8").unwrap();
        assert_eq!(a.build_config().unwrap().parallelism, 8);
        // --set has the highest precedence
        let a = parse("run --parallelism 8 --set parallelism=2").unwrap();
        assert_eq!(a.build_config().unwrap().parallelism, 2);
        assert!(parse("run --parallelism x").unwrap().build_config().is_err());
    }

    #[test]
    fn opt_level_short_and_long_forms() {
        use crate::query::opt::OptLevel;
        let a = parse("run --query Q6 -O0").unwrap();
        assert_eq!(a.build_config().unwrap().opt_level, OptLevel::O0);
        let a = parse("run --opt-level 1").unwrap();
        assert_eq!(a.build_config().unwrap().opt_level, OptLevel::O1);
        // --set has the highest precedence
        let a = parse("run -O0 --set opt_level=2").unwrap();
        assert_eq!(a.build_config().unwrap().opt_level, OptLevel::O2);
        // default is -O2
        let a = parse("run --query Q6").unwrap();
        assert_eq!(a.build_config().unwrap().opt_level, OptLevel::O2);
        assert!(parse("run -O9").is_err());
        assert!(parse("run --explain").unwrap().has("explain"));
    }

    #[test]
    fn errors_on_malformed_input() {
        assert!(parse("run query Q6").is_err());
        assert!(parse("run --query").is_err());
        assert!(parse("run --set nokv").is_err());
        assert!(parse("run --set bogus=1").unwrap().build_config().is_err());
        assert!(parse("run --engine warp").unwrap().engine().is_err());
    }

    #[test]
    fn queries_from_names_or_sql() {
        let a = parse("run --query Q6,Q11").unwrap();
        let qs = a.queries().unwrap();
        assert_eq!(qs.len(), 2);
        assert_eq!(qs[0].name, "Q6");

        // --sql needs quoting in a real shell; build Args directly here
        let a = Args::parse(
            ["run", "--sql", "from supplier | filter s_suppkey < 10"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let qs = a.queries().unwrap();
        assert_eq!(qs.len(), 1);
        assert_eq!(qs[0].name, "adhoc");
        assert_eq!(qs[0].rels[0].rel, crate::db::schema::RelId::Supplier);

        // parse errors come back rendered with a caret
        let a = Args::parse(
            ["run", "--sql", "from supplier | filter nope < 10"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let err = a.queries().unwrap_err();
        assert!(err.contains("unknown column"), "{err}");
        assert!(err.contains("^"), "{err}");
    }

    #[test]
    fn query_sources_are_mutually_exclusive() {
        let a = Args::parse(
            ["run", "--query", "Q6", "--sql", "from part | filter true"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        assert!(a.queries().unwrap_err().contains("mutually exclusive"));
        assert!(parse("run").unwrap().queries().is_err());
        assert!(parse("run --sql-file /does/not/exist.pql")
            .unwrap()
            .queries()
            .is_err());
    }

    #[test]
    fn durability_flags() {
        use crate::config::FsyncPolicy;
        // no --data-dir: in-memory run
        assert_eq!(parse("run --query Q6").unwrap().durability().unwrap(), None);
        // --data-dir alone: defaults (group-commit fsync, seed 42)
        let d = parse("run --data-dir /tmp/d").unwrap().durability().unwrap().unwrap();
        assert_eq!(d.data_dir, std::path::PathBuf::from("/tmp/d"));
        assert_eq!(d.fsync, FsyncPolicy::GroupCommit);
        assert_eq!(d.seed, 42);
        // --fsync and --seed thread through
        let d = parse("run --data-dir /tmp/d --fsync always --seed 7")
            .unwrap()
            .durability()
            .unwrap()
            .unwrap();
        assert_eq!(d.fsync, FsyncPolicy::Always);
        assert_eq!(d.seed, 7);
        // contradictions and typos are errors
        assert!(parse("run --fsync off").unwrap().durability().is_err());
        assert!(parse("run --data-dir /tmp/d --fsync sometimes")
            .unwrap()
            .durability()
            .is_err());
        assert!(parse("run --data-dir /tmp/d --checkpoint").unwrap().has("checkpoint"));
    }

    #[test]
    fn defaults() {
        let a = parse("report").unwrap();
        assert_eq!(a.get_or("exp", "all"), "all");
        assert_eq!(a.engine().unwrap(), crate::exec::pimdb::EngineKind::Native);
        let cfg = a.build_config().unwrap();
        assert_eq!(cfg.sim_sf, 0.01);
    }
}
