//! Typed result rows: decoded values and the cursor API returned by
//! [`crate::api::Prepared::execute`].
//!
//! The engine's raw [`QueryOutput`] speaks in encoded `u64`s — epoch-day
//! dates, offset cents, dictionary ids — because that is what lives in
//! the crossbars. This module is the decoding boundary: group keys come
//! back as [`Value::Date`] / [`Value::Money`] / [`Value::Str`] per the
//! schema encoding of their attribute, and aggregate cells are typed by
//! the aggregate (COUNT is an integer, MIN/MAX/SUM of a raw attribute
//! inherit its encoding, everything else is a float).

use std::fmt;

use crate::db::schema::{self, Encoding};
use crate::exec::metrics::{GroupOutput, QueryOutput};
use crate::query::ast::{AggKind, Aggregate, Query, QueryKind, ValExpr};

/// One decoded result cell.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Plain integer (raw unsigned attributes, counts).
    Int(i64),
    /// Floating-point aggregate (sums of derived expressions, averages).
    Float(f64),
    /// Currency in cents, offset already removed (`12345` = `$123.45`).
    Money(i64),
    /// Calendar date decoded from the epoch-day encoding.
    Date {
        /// Four-digit year.
        year: i64,
        /// Month, 1–12.
        month: u8,
        /// Day of month, 1–31.
        day: u8,
    },
    /// Dictionary-decoded string (group keys on Dict attributes).
    Str(String),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Money(cents) => {
                let sign = if *cents < 0 { "-" } else { "" };
                let a = cents.unsigned_abs();
                write!(f, "{sign}{}.{:02}", a / 100, a % 100)
            }
            Value::Date { year, month, day } => {
                write!(f, "{year:04}-{month:02}-{day:02}")
            }
            Value::Str(s) => write!(f, "{s}"),
        }
    }
}

impl Value {
    /// The cell as `f64` (counts and money convert; dates/strings don't).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(v) => Some(*v as f64),
            Value::Float(v) => Some(*v),
            Value::Money(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// The cell as `i64` (floats don't silently truncate).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) | Value::Money(v) => Some(*v),
            _ => None,
        }
    }

    /// The cell as a string slice, for [`Value::Str`] cells.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// One decoded result row: named, typed cells.
#[derive(Clone, Debug, PartialEq)]
pub struct Row {
    cells: Vec<(&'static str, Value)>,
}

impl Row {
    /// All cells as `(column, value)` pairs, in column order.
    pub fn cells(&self) -> &[(&'static str, Value)] {
        &self.cells
    }

    /// The cell of column `name`, if present.
    pub fn get(&self, name: &str) -> Option<&Value> {
        self.cells
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v)
    }

    /// Column names in order.
    pub fn columns(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.cells.iter().map(|(n, _)| *n)
    }
}

/// Cursor over the decoded rows of one execution (an iterator of
/// [`Row`]s; also indexable via [`Rows::len`] / [`Rows::row`]).
#[derive(Clone, Debug)]
pub struct Rows<'a> {
    rows: &'a [Row],
    next: usize,
}

impl<'a> Rows<'a> {
    pub(crate) fn new(rows: &'a [Row]) -> Rows<'a> {
        Rows { rows, next: 0 }
    }

    /// Total rows in the result (independent of cursor position).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the result has no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Random access by row index.
    pub fn row(&self, i: usize) -> Option<&'a Row> {
        self.rows.get(i)
    }
}

impl<'a> Iterator for Rows<'a> {
    type Item = &'a Row;

    fn next(&mut self) -> Option<&'a Row> {
        let r = self.rows.get(self.next)?;
        self.next += 1;
        Some(r)
    }
}

/// Decode an encoded attribute value per its schema encoding. Attributes
/// are resolved against every relation of the query (TPC-H attribute
/// names are globally unique via their `l_`/`o_`/... prefixes).
fn decode_attr(q: &Query, name: &str, raw: u64) -> Value {
    let attr = q.rels.iter().find_map(|rq| schema::attr(rq.rel, name));
    match attr.map(|a| a.enc) {
        Some(Encoding::Dict) => match schema::dict_word(name, raw) {
            Some(word) => Value::Str(word),
            None => Value::Int(raw as i64),
        },
        Some(Encoding::Date) => {
            let (year, month, day) = schema::date_ymd(raw);
            Value::Date {
                year,
                month: month as u8,
                day: day as u8,
            }
        }
        Some(Encoding::Money { offset }) => Value::Money(raw as i64 - offset),
        _ => Value::Int(raw as i64),
    }
}

/// Type one aggregate cell. `raw` is the engine's combined value (`f64`
/// after the host-side combine), `count` the group's record count.
fn decode_agg(q: &Query, agg: &Aggregate, raw: f64, count: u64) -> Value {
    match (agg.kind, &agg.expr) {
        (AggKind::Count, _) => Value::Int(raw as i64),
        // MIN/MAX of a bare attribute is an actual attribute value:
        // decode it like one (dates, money offsets, dictionary words)
        (AggKind::Min | AggKind::Max, ValExpr::Attr(a)) => {
            if count == 0 {
                // empty selection reports 0, which is not a valid encoded
                // value for offset/date attributes — keep it numeric
                Value::Float(raw)
            } else {
                decode_attr(q, a, raw as u64)
            }
        }
        // SUM of a bare money attribute stays currency: remove the
        // per-record offset using the group count
        (AggKind::Sum, ValExpr::Attr(a)) => {
            let enc = q.rels.iter().find_map(|rq| schema::attr(rq.rel, a)).map(|x| x.enc);
            if let Some(Encoding::Money { offset }) = enc {
                Value::Money(raw as i64 - offset * count as i64)
            } else {
                Value::Float(raw)
            }
        }
        // AVG of a bare money attribute: every record carries the offset
        // once, so the mean carries it exactly once (fractional cents
        // stay a float; an empty selection reports 0, not -offset)
        (AggKind::Avg, ValExpr::Attr(a)) => {
            let enc = q.rels.iter().find_map(|rq| schema::attr(rq.rel, a)).map(|x| x.enc);
            if let (Some(Encoding::Money { offset }), true) = (enc, count > 0) {
                Value::Float(raw - offset as f64)
            } else {
                Value::Float(raw)
            }
        }
        _ => Value::Float(raw),
    }
}

fn group_row(q: &Query, g: &GroupOutput) -> Row {
    let mut cells = Vec::with_capacity(g.key.len() + g.values.len() + 1);
    for (attr, raw) in &g.key {
        cells.push((*attr, decode_attr(q, attr, *raw)));
    }
    for (label, raw) in &g.values {
        // match the aggregate by label (labels are unique per query; the
        // engine emits values in declaration order)
        let agg = q
            .rels
            .iter()
            .flat_map(|rq| rq.aggregates.iter())
            .find(|a| a.label == *label);
        let v = match agg {
            Some(a) => decode_agg(q, a, *raw, g.count),
            None => Value::Float(*raw),
        };
        cells.push((*label, v));
    }
    cells.push(("count", Value::Int(g.count as i64)));
    Row { cells }
}

/// Decode an engine output into rows (see [`crate::api::QueryResult`]):
/// one row per group for full queries, one `(relation, selected)` row per
/// relation for filter-only queries.
pub(crate) fn decode_rows(q: &Query, output: &QueryOutput) -> Vec<Row> {
    match q.kind {
        QueryKind::Full => output.groups.iter().map(|g| group_row(q, g)).collect(),
        QueryKind::FilterOnly => output
            .selected
            .iter()
            .map(|(rel, n)| Row {
                cells: vec![
                    ("relation", Value::Str(rel.to_string())),
                    ("selected", Value::Int(*n as i64)),
                ],
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::schema::RelId;
    use crate::query::ast::{Pred, RelQuery};

    fn full_query() -> Query {
        Query {
            name: "t",
            kind: QueryKind::Full,
            rels: vec![RelQuery {
                rel: RelId::Lineitem,
                filter: Pred::True,
                group_by: vec!["l_returnflag", "l_shipdate"],
                aggregates: vec![
                    Aggregate {
                        kind: AggKind::Count,
                        expr: ValExpr::One,
                        label: "n",
                    },
                    Aggregate {
                        kind: AggKind::Max,
                        expr: ValExpr::Attr("l_extendedprice"),
                        label: "max_price",
                    },
                    Aggregate {
                        kind: AggKind::Sum,
                        expr: ValExpr::MulAttrs("l_quantity", "l_discount"),
                        label: "weird",
                    },
                ],
            }],
        }
    }

    #[test]
    fn group_rows_decode_schema_encodings() {
        let q = full_query();
        let out = QueryOutput {
            selected: vec![("LINEITEM", 3)],
            groups: vec![GroupOutput {
                key: vec![
                    ("l_returnflag", 1),
                    ("l_shipdate", schema::date(1994, 2, 17)),
                ],
                values: vec![("n", 3.0), ("max_price", 123_45.0), ("weird", 7.5)],
                count: 3,
            }],
        };
        let rows = decode_rows(&q, &out);
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.get("l_returnflag"), Some(&Value::Str("A".into())));
        assert_eq!(
            row.get("l_shipdate"),
            Some(&Value::Date {
                year: 1994,
                month: 2,
                day: 17
            })
        );
        assert_eq!(row.get("n"), Some(&Value::Int(3)));
        // l_extendedprice is money with zero offset -> cents
        assert_eq!(row.get("max_price"), Some(&Value::Money(12_345)));
        assert_eq!(row.get("weird"), Some(&Value::Float(7.5)));
        assert_eq!(row.get("count"), Some(&Value::Int(3)));
        assert_eq!(row.get("absent"), None);
        let cols: Vec<_> = row.columns().collect();
        assert_eq!(
            cols,
            vec!["l_returnflag", "l_shipdate", "n", "max_price", "weird", "count"]
        );
    }

    #[test]
    fn filter_only_rows_report_selected_counts() {
        let q = Query {
            name: "f",
            kind: QueryKind::FilterOnly,
            rels: vec![],
        };
        let out = QueryOutput {
            selected: vec![("PART", 10), ("SUPPLIER", 2)],
            groups: vec![],
        };
        let rows = decode_rows(&q, &out);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("relation"), Some(&Value::Str("PART".into())));
        assert_eq!(rows[1].get("selected"), Some(&Value::Int(2)));
    }

    #[test]
    fn cursor_iterates_and_indexes() {
        let rows = vec![
            Row {
                cells: vec![("a", Value::Int(1))],
            },
            Row {
                cells: vec![("a", Value::Int(2))],
            },
        ];
        let mut cur = Rows::new(&rows);
        assert_eq!(cur.len(), 2);
        assert!(!cur.is_empty());
        assert_eq!(cur.next().unwrap().get("a"), Some(&Value::Int(1)));
        assert_eq!(cur.next().unwrap().get("a"), Some(&Value::Int(2)));
        assert!(cur.next().is_none());
        assert_eq!(cur.row(1).unwrap().get("a"), Some(&Value::Int(2)));
    }

    #[test]
    fn value_display_and_accessors() {
        assert_eq!(Value::Money(12_345).to_string(), "123.45");
        assert_eq!(Value::Money(-205).to_string(), "-2.05");
        assert_eq!(
            Value::Date {
                year: 1998,
                month: 9,
                day: 2
            }
            .to_string(),
            "1998-09-02"
        );
        assert_eq!(Value::Str("RAIL".into()).to_string(), "RAIL");
        assert_eq!(Value::Int(7).as_f64(), Some(7.0));
        assert_eq!(Value::Float(1.5).as_i64(), None);
        assert_eq!(Value::Str("x".into()).as_str(), Some("x"));
    }

    #[test]
    fn money_sum_and_avg_remove_the_encoding_offset() {
        let q = Query {
            name: "m",
            kind: QueryKind::Full,
            rels: vec![RelQuery {
                rel: RelId::Supplier,
                filter: Pred::True,
                group_by: vec![],
                aggregates: vec![
                    Aggregate {
                        kind: AggKind::Sum,
                        expr: ValExpr::Attr("s_acctbal"),
                        label: "total_bal",
                    },
                    Aggregate {
                        kind: AggKind::Avg,
                        expr: ValExpr::Attr("s_acctbal"),
                        label: "avg_bal",
                    },
                ],
            }],
        };
        // two records of $1.00 stored with the +100000 offset each:
        // the sum carries the offset per record, the mean exactly once
        let raw_sum = 2.0 * (100.0 + 100_000.0);
        let raw_avg = 100.0 + 100_000.0;
        let out = QueryOutput {
            selected: vec![("SUPPLIER", 2)],
            groups: vec![GroupOutput {
                key: vec![],
                values: vec![("total_bal", raw_sum), ("avg_bal", raw_avg)],
                count: 2,
            }],
        };
        let rows = decode_rows(&q, &out);
        assert_eq!(rows[0].get("total_bal"), Some(&Value::Money(200)));
        assert_eq!(rows[0].get("avg_bal"), Some(&Value::Float(100.0)));

        // empty selection: the engine reports 0 — keep it 0, not -offset
        let empty = QueryOutput {
            selected: vec![("SUPPLIER", 0)],
            groups: vec![GroupOutput {
                key: vec![],
                values: vec![("total_bal", 0.0), ("avg_bal", 0.0)],
                count: 0,
            }],
        };
        let rows = decode_rows(&q, &empty);
        assert_eq!(rows[0].get("avg_bal"), Some(&Value::Float(0.0)));
        assert_eq!(rows[0].get("total_bal"), Some(&Value::Money(0)));
    }
}
