//! Plan cache: canonical AST hashing and the compiled-plan store behind
//! [`crate::api::Pimdb::prepare`].
//!
//! The cache key is a *canonical byte serialization* of the query AST
//! combined with the optimization level and a schema/geometry
//! fingerprint (`plan_bytes`, crate-internal — the map keys on the full
//! bytes, so hash collisions cannot serve a wrong plan; [`plan_key`] is
//! the compact 64-bit FNV-1a digest of the same stream, the identity
//! tests and the Python mirror speak). Canonicalization makes the key
//!
//! * **insensitive** to anything that cannot change the compiled program:
//!   source whitespace and comments (the AST never sees them), the query
//!   block's name, and aggregate output aliases (`as revenue` vs
//!   `as rev` — labels are rebound on the cached plan at prepare time);
//! * **sensitive** to everything that can: predicate structure and
//!   literals, aggregate kinds/expressions, group-by sets, the relation
//!   set, [`OptLevel`], and the schema/crossbar geometry fingerprint.
//!
//! The byte format is versioned (leading tag byte) and deliberately
//! simple — length-prefixed strings, little-endian integers, one tag byte
//! per enum variant — because `python/apimirror.py` mirrors it line by
//! line and fuzzes the invariance/sensitivity properties against a
//! structural duplicate-detection oracle (the no-Rust-toolchain
//! validation workflow, see that file's header).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::SystemConfig;
use crate::db::schema::{self, Encoding};
use crate::error::PimdbError;
use crate::exec::metrics::{OptSummary, PlanCacheCounters};
use crate::query::ast::{AggKind, CmpOp, Dml, Pred, Query, ValExpr};
use crate::query::compiler::{CompiledDml, CompiledRelQuery};
use crate::query::opt::OptLevel;

/// Serialization format version (first byte of every canonical stream).
/// The WAL record decoder ([`crate::storage::wal`]) checks the same byte
/// when it inverts [`dml_bytes`] at recovery time.
pub(crate) const FORMAT_VERSION: u8 = 1;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit digest of a canonical byte stream. Shared with the
/// durability layer ([`crate::storage`]): WAL record checksums and
/// checkpoint whole-file digests speak the same function the plan-cache
/// keys and the Python mirrors pin.
pub(crate) fn fnv1a(bs: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bs {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Canonical byte-stream writer. The materialized bytes — not their
/// 64-bit digest — are the cache-map key, so a (constructible, FNV is
/// not collision-resistant) hash collision can never serve the wrong
/// plan; the digest is only the compact identity [`plan_key`] exposes.
struct Ser {
    buf: Vec<u8>,
}

impl Ser {
    fn new() -> Ser {
        Ser { buf: Vec::new() }
    }

    fn bytes(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn i64(&mut self, v: i64) {
        self.bytes(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }
}

fn cmp_tag(op: CmpOp) -> u8 {
    match op {
        CmpOp::Eq => 0,
        CmpOp::Ne => 1,
        CmpOp::Lt => 2,
        CmpOp::Le => 3,
        CmpOp::Gt => 4,
        CmpOp::Ge => 5,
    }
}

fn agg_tag(kind: AggKind) -> u8 {
    match kind {
        AggKind::Sum => 0,
        AggKind::Count => 1,
        AggKind::Min => 2,
        AggKind::Max => 3,
        AggKind::Avg => 4,
    }
}

fn hash_pred(h: &mut Ser, p: &Pred) {
    match p {
        Pred::CmpImm { attr, op, value } => {
            h.u8(0);
            h.str(attr);
            h.u8(cmp_tag(*op));
            h.u64(*value);
        }
        Pred::InSet { attr, values } => {
            h.u8(1);
            h.str(attr);
            h.u32(values.len() as u32);
            for v in values {
                h.u64(*v);
            }
        }
        Pred::Between { attr, lo, hi } => {
            h.u8(2);
            h.str(attr);
            h.u64(*lo);
            h.u64(*hi);
        }
        Pred::CmpCols { a, op, b } => {
            h.u8(3);
            h.str(a);
            h.u8(cmp_tag(*op));
            h.str(b);
        }
        Pred::And(ps) => {
            h.u8(4);
            h.u32(ps.len() as u32);
            for q in ps {
                hash_pred(h, q);
            }
        }
        Pred::Or(ps) => {
            h.u8(5);
            h.u32(ps.len() as u32);
            for q in ps {
                hash_pred(h, q);
            }
        }
        Pred::Not(q) => {
            h.u8(6);
            hash_pred(h, q);
        }
        Pred::True => h.u8(7),
    }
}

fn hash_vexpr(h: &mut Ser, e: &ValExpr) {
    match e {
        ValExpr::Attr(a) => {
            h.u8(0);
            h.str(a);
        }
        ValExpr::One => h.u8(1),
        ValExpr::MulAttrs(a, b) => {
            h.u8(2);
            h.str(a);
            h.str(b);
        }
        ValExpr::MulComplement { attr, scale, other } => {
            h.u8(3);
            h.str(attr);
            h.u64(*scale);
            h.str(other);
        }
        ValExpr::MulSum { attr, scale, other } => {
            h.u8(4);
            h.str(attr);
            h.u64(*scale);
            h.str(other);
        }
        ValExpr::MulComplementSum {
            attr,
            scale1,
            other1,
            scale2,
            other2,
        } => {
            h.u8(5);
            h.str(attr);
            h.u64(*scale1);
            h.str(other1);
            h.u64(*scale2);
            h.str(other2);
        }
    }
}

/// Fingerprint of everything *outside* the query that the compiled plan
/// depends on: the PIM schema (attribute names, widths, encodings per
/// relation) and the crossbar geometry the compiler and optimizer see.
/// Two [`crate::api::Pimdb`] handles share plan keys iff their
/// fingerprints match.
pub fn plan_fingerprint(cfg: &SystemConfig) -> u64 {
    let mut h = Ser::new();
    h.u8(FORMAT_VERSION);
    h.u32(cfg.xbar_cols as u32);
    h.u32(cfg.xbar_rows as u32);
    for rel in schema::PIM_RELATIONS {
        h.str(rel.name());
        let attrs = schema::attrs(rel);
        h.u32(attrs.len() as u32);
        for a in attrs {
            h.str(a.name);
            h.u32(a.bits as u32);
            match a.enc {
                Encoding::Uint => {
                    h.u8(0);
                    h.i64(0);
                }
                Encoding::Dict => {
                    h.u8(1);
                    h.i64(0);
                }
                Encoding::Date => {
                    h.u8(2);
                    h.i64(0);
                }
                Encoding::Money { offset } => {
                    h.u8(3);
                    h.i64(offset);
                }
            }
        }
    }
    fnv1a(&h.buf)
}

/// The full canonical serialization of `(q, level, fingerprint)` — the
/// exact (collision-free) cache-map key. [`plan_key`] is its digest.
pub(crate) fn plan_bytes(q: &Query, level: OptLevel, fingerprint: u64) -> Vec<u8> {
    let mut h = Ser::new();
    h.u8(FORMAT_VERSION);
    // query name omitted: renaming a block must not defeat the cache
    h.u8(match q.kind {
        crate::query::ast::QueryKind::Full => 0,
        crate::query::ast::QueryKind::FilterOnly => 1,
    });
    h.u32(q.rels.len() as u32);
    for rq in &q.rels {
        h.str(rq.rel.name());
        hash_pred(&mut h, &rq.filter);
        h.u32(rq.group_by.len() as u32);
        for g in &rq.group_by {
            h.str(g);
        }
        h.u32(rq.aggregates.len() as u32);
        for a in &rq.aggregates {
            // label omitted: aliases are rebound on the cached plan
            h.u8(agg_tag(a.kind));
            hash_vexpr(&mut h, &a.expr);
        }
    }
    h.u8(match level {
        OptLevel::O0 => 0,
        OptLevel::O1 => 1,
        OptLevel::O2 => 2,
    });
    h.u64(fingerprint);
    h.buf
}

/// Canonical plan-cache key of `q` at `level` under `fingerprint` — the
/// 64-bit FNV-1a digest of [`plan_bytes`] (see the module docs for the
/// exact invariance/sensitivity contract, and `python/apimirror.py` for
/// the mirrored implementation).
pub fn plan_key(q: &Query, level: OptLevel, fingerprint: u64) -> u64 {
    fnv1a(&plan_bytes(q, level, fingerprint))
}

/// Canonical serialization of a DML statement under `fingerprint` — the
/// prepared-DML cache-map key. The kind byte (2/3/4 for insert/update/
/// delete) is disjoint from the query kind bytes (0/1), so DML keys can
/// never collide with query keys; the query byte format — and therefore
/// the schema fingerprint and the cross-language golden pins — is
/// unchanged. DML programs bypass the optimizer, so no [`OptLevel`] is
/// folded in.
pub(crate) fn dml_bytes(d: &Dml, fingerprint: u64) -> Vec<u8> {
    let mut h = Ser::new();
    h.u8(FORMAT_VERSION);
    match d {
        Dml::Insert { rel, values } => {
            h.u8(2);
            h.str(rel.name());
            h.u32(values.len() as u32);
            for (n, v) in values {
                h.str(n);
                h.u64(*v);
            }
        }
        Dml::Update { rel, filter, sets } => {
            h.u8(3);
            h.str(rel.name());
            hash_pred(&mut h, filter);
            h.u32(sets.len() as u32);
            for (n, v) in sets {
                h.str(n);
                h.u64(*v);
            }
        }
        Dml::Delete { rel, filter } => {
            h.u8(4);
            h.str(rel.name());
            hash_pred(&mut h, filter);
        }
    }
    h.u64(fingerprint);
    h.buf
}

/// Compact digest of [`dml_bytes`] (observability twin of [`plan_key`]).
pub fn dml_key(d: &Dml, fingerprint: u64) -> u64 {
    fnv1a(&dml_bytes(d, fingerprint))
}

/// One cached prepared plan: the optimized per-relation programs plus the
/// optimizer summary the report path surfaces.
pub(crate) struct CachedPlan {
    /// Optimized programs the *executor* runs, parallel to the source
    /// query's `rels`. When zone-map statistics were available at
    /// prepare time these carry the cost-based predicate reordering.
    pub compiled: Vec<CompiledRelQuery>,
    /// The same programs through the plain (stats-free) pass pipeline —
    /// what the legacy session compiles. The simulator and the wear
    /// model charge these, keeping every simulated metric bit-identical
    /// to the unreordered path: reordering and pruning are host-runtime
    /// execution-schedule choices, not changes to what the simulated
    /// device does.
    pub sim: Vec<CompiledRelQuery>,
    /// Shared-scan split + canonical prefix key per program (parallel to
    /// `compiled`); `None` where the analysis proved nothing shareable.
    pub scans: Vec<Option<crate::query::opt::sharedscan::ScanInfo>>,
    /// What the pass pipeline did, summed per Table 5 semantics.
    pub opt: OptSummary,
}

/// Bound on resident plans: literal-sensitive keys mean a serving
/// workload with per-request literals mints unbounded distinct
/// templates; past the cap an arbitrary entry is evicted (pseudo-random
/// — swap for LRU if a real workload ever shows thrash here).
const MAX_CACHED_PLANS: usize = 1024;

/// One cached prepared DML plan (the compiled statement; DML bypasses
/// the optimizer pass pipeline).
pub(crate) struct CachedDmlPlan {
    /// The compiled statement.
    pub compiled: CompiledDml,
}

/// Thread-safe plan store keyed by the *full* canonical serialization
/// ([`plan_bytes`] / [`dml_bytes`] — collision-free by construction),
/// with hit/miss counters shared by queries and DML (`hits + misses`
/// equals the prepares served). `misses` counts compilations: two
/// threads racing the same new template may both compile (the first
/// insert wins, both count).
pub(crate) struct PlanCache {
    plans: Mutex<HashMap<Vec<u8>, Arc<CachedPlan>>>,
    dml_plans: Mutex<HashMap<Vec<u8>, Arc<CachedDmlPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub(crate) fn new() -> PlanCache {
        PlanCache {
            plans: Mutex::new(HashMap::new()),
            dml_plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Lock the query-plan map (test/introspection accessor).
    fn lock_plans(&self) -> std::sync::MutexGuard<'_, HashMap<Vec<u8>, Arc<CachedPlan>>> {
        lock_map(&self.plans)
    }

    /// The lookup/compile/evict discipline shared by both plan maps.
    /// Compilation runs *outside* the map lock so cache hits on other
    /// templates never stall behind an in-flight compile; the first
    /// insert wins a racing duplicate compile (both count a miss).
    fn get_or_compile_in<T>(
        &self,
        map: &Mutex<HashMap<Vec<u8>, Arc<T>>>,
        key: Vec<u8>,
        compile: impl FnOnce() -> Result<T, PimdbError>,
    ) -> Result<Arc<T>, PimdbError> {
        if let Some(plan) = lock_map(map).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(plan));
        }
        let plan = Arc::new(compile()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut plans = lock_map(map);
        if plans.len() >= MAX_CACHED_PLANS && !plans.contains_key(&key) {
            if let Some(evict) = plans.keys().next().cloned() {
                plans.remove(&evict);
            }
        }
        Ok(Arc::clone(plans.entry(key).or_insert(plan)))
    }

    /// Look `key` up; on a miss run `compile` and cache its result.
    pub(crate) fn get_or_compile(
        &self,
        key: Vec<u8>,
        compile: impl FnOnce() -> Result<CachedPlan, PimdbError>,
    ) -> Result<Arc<CachedPlan>, PimdbError> {
        self.get_or_compile_in(&self.plans, key, compile)
    }

    /// Look a DML key up; on a miss run `compile` and cache its result
    /// (same discipline; the hit/miss counters are shared with the
    /// query side).
    pub(crate) fn get_or_compile_dml(
        &self,
        key: Vec<u8>,
        compile: impl FnOnce() -> Result<CachedDmlPlan, PimdbError>,
    ) -> Result<Arc<CachedDmlPlan>, PimdbError> {
        self.get_or_compile_in(&self.dml_plans, key, compile)
    }

    /// Snapshot of the hit/miss counters.
    pub(crate) fn counters(&self) -> PlanCacheCounters {
        PlanCacheCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Drop every cached plan, query and DML (counters keep
    /// accumulating). The next prepare of any statement recompiles —
    /// used by benchmarks to measure the unprepared path honestly.
    pub(crate) fn clear(&self) {
        lock_map(&self.plans).clear();
        lock_map(&self.dml_plans).clear();
    }
}

/// Lock a plan map, recovering from poisoning (a panicked compile never
/// ran `insert`, so the map contents are always consistent).
fn lock_map<T>(
    m: &Mutex<HashMap<Vec<u8>, Arc<T>>>,
) -> std::sync::MutexGuard<'_, HashMap<Vec<u8>, Arc<T>>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::lang::parse_program;

    fn key_of(src: &str, level: OptLevel) -> u64 {
        let qs = parse_program(src).expect("fixture parses");
        assert_eq!(qs.len(), 1);
        plan_key(&qs[0], level, plan_fingerprint(&SystemConfig::default()))
    }

    const Q6ISH: &str = "from lineitem | filter l_quantity < 24 \
                         | aggregate sum(l_extendedprice * l_discount) as revenue";

    #[test]
    fn whitespace_and_comments_do_not_change_the_key() {
        let reformatted = "from lineitem\n  | filter l_quantity < 24\n  \
                           # a comment\n  | aggregate sum(l_extendedprice * l_discount) as revenue";
        assert_eq!(key_of(Q6ISH, OptLevel::O2), key_of(reformatted, OptLevel::O2));
    }

    #[test]
    fn alias_and_query_name_renames_do_not_change_the_key() {
        let renamed = "query totally_different_name from lineitem | filter l_quantity < 24 \
                       | aggregate sum(l_extendedprice * l_discount) as rev2";
        assert_eq!(key_of(Q6ISH, OptLevel::O2), key_of(renamed, OptLevel::O2));
    }

    #[test]
    fn literals_ops_and_structure_change_the_key() {
        let base = key_of(Q6ISH, OptLevel::O2);
        for variant in [
            // literal changed
            "from lineitem | filter l_quantity < 25 \
             | aggregate sum(l_extendedprice * l_discount) as revenue",
            // operator changed
            "from lineitem | filter l_quantity <= 24 \
             | aggregate sum(l_extendedprice * l_discount) as revenue",
            // aggregate kind changed
            "from lineitem | filter l_quantity < 24 \
             | aggregate min(l_extendedprice * l_discount) as revenue",
            // attribute changed
            "from lineitem | filter l_linenumber < 24 \
             | aggregate sum(l_extendedprice * l_discount) as revenue",
            // extra aggregate
            "from lineitem | filter l_quantity < 24 \
             | aggregate sum(l_extendedprice * l_discount) as revenue, count() as n",
        ] {
            assert_ne!(base, key_of(variant, OptLevel::O2), "{variant}");
        }
    }

    #[test]
    fn opt_level_and_schema_fingerprint_change_the_key() {
        assert_ne!(key_of(Q6ISH, OptLevel::O0), key_of(Q6ISH, OptLevel::O2));

        let q = &parse_program(Q6ISH).unwrap()[0];
        let fp_default = plan_fingerprint(&SystemConfig::default());
        let narrow = SystemConfig {
            xbar_cols: 256,
            ..SystemConfig::default()
        };
        let fp_narrow = plan_fingerprint(&narrow);
        assert_ne!(fp_default, fp_narrow);
        assert_ne!(
            plan_key(q, OptLevel::O2, fp_default),
            plan_key(q, OptLevel::O2, fp_narrow)
        );
    }

    /// Cross-language golden pin: `python/apimirror.py` mirrors the
    /// canonical byte format and pins the same literal
    /// (`DEFAULT_FINGERPRINT`); a one-sided format change breaks exactly
    /// one of the two suites. Regenerate with
    /// `python -c "import apimirror; print(hex(apimirror.default_fingerprint()))"`
    /// and bump `FORMAT_VERSION` in both languages together.
    #[test]
    fn default_fingerprint_matches_the_python_mirror_pin() {
        assert_eq!(
            plan_fingerprint(&SystemConfig::default()),
            0xDD8B_B4AF_22C1_1FDB
        );
    }

    /// Same fixture as `golden_query()` in
    /// `python/tests/test_apimirror.py`: every predicate, expression and
    /// aggregate tag, hashed to the same pinned key by both languages.
    #[test]
    fn golden_key_matches_the_python_mirror_pin() {
        use crate::db::schema::RelId;
        use crate::query::ast::{Aggregate, QueryKind, RelQuery};
        let q = Query {
            name: "golden",
            kind: QueryKind::Full,
            rels: vec![RelQuery {
                rel: RelId::Lineitem,
                filter: Pred::And(vec![
                    Pred::CmpImm {
                        attr: "l_quantity",
                        op: CmpOp::Lt,
                        value: 24,
                    },
                    Pred::Between {
                        attr: "l_discount",
                        lo: 5,
                        hi: 7,
                    },
                    Pred::Not(Box::new(Pred::InSet {
                        attr: "l_shipmode",
                        values: vec![1, 3],
                    })),
                    Pred::Or(vec![
                        Pred::CmpCols {
                            a: "l_commitdate",
                            op: CmpOp::Lt,
                            b: "l_receiptdate",
                        },
                        Pred::True,
                    ]),
                ]),
                group_by: vec!["l_returnflag", "l_linestatus"],
                aggregates: vec![
                    Aggregate {
                        kind: AggKind::Count,
                        expr: ValExpr::One,
                        label: "n",
                    },
                    Aggregate {
                        kind: AggKind::Sum,
                        expr: ValExpr::MulComplement {
                            attr: "l_extendedprice",
                            scale: 100,
                            other: "l_discount",
                        },
                        label: "rev",
                    },
                    Aggregate {
                        kind: AggKind::Avg,
                        expr: ValExpr::Attr("l_quantity"),
                        label: "avg_q",
                    },
                    Aggregate {
                        kind: AggKind::Min,
                        expr: ValExpr::MulAttrs("l_quantity", "l_tax"),
                        label: "m1",
                    },
                    Aggregate {
                        kind: AggKind::Max,
                        expr: ValExpr::MulComplementSum {
                            attr: "l_extendedprice",
                            scale1: 100,
                            other1: "l_discount",
                            scale2: 100,
                            other2: "l_tax",
                        },
                        label: "m2",
                    },
                    Aggregate {
                        kind: AggKind::Sum,
                        expr: ValExpr::MulSum {
                            attr: "l_extendedprice",
                            scale: 100,
                            other: "l_tax",
                        },
                        label: "m3",
                    },
                ],
            }],
        };
        assert_eq!(
            plan_key(&q, OptLevel::O2, 0xDD8B_B4AF_22C1_1FDB),
            0xF468_1E94_59AE_97DE
        );
    }

    #[test]
    fn dml_keys_are_sensitive_and_disjoint_from_query_keys() {
        use crate::db::schema::RelId;
        let fp = plan_fingerprint(&SystemConfig::default());
        let del = Dml::Delete {
            rel: RelId::Lineitem,
            filter: Pred::CmpImm {
                attr: "l_quantity",
                op: CmpOp::Lt,
                value: 24,
            },
        };
        let base = dml_key(&del, fp);
        // literal, relation, kind and fingerprint all change the key
        let mut lit = del.clone();
        if let Dml::Delete {
            filter: Pred::CmpImm { value, .. },
            ..
        } = &mut lit
        {
            *value = 25;
        }
        assert_ne!(base, dml_key(&lit, fp));
        let other_rel = Dml::Delete {
            rel: RelId::Orders,
            filter: del.filter().clone(),
        };
        assert_ne!(base, dml_key(&other_rel, fp));
        let upd = Dml::Update {
            rel: RelId::Lineitem,
            filter: del.filter().clone(),
            sets: vec![("l_tax", 0)],
        };
        assert_ne!(base, dml_key(&upd, fp));
        assert_ne!(base, dml_key(&del, fp ^ 1));
        // set order matters (writes apply in order), insert values too
        let upd2 = Dml::Update {
            rel: RelId::Lineitem,
            filter: del.filter().clone(),
            sets: vec![("l_tax", 0), ("l_discount", 1)],
        };
        let upd3 = Dml::Update {
            rel: RelId::Lineitem,
            filter: del.filter().clone(),
            sets: vec![("l_discount", 1), ("l_tax", 0)],
        };
        assert_ne!(dml_key(&upd2, fp), dml_key(&upd3, fp));
        // the leading kind byte spaces (2/3/4 vs 0/1) keep DML bytes
        // disjoint from every query serialization
        let d_bytes = dml_bytes(&del, fp);
        let q = &parse_program(Q6ISH).unwrap()[0];
        let q_bytes = plan_bytes(q, OptLevel::O2, fp);
        assert_ne!(d_bytes, q_bytes);
        assert!(matches!(d_bytes[1], 2..=4));
        assert!(matches!(q_bytes[1], 0 | 1));
    }

    fn mk() -> Result<CachedPlan, PimdbError> {
        Ok(CachedPlan {
            compiled: vec![],
            sim: vec![],
            scans: vec![],
            opt: OptSummary::default(),
        })
    }

    #[test]
    fn cache_counts_hits_and_misses_and_clears() {
        let cache = PlanCache::new();
        cache.get_or_compile(vec![7], mk).unwrap();
        cache.get_or_compile(vec![7], mk).unwrap();
        cache.get_or_compile(vec![8], mk).unwrap();
        assert_eq!(
            cache.counters(),
            PlanCacheCounters { hits: 1, misses: 2 }
        );
        cache.clear();
        cache.get_or_compile(vec![7], mk).unwrap();
        assert_eq!(
            cache.counters(),
            PlanCacheCounters { hits: 1, misses: 3 }
        );
    }

    #[test]
    fn failed_compiles_are_not_cached_or_counted_as_misses() {
        let cache = PlanCache::new();
        let boom = || Err(PimdbError::UnknownQuery("nope".into()));
        assert!(cache.get_or_compile(vec![1], boom).is_err());
        assert_eq!(cache.counters(), PlanCacheCounters::default());
        // a later successful compile still lands
        cache.get_or_compile(vec![1], mk).unwrap();
        assert_eq!(
            cache.counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
    }

    #[test]
    fn cache_is_bounded_by_eviction() {
        let cache = PlanCache::new();
        for i in 0..(MAX_CACHED_PLANS + 50) {
            let key = (i as u64).to_le_bytes().to_vec();
            cache.get_or_compile(key, mk).unwrap();
        }
        assert!(cache.lock_plans().len() <= MAX_CACHED_PLANS);
        // evicted-then-reprepared templates recompile rather than error
        cache.get_or_compile(0u64.to_le_bytes().to_vec(), mk).unwrap();
    }
}
