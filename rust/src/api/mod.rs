//! The PIMDB embedding API: an owned, shareable database-service handle.
//!
//! The paper's host programming model treats PIM as a long-lived database
//! service: the PIM copy is constructed once, then many independent
//! queries execute against it (§4). This module is that model as a
//! library surface:
//!
//! * [`Pimdb::open`] takes *ownership* of a [`SystemConfig`] and a
//!   generated [`Database`], lays the relations out over the PIM modules,
//!   and returns a handle that is `Send + Sync` — wrap it in an
//!   [`std::sync::Arc`] and share it across threads.
//! * [`Pimdb::prepare`] turns a [`QuerySource`] (PQL text, an AST
//!   [`Query`], or a TPC-H query name) into a [`Prepared`] statement:
//!   parse → compile → optimize runs **once**, and the compiled plan is
//!   stored in a plan cache keyed by a canonical AST hash
//!   ([`cache::plan_key`]) so re-preparing the same query template —
//!   reformatted, renamed, or re-aliased — is a cache hit. Hit/miss
//!   counters surface in [`QueryMetrics::plan_cache`].
//! * [`Prepared::execute`] runs the plan over the shared shard pool from
//!   `&self`: independent prepared queries submit concurrently without
//!   external `&mut` serialization (per-relation locks serialize exactly
//!   the queries that share a relation's crossbar compute area, the same
//!   rule the wave scheduler applies). Results come back as a
//!   [`QueryResult`] whose [`Rows`] cursor *decodes* the schema encodings
//!   — dates, money cents, dictionary strings — instead of exposing raw
//!   engine outputs.
//!
//! Every fallible path returns the crate-wide typed
//! [`PimdbError`](crate::error::PimdbError).
//!
//! ```
//! use pimdb::api::Pimdb;
//! use pimdb::config::SystemConfig;
//! use pimdb::db::dbgen::Database;
//!
//! let db = Pimdb::open(SystemConfig::default(), Database::generate(0.001, 42))?;
//! let q6 = db.prepare(
//!     "from lineitem
//!      | filter (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01))
//!          and l_discount between 0.05..0.07 and l_quantity < 24
//!      | aggregate sum(l_extendedprice * l_discount) as revenue_x100",
//! )?;
//! let result = q6.execute()?;
//! for row in result.rows() {
//!     println!("revenue = {}", row.get("revenue_x100").unwrap());
//! }
//! // preparing the same template again (any formatting) hits the cache
//! let again = db.prepare("from lineitem | filter (l_shipdate >= date(1994-01-01)
//!      and l_shipdate < date(1995-01-01)) and l_discount between 0.05..0.07
//!      and l_quantity < 24 | aggregate sum(l_extendedprice*l_discount) as rev")?;
//! assert_eq!(db.plan_cache_counters().hits, 1);
//! # let _ = again;
//! # Ok::<(), pimdb::error::PimdbError>(())
//! ```

pub mod cache;
pub mod rows;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::SystemConfig;
use crate::db::dbgen::Database;
use crate::db::layout::DbLayout;
use crate::db::schema::{RelId, PIM_RELATIONS};
use crate::error::PimdbError;
use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::exec::metrics::{PlanCacheCounters, QueryMetrics, RunReport};
use crate::exec::pimdb as session;
use crate::exec::plan::{self, ExecPlan};
use crate::query::ast::Query;
use crate::query::compiler::{CompileError, Compiler};
use crate::query::lang;
use crate::query::opt::{self, OptStats};
use crate::query::tpch;

use cache::{CachedPlan, PlanCache};

pub use crate::exec::pimdb::EngineKind;
pub use rows::{Row, Rows, Value};

/// Where a query to [`Pimdb::prepare`] comes from.
#[derive(Clone, Copy, Debug)]
pub enum QuerySource<'a> {
    /// PQL text (see the grammar in [`crate::query::lang`]).
    Pql(&'a str),
    /// An already-built AST query (cloned into the prepared statement).
    Ast(&'a Query),
    /// One of the 19 evaluated TPC-H queries by name (e.g. `"Q6"`).
    Tpch(&'a str),
}

impl<'a> From<&'a str> for QuerySource<'a> {
    /// Bare strings are PQL text.
    fn from(s: &'a str) -> QuerySource<'a> {
        QuerySource::Pql(s)
    }
}

impl<'a> From<&'a Query> for QuerySource<'a> {
    fn from(q: &'a Query) -> QuerySource<'a> {
        QuerySource::Ast(q)
    }
}

/// The owned PIMDB service handle: one resident database copy, a plan
/// cache, and per-relation crossbar states behind locks so prepared
/// queries execute concurrently from `&self` (see the module docs).
pub struct Pimdb {
    cfg: SystemConfig,
    db: Database,
    layout: DbLayout,
    exec_plan: ExecPlan,
    fingerprint: u64,
    /// Functional crossbar states, lazily materialized per relation. The
    /// mutex is the concurrency rule of the wave scheduler in lock form:
    /// queries on disjoint relations proceed in parallel, queries sharing
    /// a relation serialize (they share its compute area).
    states: BTreeMap<RelId, Mutex<Option<Vec<XbarState>>>>,
    cache: PlanCache,
}

// The service-handle contract: `Pimdb` (and everything borrowed from it)
// must stay shareable across threads. Compile-time regression guard for
// the old `PimSession<'a>`-style borrow/`&mut` coupling.
const fn assert_send_sync<T: Send + Sync>() {}
const _: () = {
    assert_send_sync::<Pimdb>();
    assert_send_sync::<Prepared<'static>>();
    assert_send_sync::<QueryResult>();
};

impl Pimdb {
    /// Take ownership of a configuration and database, lay the relations
    /// out over the PIM modules, and return the service handle. Crossbar
    /// states materialize lazily, per relation, on first execution.
    pub fn open(cfg: SystemConfig, db: Database) -> Result<Pimdb, PimdbError> {
        let layout = DbLayout::build(&cfg, &|r| db.rel(r).records as u64)?;
        let states = PIM_RELATIONS
            .iter()
            .map(|&r| (r, Mutex::new(None)))
            .collect();
        Ok(Pimdb {
            exec_plan: ExecPlan::for_config(&cfg),
            fingerprint: cache::plan_fingerprint(&cfg),
            layout,
            states,
            cache: PlanCache::new(),
            cfg,
            db,
        })
    }

    /// The configuration the handle was opened with.
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The resident database (for baselines and oracles).
    pub fn database(&self) -> &Database {
        &self.db
    }

    /// The database's PIM layout (page placement, column slots).
    pub fn layout(&self) -> &DbLayout {
        &self.layout
    }

    /// Plan-cache hit/miss counters so far (also snapshotted into every
    /// execution's [`QueryMetrics::plan_cache`]).
    pub fn plan_cache_counters(&self) -> PlanCacheCounters {
        self.cache.counters()
    }

    /// Drop all cached plans (counters keep accumulating); the next
    /// prepare of any template recompiles. Benchmarks use this to measure
    /// the unprepared path.
    pub fn clear_plan_cache(&self) {
        self.cache.clear()
    }

    /// Prepare one query: parse (if text), compile and optimize once —
    /// or fetch the plan from the cache — and return the executable
    /// statement. A PQL program with several `query` blocks is an
    /// [`PimdbError::ExpectedSingleQuery`] error; use
    /// [`Pimdb::prepare_all`] for programs.
    pub fn prepare<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Prepared<'_>, PimdbError> {
        let mut queries = self.resolve(source.into())?;
        if queries.len() != 1 {
            return Err(PimdbError::ExpectedSingleQuery {
                found: queries.len(),
            });
        }
        self.prepare_query(queries.pop().expect("length checked"))
    }

    /// Prepare every query of a source (a PQL program may hold several
    /// `query` blocks), in source order.
    pub fn prepare_all<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Vec<Prepared<'_>>, PimdbError> {
        self.resolve(source.into())?
            .into_iter()
            .map(|q| self.prepare_query(q))
            .collect()
    }

    fn resolve(&self, source: QuerySource<'_>) -> Result<Vec<Query>, PimdbError> {
        match source {
            QuerySource::Pql(text) => {
                lang::parse_program(text).map_err(|diag| PimdbError::Parse {
                    diag,
                    src: text.to_string(),
                })
            }
            QuerySource::Ast(q) => Ok(vec![q.clone()]),
            QuerySource::Tpch(name) => tpch::query(name)
                .map(|q| vec![q])
                .ok_or_else(|| PimdbError::UnknownQuery(name.to_string())),
        }
    }

    fn prepare_query(&self, query: Query) -> Result<Prepared<'_>, PimdbError> {
        // the cache map keys on the full canonical bytes (collision-free);
        // plan_key is the same stream's compact digest for observability
        let key = cache::plan_bytes(&query, self.cfg.opt_level, self.fingerprint);
        let plan = self.cache.get_or_compile(key, || {
            let mut sum = OptStats::default();
            let compiled = query
                .rels
                .iter()
                .map(|rq| {
                    let c = Compiler::compile(rq, self.layout.rel(rq.rel), self.cfg.xbar_cols)?;
                    let (o, st) = opt::optimize(&c, self.cfg.opt_level, self.cfg.xbar_rows);
                    sum.merge(&st);
                    Ok(o)
                })
                .collect::<Result<Vec<_>, CompileError>>()?;
            Ok(CachedPlan {
                compiled,
                opt: sum.into(),
            })
        })?;
        let plan = rebind_labels(plan, &query);
        Ok(Prepared {
            handle: self,
            query,
            plan,
        })
    }

    /// Execute a prepared statement (see [`Prepared::execute`]).
    fn execute_prepared(
        &self,
        p: &Prepared<'_>,
        engine_kind: EngineKind,
    ) -> Result<QueryResult, PimdbError> {
        let compiled = &p.plan.compiled;

        // Lock every touched relation in canonical RelId order: concurrent
        // queries acquiring overlapping sets cannot deadlock, and queries
        // on disjoint sets never contend.
        let rels: BTreeSet<RelId> = compiled.iter().map(|c| c.rel).collect();
        let mut guards: Vec<(RelId, MutexGuard<'_, Option<Vec<XbarState>>>)> = rels
            .iter()
            .map(|r| {
                let mutex = self.states.get(r).expect("PIM relation");
                let guard = match mutex.lock() {
                    Ok(g) => g,
                    Err(poisoned) => {
                        // a panicked execution may have left a dirty
                        // compute area behind: drop the states so they
                        // reload clean below, and clear the poison flag
                        // so later executions pay the reload only once
                        mutex.clear_poison();
                        let mut g = poisoned.into_inner();
                        *g = None;
                        g
                    }
                };
                (*r, guard)
            })
            .collect();

        // materialize every touched relation once (lazy, like PimSession)
        for (r, guard) in guards.iter_mut() {
            if guard.is_none() {
                let rel = self.db.rel(*r);
                **guard = Some(engine::load_states(
                    rel,
                    self.layout.rel(*r),
                    self.cfg.xbar_cols,
                    0..rel.records,
                ));
            }
        }

        // One sharded run per program. Programs are sequential within the
        // query (two programs of one query on the same relation share its
        // compute area — the wave scheduler's duplicate rule); each run
        // still fans out over the shard pool. States move out of the
        // guard for the duration so a backend error drops them rather
        // than leaving a half-mutated compute area resident.
        let mut outs: Vec<ExecOutputs> = Vec::with_capacity(compiled.len());
        for c in compiled {
            let guard = &mut guards
                .iter_mut()
                .find(|(r, _)| *r == c.rel)
                .expect("locked above")
                .1;
            let mut states = guard.take().expect("materialized above");
            let out = plan::exec_steps_sharded(
                &mut states,
                &c.steps,
                c.mask_col,
                engine_kind,
                &self.exec_plan,
            )?;
            session::clear_compute(&mut states, self.layout.rel(c.rel).compute_base);
            **guard = Some(states);
            outs.push(out);
        }

        let output = session::assemble_output(&p.query, compiled, &outs);
        let mut metrics = session::simulate(&self.cfg, &p.query, compiled, &self.layout);
        metrics.inter_cells = compiled
            .iter()
            .map(|c| c.peak_inter_cells)
            .max()
            .unwrap_or(0);
        metrics.opt = p.plan.opt;
        metrics.plan_cache = self.cache.counters();
        Ok(QueryResult::new(
            p.query.clone(),
            RunReport {
                query: p.query.name,
                metrics,
                output,
            },
        ))
    }
}

/// Rebind aggregate output labels of a cached plan to the labels of the
/// *prepared* query. The cache key is alias-insensitive, so a hit may
/// carry the labels of whichever alias-variant compiled first; the
/// compiler emits exactly one [`crate::query::compiler::OutputSpec`] per
/// `(group, aggregate)` in aggregate order, which makes the rebinding a
/// positional rewrite. Returns the input `Arc` untouched when the labels
/// already match (the common case).
fn rebind_labels(plan: Arc<CachedPlan>, query: &Query) -> Arc<CachedPlan> {
    let matches = plan.compiled.iter().zip(&query.rels).all(|(c, rq)| {
        let n = rq.aggregates.len();
        n == 0
            || c.outputs
                .iter()
                .enumerate()
                .all(|(j, s)| s.label == rq.aggregates[j % n].label)
    });
    if matches {
        return plan;
    }
    let compiled = plan
        .compiled
        .iter()
        .zip(&query.rels)
        .map(|(c, rq)| {
            let mut c = c.clone();
            let n = rq.aggregates.len();
            if n > 0 {
                for (j, spec) in c.outputs.iter_mut().enumerate() {
                    debug_assert_eq!(spec.kind, rq.aggregates[j % n].kind);
                    spec.label = rq.aggregates[j % n].label;
                }
            }
            c
        })
        .collect();
    Arc::new(CachedPlan {
        compiled,
        opt: plan.opt,
    })
}

/// A prepared statement: the parsed query plus its compiled, optimized
/// plan (shared with the handle's plan cache). Executing takes `&self` —
/// the same statement can run concurrently from several threads, and
/// distinct statements on disjoint relations run in parallel.
pub struct Prepared<'db> {
    handle: &'db Pimdb,
    query: Query,
    plan: Arc<CachedPlan>,
}

impl Prepared<'_> {
    /// The query this statement executes.
    pub fn query(&self) -> &Query {
        &self.query
    }

    /// Execute on the native functional backend.
    pub fn execute(&self) -> Result<QueryResult, PimdbError> {
        self.execute_on(EngineKind::Native)
    }

    /// Execute on an explicit functional backend.
    pub fn execute_on(&self, engine_kind: EngineKind) -> Result<QueryResult, PimdbError> {
        self.handle.execute_prepared(self, engine_kind)
    }
}

/// One execution's result: decoded, typed rows plus the full simulated
/// metric set.
pub struct QueryResult {
    report: RunReport,
    rows: Vec<Row>,
}

impl QueryResult {
    fn new(query: Query, report: RunReport) -> QueryResult {
        let rows = rows::decode_rows(&query, &report.output);
        QueryResult { report, rows }
    }

    /// Name of the executed query.
    pub fn query_name(&self) -> &'static str {
        self.report.query
    }

    /// Cursor over the decoded result rows: one row per group for full
    /// queries, one `(relation, selected)` row per relation for
    /// filter-only queries.
    pub fn rows(&self) -> Rows<'_> {
        Rows::new(&self.rows)
    }

    /// The simulated timing/energy/power/endurance metrics, including the
    /// plan-cache counters at execution time.
    pub fn metrics(&self) -> &QueryMetrics {
        &self.report.metrics
    }

    /// The raw engine report (encoded outputs, paper-report shape). The
    /// escape hatch for the report generators and the differential suite;
    /// prefer [`QueryResult::rows`] for consuming results.
    pub fn raw_report(&self) -> &RunReport {
        &self.report
    }

    /// Consume the result into the raw engine report.
    pub fn into_report(self) -> RunReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pimdb::PimSession;

    fn db() -> Database {
        Database::generate(0.001, 11)
    }

    #[test]
    fn open_prepare_execute_matches_the_legacy_session() {
        let cfg = SystemConfig::default();
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let handle = Pimdb::open(cfg.clone(), db()).unwrap();
        for name in ["Q6", "Q1", "Q12"] {
            let q = tpch::query(name).unwrap();
            let want = legacy.run_query(&q, EngineKind::Native).unwrap();
            let got = handle.prepare(QuerySource::Tpch(name)).unwrap().execute().unwrap();
            assert_eq!(want.output, got.raw_report().output, "{name}");
            assert_eq!(
                want.metrics.cycles,
                got.metrics().cycles,
                "{name}"
            );
            assert_eq!(
                want.metrics.exec_time_s.to_bits(),
                got.metrics().exec_time_s.to_bits(),
                "{name}"
            );
        }
    }

    #[test]
    fn preparing_twice_compiles_once() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let src = "from supplier | filter s_suppkey < 50 | aggregate count() as n";
        let p1 = handle.prepare(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
        // reformatted + re-aliased: same template, cache hit
        let p2 = handle
            .prepare("from supplier\n  | filter s_suppkey < 50\n  | aggregate count() as how_many")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        let r1 = p1.execute().unwrap();
        let r2 = p2.execute().unwrap();
        // the rebound alias shows up in the typed rows of the hit
        assert!(r1.rows().row(0).unwrap().get("n").is_some());
        assert!(r2.rows().row(0).unwrap().get("how_many").is_some());
        assert_eq!(
            r1.rows().row(0).unwrap().get("n"),
            r2.rows().row(0).unwrap().get("how_many")
        );
        // counters surface in the metrics
        assert_eq!(
            r2.metrics().plan_cache,
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        // a literal change misses
        handle
            .prepare("from supplier | filter s_suppkey < 51 | aggregate count() as n")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 2 }
        );
    }

    #[test]
    fn prepare_rejects_multi_block_programs_and_unknown_names() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let program = "query a from part | filter true ; query b from supplier | filter true";
        match handle.prepare(program) {
            Err(PimdbError::ExpectedSingleQuery { found }) => assert_eq!(found, 2),
            other => panic!("expected ExpectedSingleQuery, got {:?}", other.map(|_| ())),
        }
        assert_eq!(handle.prepare_all(program).unwrap().len(), 2);
        assert!(matches!(
            handle.prepare(QuerySource::Tpch("Q99")),
            Err(PimdbError::UnknownQuery(_))
        ));
        assert!(matches!(
            handle.prepare("from lineitem | filter nope < 3"),
            Err(PimdbError::Parse { .. })
        ));
    }

    #[test]
    fn concurrent_execution_from_shared_reference() {
        let cfg = SystemConfig {
            parallelism: 2,
            ..SystemConfig::default()
        };
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let want_q6 = legacy
            .run_query(&tpch::query("Q6").unwrap(), EngineKind::Native)
            .unwrap();
        let want_q11 = legacy
            .run_query(&tpch::query("Q11").unwrap(), EngineKind::Native)
            .unwrap();

        let handle = Arc::new(Pimdb::open(cfg.clone(), db()).unwrap());
        let q6 = handle.prepare(QuerySource::Tpch("Q6")).unwrap();
        let q11 = handle.prepare(QuerySource::Tpch("Q11")).unwrap();
        std::thread::scope(|s| {
            let t6 = s.spawn(|| q6.execute().unwrap());
            let t11 = s.spawn(|| q11.execute().unwrap());
            let r6 = t6.join().unwrap();
            let r11 = t11.join().unwrap();
            assert_eq!(r6.raw_report().output, want_q6.output);
            assert_eq!(r11.raw_report().output, want_q11.output);
            assert_eq!(
                r6.metrics().exec_time_s.to_bits(),
                want_q6.metrics.exec_time_s.to_bits()
            );
        });
        // re-executing after the concurrent burst still matches
        let again = q6.execute().unwrap();
        assert_eq!(again.raw_report().output, want_q6.output);
    }
}
