//! The PIMDB embedding API: an owned, shareable database-service handle.
//!
//! The paper's host programming model treats PIM as a long-lived database
//! service: the PIM copy is constructed once, then many independent
//! queries execute against it (§4). This module is that model as a
//! library surface:
//!
//! * [`Pimdb::open`] takes *ownership* of a [`SystemConfig`] and a
//!   generated [`Database`], lays the relations out over the PIM modules,
//!   and returns a handle that is `Send + Sync` — wrap it in an
//!   [`std::sync::Arc`] and share it across threads.
//! * [`Pimdb::prepare`] turns a [`QuerySource`] (PQL text, an AST
//!   [`Query`], or a TPC-H query name) into a [`Prepared`] statement:
//!   parse → compile → optimize runs **once**, and the compiled plan is
//!   stored in a plan cache keyed by a canonical AST hash
//!   ([`cache::plan_key`]) so re-preparing the same query template —
//!   reformatted, renamed, or re-aliased — is a cache hit. Hit/miss
//!   counters surface in [`QueryMetrics::plan_cache`].
//! * [`Prepared::execute`] runs the plan over the shared shard pool from
//!   `&self`: independent prepared queries submit concurrently without
//!   external `&mut` serialization (per-relation locks serialize exactly
//!   the queries that share a relation's crossbar compute area, the same
//!   rule the wave scheduler applies). Results come back as a
//!   [`QueryResult`] whose [`Rows`] cursor *decodes* the schema encodings
//!   — dates, money cents, dictionary strings — instead of exposing raw
//!   engine outputs.
//!
//! Every fallible path returns the crate-wide typed
//! [`PimdbError`](crate::error::PimdbError).
//!
//! ```
//! use pimdb::api::Pimdb;
//! use pimdb::config::SystemConfig;
//! use pimdb::db::dbgen::Database;
//!
//! let db = Pimdb::open(SystemConfig::default(), Database::generate(0.001, 42))?;
//! let q6 = db.prepare(
//!     "from lineitem
//!      | filter (l_shipdate >= date(1994-01-01) and l_shipdate < date(1995-01-01))
//!          and l_discount between 0.05..0.07 and l_quantity < 24
//!      | aggregate sum(l_extendedprice * l_discount) as revenue_x100",
//! )?;
//! let result = q6.execute()?;
//! for row in result.rows() {
//!     println!("revenue = {}", row.get("revenue_x100").unwrap());
//! }
//! // preparing the same template again (any formatting) hits the cache
//! let again = db.prepare("from lineitem | filter (l_shipdate >= date(1994-01-01)
//!      and l_shipdate < date(1995-01-01)) and l_discount between 0.05..0.07
//!      and l_quantity < 24 | aggregate sum(l_extendedprice*l_discount) as rev")?;
//! assert_eq!(db.plan_cache_counters().hits, 1);
//! # let _ = again;
//! # Ok::<(), pimdb::error::PimdbError>(())
//! ```

pub mod cache;
pub mod rows;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::config::SystemConfig;
use crate::db::dbgen::Database;
use crate::db::freerows::FreeRowMap;
use crate::db::layout::DbLayout;
use crate::db::schema::{RelId, PIM_RELATIONS};
use crate::error::PimdbError;
use crate::exec::engine::{self, ExecOutputs, XbarState};
use crate::exec::metrics::{PlanCacheCounters, QueryMetrics, RunReport, SharedScanCounters};
use crate::exec::pimdb as session;
use crate::exec::plan::{self, ExecPlan};
use crate::query::ast::{Dml, Query};
use crate::query::compiler::{compile_dml, CompileError, Compiler};
use crate::query::lang;
use crate::query::opt::sharedscan;
use crate::query::opt::{self, OptStats};
use crate::query::tpch;
use crate::util::bits::{WORDS, XBAR_ROWS};

use cache::{CachedDmlPlan, CachedPlan, PlanCache};

pub use crate::exec::metrics::DmlResult;
pub use crate::exec::pimdb::EngineKind;
pub use rows::{Row, Rows, Value};

/// Where a query to [`Pimdb::prepare`] comes from.
#[derive(Clone, Copy, Debug)]
pub enum QuerySource<'a> {
    /// PQL text (see the grammar in [`crate::query::lang`]).
    Pql(&'a str),
    /// An already-built AST query (cloned into the prepared statement).
    Ast(&'a Query),
    /// One of the 19 evaluated TPC-H queries by name (e.g. `"Q6"`).
    Tpch(&'a str),
}

impl<'a> From<&'a str> for QuerySource<'a> {
    /// Bare strings are PQL text.
    fn from(s: &'a str) -> QuerySource<'a> {
        QuerySource::Pql(s)
    }
}

impl<'a> From<&'a Query> for QuerySource<'a> {
    fn from(q: &'a Query) -> QuerySource<'a> {
        QuerySource::Ast(q)
    }
}

/// Where a DML statement to [`Pimdb::execute_dml`] comes from.
#[derive(Clone, Copy, Debug)]
pub enum DmlSource<'a> {
    /// PQL DML text (`insert into ...` / `update ... set ...` /
    /// `delete from ...`).
    Pql(&'a str),
    /// An already-built AST statement (cloned into the prepared form).
    Ast(&'a Dml),
}

impl<'a> From<&'a str> for DmlSource<'a> {
    /// Bare strings are PQL DML text.
    fn from(s: &'a str) -> DmlSource<'a> {
        DmlSource::Pql(s)
    }
}

impl<'a> From<&'a Dml> for DmlSource<'a> {
    fn from(d: &'a Dml) -> DmlSource<'a> {
        DmlSource::Ast(d)
    }
}

/// Per-relation mutable state behind the relation lock: the functional
/// crossbar states plus — once a DML statement touches the relation —
/// the free-row map (liveness + monotone per-row wear counters).
struct RelState {
    /// Lazily materialized crossbar states.
    states: Option<Vec<XbarState>>,
    /// Liveness + wear, created on the first mutation.
    freerows: Option<FreeRowMap>,
    /// Set once DML has mutated the relation: poison recovery must scrub
    /// the compute area in place instead of dropping the states back to
    /// the pristine load image (which would silently revert the DML).
    mutated: bool,
    /// Shared-scan mask cache: canonical prefix key -> mask planes (one
    /// per crossbar). Lives behind the relation lock with the states it
    /// describes; dropped whenever DML mutates the relation.
    scan_cache: ScanMaskCache,
}

/// Bound on cached scan masks per relation: a serving workload with
/// per-request literals mints unbounded distinct prefixes; past the cap
/// the oldest entry is evicted (FIFO — prefix reuse in a prepared
/// workload is dominated by a handful of hot scans).
const MAX_CACHED_SCANS: usize = 8;

/// Per-relation store of executed filter-prefix results, keyed by the
/// canonical prefix bytes of [`sharedscan::ScanInfo`]. Byte equality of
/// keys implies the identical mask function, so replaying a cached mask
/// is exact, not approximate.
struct ScanMaskCache {
    entries: Vec<(Vec<u8>, Vec<[u64; WORDS]>)>,
}

impl ScanMaskCache {
    fn new() -> ScanMaskCache {
        ScanMaskCache {
            entries: Vec::new(),
        }
    }

    fn get(&self, key: &[u8]) -> Option<&Vec<[u64; WORDS]>> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, m)| m)
    }

    fn insert(&mut self, key: Vec<u8>, mask: Vec<[u64; WORDS]>) {
        if let Some(e) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            e.1 = mask;
            return;
        }
        if self.entries.len() >= MAX_CACHED_SCANS {
            self.entries.remove(0);
        }
        self.entries.push((key, mask));
    }

    /// Drop every cached mask; `true` when anything was resident.
    fn clear(&mut self) -> bool {
        let had = !self.entries.is_empty();
        self.entries.clear();
        had
    }
}

/// Handle-wide shared-scan counters (atomic: executions run from
/// `&self` across threads).
#[derive(Default)]
struct ScanStats {
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

/// The owned PIMDB service handle: one resident database copy, a plan
/// cache, and per-relation crossbar states behind locks so prepared
/// queries execute concurrently from `&self` (see the module docs).
///
/// Since the DML refactor the handle is also the *mutable* surface:
/// [`Pimdb::execute_dml`] applies `insert into` / `update ... set` /
/// `delete from` statements to the resident PIM copy — valid-bit
/// liveness, endurance-aware free-row allocation, wear accounting —
/// while queries keep executing against the mutated data (every filter
/// ANDs the VALID column, so deleted rows are invisible to every
/// filter and aggregate).
pub struct Pimdb {
    cfg: SystemConfig,
    db: Database,
    layout: DbLayout,
    exec_plan: ExecPlan,
    fingerprint: u64,
    /// Per-relation mutable state. The mutex is the concurrency rule of
    /// the wave scheduler in lock form: statements on disjoint relations
    /// proceed in parallel, statements sharing a relation serialize
    /// (they share its compute area — and now also its liveness).
    states: BTreeMap<RelId, Mutex<RelState>>,
    cache: PlanCache,
    scan_stats: ScanStats,
}

// The service-handle contract: `Pimdb` (and everything borrowed from it)
// must stay shareable across threads. Compile-time regression guard for
// the old `PimSession<'a>`-style borrow/`&mut` coupling.
const fn assert_send_sync<T: Send + Sync>() {}
const _: () = {
    assert_send_sync::<Pimdb>();
    assert_send_sync::<Prepared<'static>>();
    assert_send_sync::<PreparedDml<'static>>();
    assert_send_sync::<QueryResult>();
};

impl Pimdb {
    /// Take ownership of a configuration and database, lay the relations
    /// out over the PIM modules, and return the service handle. Crossbar
    /// states materialize lazily, per relation, on first execution.
    pub fn open(cfg: SystemConfig, db: Database) -> Result<Pimdb, PimdbError> {
        let layout = DbLayout::build(&cfg, &|r| db.rel(r).records as u64)?;
        let states = PIM_RELATIONS
            .iter()
            .map(|&r| {
                (
                    r,
                    Mutex::new(RelState {
                        states: None,
                        freerows: None,
                        mutated: false,
                        scan_cache: ScanMaskCache::new(),
                    }),
                )
            })
            .collect();
        Ok(Pimdb {
            exec_plan: ExecPlan::for_config(&cfg),
            fingerprint: cache::plan_fingerprint(&cfg),
            layout,
            states,
            cache: PlanCache::new(),
            scan_stats: ScanStats::default(),
            cfg,
            db,
        })
    }

    /// The configuration the handle was opened with.
    pub fn cfg(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The resident database *load image* (for baselines and oracles).
    /// DML mutates the PIM copy, not this image — hold your own
    /// [`Database`] copy and mirror statements through
    /// [`crate::exec::baseline::apply_dml`] when a host-side twin of the
    /// mutated state is needed (the differential suites do exactly that).
    pub fn database(&self) -> &Database {
        &self.db
    }

    /// Live records currently in the PIM copy of `rel` (the load image's
    /// live count until a DML statement touches the relation).
    pub fn live_records(&self, rel: RelId) -> usize {
        let guard = self.lock_rel(rel);
        guard
            .freerows
            .as_ref()
            .map(|f| f.live_count())
            .unwrap_or_else(|| self.db.rel(rel).live_count())
    }

    /// Per-row cumulative cell-write counters of `rel` (monotonically
    /// nondecreasing; empty until a DML statement touches the relation
    /// — wear accounting starts with the first mutation).
    pub fn wear_counters(&self, rel: RelId) -> Vec<u64> {
        let guard = self.lock_rel(rel);
        guard
            .freerows
            .as_ref()
            .map(|f| (0..f.capacity()).map(|r| f.row_wear(r)).collect())
            .unwrap_or_default()
    }

    /// The database's PIM layout (page placement, column slots).
    pub fn layout(&self) -> &DbLayout {
        &self.layout
    }

    /// Plan-cache hit/miss counters so far (also snapshotted into every
    /// execution's [`QueryMetrics::plan_cache`]).
    pub fn plan_cache_counters(&self) -> PlanCacheCounters {
        self.cache.counters()
    }

    /// Shared-scan cache counters so far: executions that replayed a
    /// cached filter-prefix mask (`hits`), shareable executions that ran
    /// in full and populated the cache (`misses`), and per-relation cache
    /// drops (`invalidations` — DML mutation or poison recovery).
    pub fn shared_scan_counters(&self) -> SharedScanCounters {
        SharedScanCounters {
            hits: self.scan_stats.hits.load(Ordering::Relaxed),
            misses: self.scan_stats.misses.load(Ordering::Relaxed),
            invalidations: self.scan_stats.invalidations.load(Ordering::Relaxed),
        }
    }

    /// Drop all cached plans (counters keep accumulating); the next
    /// prepare of any template recompiles. Benchmarks use this to measure
    /// the unprepared path.
    pub fn clear_plan_cache(&self) {
        self.cache.clear()
    }

    /// Prepare one query: parse (if text), compile and optimize once —
    /// or fetch the plan from the cache — and return the executable
    /// statement. A PQL program with several `query` blocks is an
    /// [`PimdbError::ExpectedSingleQuery`] error; use
    /// [`Pimdb::prepare_all`] for programs.
    pub fn prepare<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Prepared<'_>, PimdbError> {
        let mut queries = self.resolve(source.into())?;
        if queries.len() != 1 {
            return Err(PimdbError::ExpectedSingleQuery {
                found: queries.len(),
            });
        }
        self.prepare_query(queries.pop().expect("length checked"))
    }

    /// Prepare every query of a source (a PQL program may hold several
    /// `query` blocks), in source order.
    pub fn prepare_all<'q>(
        &self,
        source: impl Into<QuerySource<'q>>,
    ) -> Result<Vec<Prepared<'_>>, PimdbError> {
        self.resolve(source.into())?
            .into_iter()
            .map(|q| self.prepare_query(q))
            .collect()
    }

    fn resolve(&self, source: QuerySource<'_>) -> Result<Vec<Query>, PimdbError> {
        match source {
            QuerySource::Pql(text) => {
                lang::parse_program(text).map_err(|diag| PimdbError::Parse {
                    diag,
                    src: text.to_string(),
                })
            }
            QuerySource::Ast(q) => Ok(vec![q.clone()]),
            QuerySource::Tpch(name) => tpch::query(name)
                .map(|q| vec![q])
                .ok_or_else(|| PimdbError::UnknownQuery(name.to_string())),
        }
    }

    fn prepare_query(&self, query: Query) -> Result<Prepared<'_>, PimdbError> {
        // the cache map keys on the full canonical bytes (collision-free);
        // plan_key is the same stream's compact digest for observability
        let key = cache::plan_bytes(&query, self.cfg.opt_level, self.fingerprint);
        let plan = self.cache.get_or_compile(key, || {
            let mut sum = OptStats::default();
            let compiled = query
                .rels
                .iter()
                .map(|rq| {
                    let c = Compiler::compile(rq, self.layout.rel(rq.rel), self.cfg.xbar_cols)?;
                    let (o, st) = opt::optimize(&c, self.cfg.opt_level, self.cfg.xbar_rows);
                    sum.merge(&st);
                    Ok(o)
                })
                .collect::<Result<Vec<_>, CompileError>>()?;
            let scans = compiled.iter().map(sharedscan::scan_info).collect();
            Ok(CachedPlan {
                compiled,
                scans,
                opt: sum.into(),
            })
        })?;
        let plan = rebind_labels(plan, &query);
        Ok(Prepared {
            handle: self,
            query,
            plan,
        })
    }

    /// Lock one relation's state, recovering from poisoning. A panicked
    /// execution may have left a dirty compute area behind; a pristine
    /// relation reloads from the load image, while a DML-mutated one is
    /// scrubbed in place (reloading would silently revert the DML). If
    /// the panic struck while the states were checked out of the guard
    /// (mid-execution), a mutated relation's liveness map can no longer
    /// be trusted to match the arrays, so the relation reverts to the
    /// pristine load image — consistent, at the cost of the mutations.
    fn lock_rel(&self, rel: RelId) -> MutexGuard<'_, RelState> {
        let mutex = self.states.get(&rel).expect("PIM relation");
        match mutex.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                mutex.clear_poison();
                let mut g = poisoned.into_inner();
                if let (true, Some(states)) = (g.mutated, g.states.as_mut()) {
                    session::clear_compute(states, self.layout.rel(rel).compute_base);
                } else {
                    g.states = None;
                    g.freerows = None;
                    g.mutated = false;
                }
                // cached scan masks describe the pre-panic state; drop them
                if g.scan_cache.clear() {
                    self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
                }
                g
            }
        }
    }

    /// Materialize a relation's crossbar states from the load image.
    fn materialize(&self, rel: RelId, g: &mut RelState) {
        if g.states.is_none() {
            let r = self.db.rel(rel);
            g.states = Some(engine::load_states(
                r,
                self.layout.rel(rel),
                self.cfg.xbar_cols,
                0..r.records,
            ));
        }
    }

    /// Execute a prepared statement (see [`Prepared::execute`]).
    fn execute_prepared(
        &self,
        p: &Prepared<'_>,
        engine_kind: EngineKind,
    ) -> Result<QueryResult, PimdbError> {
        let compiled = &p.plan.compiled;

        // Lock every touched relation in canonical RelId order: concurrent
        // queries acquiring overlapping sets cannot deadlock, and queries
        // on disjoint sets never contend.
        let rels: BTreeSet<RelId> = compiled.iter().map(|c| c.rel).collect();
        let mut guards: Vec<(RelId, MutexGuard<'_, RelState>)> = rels
            .iter()
            .map(|r| (*r, self.lock_rel(*r)))
            .collect();

        // materialize every touched relation once (lazy, like PimSession)
        for (r, guard) in guards.iter_mut() {
            self.materialize(*r, guard);
        }

        // One sharded run per program. Programs are sequential within the
        // query (two programs of one query on the same relation share its
        // compute area — the wave scheduler's duplicate rule); each run
        // still fans out over the shard pool. States move out of the
        // guard for the duration so a backend error drops them rather
        // than leaving a half-mutated compute area resident.
        let mut outs: Vec<ExecOutputs> = Vec::with_capacity(compiled.len());
        for (c, scan) in compiled.iter().zip(&p.plan.scans) {
            let guard = &mut guards
                .iter_mut()
                .find(|(r, _)| *r == c.rel)
                .expect("locked above")
                .1;
            let mut states = guard.states.take().expect("materialized above");
            // Shared scan: when this program's filter prefix matches a
            // cached mask (byte-equal canonical key — identical mask
            // function), transplant the mask planes and run only the
            // suffix. The prefix writes nothing but compute columns and
            // the suffix never writes the mask column, so the replay is
            // bit-identical to the full run.
            let replayed = match scan {
                Some(info) => match guard.scan_cache.get(&info.key) {
                    Some(mask) if mask.len() == states.len() => {
                        for (st, m) in states.iter_mut().zip(mask) {
                            st.planes[c.mask_col] = *m;
                        }
                        true
                    }
                    _ => false,
                },
                None => false,
            };
            let steps = match scan {
                Some(info) if replayed => &c.steps[info.prefix_len..],
                _ => &c.steps[..],
            };
            let out = plan::exec_steps_sharded(
                &mut states,
                steps,
                c.mask_col,
                engine_kind,
                &self.exec_plan,
            );
            let out = match out {
                Ok(o) => o,
                Err(e) => {
                    // query steps only dirty the compute area, so a
                    // mutated relation keeps its (scrubbed) states — a
                    // pristine one simply reloads on next use
                    if guard.mutated {
                        session::clear_compute(
                            &mut states,
                            self.layout.rel(c.rel).compute_base,
                        );
                        guard.states = Some(states);
                    }
                    return Err(e.into());
                }
            };
            if let Some(info) = scan {
                if replayed {
                    self.scan_stats.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    // capture the mask planes before clear_compute wipes
                    // the compute area they live in
                    guard.scan_cache.insert(
                        info.key.clone(),
                        states.iter().map(|st| st.planes[c.mask_col]).collect(),
                    );
                    self.scan_stats.misses.fetch_add(1, Ordering::Relaxed);
                }
            }
            session::clear_compute(&mut states, self.layout.rel(c.rel).compute_base);
            guard.states = Some(states);
            // mutated relations accumulate this query's write profile
            // into the persistent wear counters the endurance-aware
            // row allocator consults; the wear model charges the full
            // program either way — the shared-scan replay is a simulator
            // shortcut, not a change to what the simulated device does
            if let Some(free) = guard.freerows.as_mut() {
                session::charge_wear(free, &c.steps, self.cfg.xbar_cols);
            }
            outs.push(out);
        }

        let output = session::assemble_output(&p.query, compiled, &outs);
        let mut metrics = session::simulate(&self.cfg, &p.query, compiled, &self.layout);
        metrics.inter_cells = compiled
            .iter()
            .map(|c| c.peak_inter_cells)
            .max()
            .unwrap_or(0);
        metrics.opt = p.plan.opt;
        metrics.plan_cache = self.cache.counters();
        Ok(QueryResult::new(
            p.query.clone(),
            RunReport {
                query: p.query.name,
                metrics,
                output,
            },
        ))
    }

    /// Prepare one DML statement: parse (if text) and compile once — or
    /// fetch the compiled form from the plan cache (canonical DML
    /// serialization keys, see [`cache::dml_key`]; prepared DML is
    /// cacheable exactly like prepared queries, and the schema
    /// fingerprint is shared) — and return the executable statement.
    pub fn prepare_dml<'q>(
        &self,
        source: impl Into<DmlSource<'q>>,
    ) -> Result<PreparedDml<'_>, PimdbError> {
        let dml = match source.into() {
            DmlSource::Pql(text) => {
                lang::parse_dml(text).map_err(|diag| PimdbError::Parse {
                    diag,
                    src: text.to_string(),
                })?
            }
            DmlSource::Ast(d) => d.clone(),
        };
        let rel = dml.rel();
        if !rel.in_pim() {
            // the PQL lowering rejects this with a spanned diagnostic;
            // AST-built statements get the typed error here instead of a
            // layout panic
            return Err(CompileError::NotPimResident { rel }.into());
        }
        let key = cache::dml_bytes(&dml, self.fingerprint);
        let plan = self.cache.get_or_compile_dml(key, || {
            Ok(CachedDmlPlan {
                compiled: compile_dml(&dml, self.layout.rel(rel), self.cfg.xbar_cols)?,
            })
        })?;
        Ok(PreparedDml {
            handle: self,
            dml,
            plan,
        })
    }

    /// Execute one DML statement against the resident PIM copy: INSERT
    /// writes the encoded record into the least-worn free row and sets
    /// its VALID bit; UPDATE filters (live rows only) and rewrites the
    /// SET attributes in place; DELETE filters and clears VALID (and the
    /// row data, keeping the all-zero-dead-row invariant the optimizer's
    /// zero-row reasoning relies on). Returns rows affected, the wear
    /// delta and the simulated application cost.
    ///
    /// ```
    /// use pimdb::api::Pimdb;
    /// use pimdb::config::SystemConfig;
    /// use pimdb::db::dbgen::Database;
    ///
    /// let db = Pimdb::open(SystemConfig::default(), Database::generate(0.001, 42))?;
    /// let del = db.execute_dml("delete from supplier where s_suppkey <= 3")?;
    /// assert_eq!(del.rows_affected, 3);
    /// let ins = db.execute_dml(
    ///     "insert into supplier (s_suppkey, s_nationkey, s_acctbal) \
    ///      values (10001, 7, 1000.00)",
    /// )?;
    /// assert_eq!(ins.rows_affected, 1);
    /// // deleted rows are invisible to every filter and aggregate
    /// let n = db.prepare("from supplier | filter s_suppkey <= 3 \
    ///                     | aggregate count() as n")?.execute()?;
    /// assert_eq!(n.rows().row(0).unwrap().get("n").unwrap().as_i64(), Some(0));
    /// # Ok::<(), pimdb::error::PimdbError>(())
    /// ```
    pub fn execute_dml<'q>(
        &self,
        source: impl Into<DmlSource<'q>>,
    ) -> Result<DmlResult, PimdbError> {
        self.prepare_dml(source)?.execute()
    }

    /// Execute a prepared DML statement (see [`PreparedDml::execute`]).
    fn execute_dml_prepared(
        &self,
        p: &PreparedDml<'_>,
        engine_kind: EngineKind,
    ) -> Result<DmlResult, PimdbError> {
        let rel = p.dml.rel();
        let mut guard = self.lock_rel(rel);
        self.materialize(rel, &mut guard);
        if guard.freerows.is_none() {
            // shadow the load image's liveness exactly — a DML-mutated
            // store reloads with dead slots between live ones
            let capacity = guard.states.as_ref().expect("materialized").len() * XBAR_ROWS;
            let r = self.db.rel(rel);
            let flags: Vec<bool> = (0..r.records).map(|i| r.live(i)).collect();
            guard.freerows = Some(FreeRowMap::from_flags(&flags, capacity, XBAR_ROWS));
        }
        guard.mutated = true;
        // any cached scan mask describes pre-mutation data
        if guard.scan_cache.clear() {
            self.scan_stats.invalidations.fetch_add(1, Ordering::Relaxed);
        }
        let mut states = guard.states.take().expect("materialized above");
        let out = session::exec_dml_on_states(
            &self.cfg,
            &self.layout,
            rel,
            &mut states,
            guard.freerows.as_mut().expect("created above"),
            &p.plan.compiled,
            engine_kind,
            &self.exec_plan,
        );
        if out.is_ok() {
            guard.states = Some(states);
        } else {
            // a failed backend may have torn the statement across shards,
            // leaving states and the liveness map out of sync: revert the
            // relation to the pristine load image (only reachable through
            // backend-runtime errors — the native engine is total)
            guard.states = None;
            guard.freerows = None;
            guard.mutated = false;
        }
        out
    }
}

/// Rebind aggregate output labels of a cached plan to the labels of the
/// *prepared* query. The cache key is alias-insensitive, so a hit may
/// carry the labels of whichever alias-variant compiled first; the
/// compiler emits exactly one [`crate::query::compiler::OutputSpec`] per
/// `(group, aggregate)` in aggregate order, which makes the rebinding a
/// positional rewrite. Returns the input `Arc` untouched when the labels
/// already match (the common case).
fn rebind_labels(plan: Arc<CachedPlan>, query: &Query) -> Arc<CachedPlan> {
    let matches = plan.compiled.iter().zip(&query.rels).all(|(c, rq)| {
        let n = rq.aggregates.len();
        n == 0
            || c.outputs
                .iter()
                .enumerate()
                .all(|(j, s)| s.label == rq.aggregates[j % n].label)
    });
    if matches {
        return plan;
    }
    let compiled = plan
        .compiled
        .iter()
        .zip(&query.rels)
        .map(|(c, rq)| {
            let mut c = c.clone();
            let n = rq.aggregates.len();
            if n > 0 {
                for (j, spec) in c.outputs.iter_mut().enumerate() {
                    debug_assert_eq!(spec.kind, rq.aggregates[j % n].kind);
                    spec.label = rq.aggregates[j % n].label;
                }
            }
            c
        })
        .collect();
    Arc::new(CachedPlan {
        compiled,
        scans: plan.scans.clone(),
        opt: plan.opt,
    })
}

/// A prepared statement: the parsed query plus its compiled, optimized
/// plan (shared with the handle's plan cache). Executing takes `&self` —
/// the same statement can run concurrently from several threads, and
/// distinct statements on disjoint relations run in parallel.
pub struct Prepared<'db> {
    handle: &'db Pimdb,
    query: Query,
    plan: Arc<CachedPlan>,
}

impl Prepared<'_> {
    /// The query this statement executes.
    pub fn query(&self) -> &Query {
        &self.query
    }

    /// Execute on the native functional backend.
    pub fn execute(&self) -> Result<QueryResult, PimdbError> {
        self.execute_on(EngineKind::Native)
    }

    /// Execute on an explicit functional backend.
    pub fn execute_on(&self, engine_kind: EngineKind) -> Result<QueryResult, PimdbError> {
        self.handle.execute_prepared(self, engine_kind)
    }
}

/// A prepared DML statement: the parsed statement plus its compiled form
/// (shared with the handle's plan cache). Executing takes `&self` and
/// serializes on the target relation's lock — concurrent queries on
/// other relations keep running, and queries on the same relation
/// observe either the pre- or post-statement state, never a torn one.
pub struct PreparedDml<'db> {
    handle: &'db Pimdb,
    dml: Dml,
    plan: Arc<CachedDmlPlan>,
}

impl PreparedDml<'_> {
    /// The statement this prepared form executes.
    pub fn dml(&self) -> &Dml {
        &self.dml
    }

    /// Execute on the native functional backend.
    pub fn execute(&self) -> Result<DmlResult, PimdbError> {
        self.execute_on(EngineKind::Native)
    }

    /// Execute on an explicit functional backend.
    pub fn execute_on(&self, engine_kind: EngineKind) -> Result<DmlResult, PimdbError> {
        self.handle.execute_dml_prepared(self, engine_kind)
    }
}

/// One execution's result: decoded, typed rows plus the full simulated
/// metric set.
pub struct QueryResult {
    report: RunReport,
    rows: Vec<Row>,
}

impl QueryResult {
    fn new(query: Query, report: RunReport) -> QueryResult {
        let rows = rows::decode_rows(&query, &report.output);
        QueryResult { report, rows }
    }

    /// Name of the executed query.
    pub fn query_name(&self) -> &'static str {
        self.report.query
    }

    /// Cursor over the decoded result rows: one row per group for full
    /// queries, one `(relation, selected)` row per relation for
    /// filter-only queries.
    pub fn rows(&self) -> Rows<'_> {
        Rows::new(&self.rows)
    }

    /// The simulated timing/energy/power/endurance metrics, including the
    /// plan-cache counters at execution time.
    pub fn metrics(&self) -> &QueryMetrics {
        &self.report.metrics
    }

    /// The raw engine report (encoded outputs, paper-report shape). The
    /// escape hatch for the report generators and the differential suite;
    /// prefer [`QueryResult::rows`] for consuming results.
    pub fn raw_report(&self) -> &RunReport {
        &self.report
    }

    /// Consume the result into the raw engine report.
    pub fn into_report(self) -> RunReport {
        self.report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::pimdb::PimSession;

    fn db() -> Database {
        Database::generate(0.001, 11)
    }

    #[test]
    fn open_prepare_execute_matches_the_legacy_session() {
        let cfg = SystemConfig::default();
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let handle = Pimdb::open(cfg.clone(), db()).unwrap();
        for name in ["Q6", "Q1", "Q12"] {
            let q = tpch::query(name).unwrap();
            let want = legacy.run_query(&q, EngineKind::Native).unwrap();
            let got = handle.prepare(QuerySource::Tpch(name)).unwrap().execute().unwrap();
            assert_eq!(want.output, got.raw_report().output, "{name}");
            assert_eq!(
                want.metrics.cycles,
                got.metrics().cycles,
                "{name}"
            );
            assert_eq!(
                want.metrics.exec_time_s.to_bits(),
                got.metrics().exec_time_s.to_bits(),
                "{name}"
            );
        }
    }

    #[test]
    fn preparing_twice_compiles_once() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let src = "from supplier | filter s_suppkey < 50 | aggregate count() as n";
        let p1 = handle.prepare(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
        // reformatted + re-aliased: same template, cache hit
        let p2 = handle
            .prepare("from supplier\n  | filter s_suppkey < 50\n  | aggregate count() as how_many")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        let r1 = p1.execute().unwrap();
        let r2 = p2.execute().unwrap();
        // the rebound alias shows up in the typed rows of the hit
        assert!(r1.rows().row(0).unwrap().get("n").is_some());
        assert!(r2.rows().row(0).unwrap().get("how_many").is_some());
        assert_eq!(
            r1.rows().row(0).unwrap().get("n"),
            r2.rows().row(0).unwrap().get("how_many")
        );
        // counters surface in the metrics
        assert_eq!(
            r2.metrics().plan_cache,
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        // a literal change misses
        handle
            .prepare("from supplier | filter s_suppkey < 51 | aggregate count() as n")
            .unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 2 }
        );
    }

    #[test]
    fn prepare_rejects_multi_block_programs_and_unknown_names() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let program = "query a from part | filter true ; query b from supplier | filter true";
        match handle.prepare(program) {
            Err(PimdbError::ExpectedSingleQuery { found }) => assert_eq!(found, 2),
            other => panic!("expected ExpectedSingleQuery, got {:?}", other.map(|_| ())),
        }
        assert_eq!(handle.prepare_all(program).unwrap().len(), 2);
        assert!(matches!(
            handle.prepare(QuerySource::Tpch("Q99")),
            Err(PimdbError::UnknownQuery(_))
        ));
        assert!(matches!(
            handle.prepare("from lineitem | filter nope < 3"),
            Err(PimdbError::Parse { .. })
        ));
    }

    #[test]
    fn dml_prepares_cache_and_execute_mutates_the_pim_copy() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let src = "update supplier set s_nationkey = 3 where s_suppkey <= 10";
        let p1 = handle.prepare_dml(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 0, misses: 1 }
        );
        let p2 = handle.prepare_dml(src).unwrap();
        assert_eq!(
            handle.plan_cache_counters(),
            PlanCacheCounters { hits: 1, misses: 1 }
        );
        assert_eq!(p2.dml().kind_name(), "update");
        let r = p1.execute().unwrap();
        assert_eq!(r.rows_affected, 10);
        assert!(r.wear_delta > 0.0);
        assert!(r.metrics.exec_time_s > 0.0);
        // the rewrite is visible to queries through the same handle
        let n = handle
            .prepare(
                "from supplier | filter s_nationkey == 3 and s_suppkey <= 10 \
                 | aggregate count() as n",
            )
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(n.raw_report().output.groups[0].count, 10);
        // a literal change is a different DML plan (cache miss)
        handle
            .prepare_dml("update supplier set s_nationkey = 4 where s_suppkey <= 10")
            .unwrap();
        let c = handle.plan_cache_counters();
        assert_eq!(c.misses, 3); // 2 dml templates + 1 query
        // query text given to prepare_dml is a typed parse error
        assert!(matches!(
            handle.prepare_dml("from supplier | filter true"),
            Err(PimdbError::Parse { .. })
        ));
        // AST-built DML on a DRAM-resident relation is a typed error,
        // not a layout panic
        let dram = Dml::Delete {
            rel: crate::db::schema::RelId::Nation,
            filter: crate::query::ast::Pred::True,
        };
        assert!(matches!(
            handle.execute_dml(&dram),
            Err(PimdbError::Compile(CompileError::NotPimResident { .. }))
        ));
        // clear_plan_cache drops DML plans too: re-preparing recompiles
        handle.clear_plan_cache();
        handle.prepare_dml(src).unwrap();
        assert_eq!(handle.plan_cache_counters().misses, 4);
    }

    #[test]
    fn queries_on_mutated_relations_accumulate_wear() {
        use crate::db::schema::RelId;
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        // pristine relation: no wear tracking yet
        assert!(handle.wear_counters(RelId::Supplier).is_empty());
        handle
            .execute_dml("delete from supplier where s_suppkey == 1")
            .unwrap();
        let w1: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
        assert!(w1 > 0, "DML charges wear");
        handle
            .prepare("from supplier | filter s_acctbal > 0.00 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        let w2: u64 = handle.wear_counters(RelId::Supplier).iter().sum();
        assert!(w2 > w1, "queries on mutated relations charge wear too");
        // other relations stay untracked until mutated
        assert!(handle.wear_counters(RelId::Part).is_empty());
    }

    #[test]
    fn dml_matches_the_legacy_session_path() {
        use crate::db::schema::RelId;
        use crate::query::lang::parse_dml;
        let cfg = SystemConfig::default();
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let handle = Pimdb::open(cfg.clone(), db()).unwrap();
        let statements = [
            "delete from supplier where s_acctbal < 100.00",
            "update supplier set s_phone_cc = 11 where s_nationkey == 1",
            "insert into supplier (s_suppkey, s_acctbal) values (9000, 50.00)",
        ];
        for src in statements {
            let dml = parse_dml(src).unwrap();
            let a = legacy.run_dml(&dml, EngineKind::Native).unwrap();
            let b = handle.execute_dml(&dml).unwrap();
            assert_eq!(a.rows_affected, b.rows_affected, "{src}");
            assert_eq!(a.wear_delta.to_bits(), b.wear_delta.to_bits(), "{src}");
            assert_eq!(
                a.metrics.exec_time_s.to_bits(),
                b.metrics.exec_time_s.to_bits(),
                "{src}"
            );
        }
        assert_eq!(
            legacy.live_records(RelId::Supplier),
            handle.live_records(RelId::Supplier)
        );
        // queries agree on the mutated state
        let q = tpch::query("Q11").unwrap();
        let a = legacy.run_query(&q, EngineKind::Native).unwrap();
        let b = handle.prepare(QuerySource::Ast(&q)).unwrap().execute().unwrap();
        assert_eq!(a.output, b.raw_report().output);
    }

    #[test]
    fn concurrent_execution_from_shared_reference() {
        let cfg = SystemConfig {
            parallelism: 2,
            ..SystemConfig::default()
        };
        let data = db();
        let mut legacy = PimSession::new(&cfg, &data).unwrap();
        let want_q6 = legacy
            .run_query(&tpch::query("Q6").unwrap(), EngineKind::Native)
            .unwrap();
        let want_q11 = legacy
            .run_query(&tpch::query("Q11").unwrap(), EngineKind::Native)
            .unwrap();

        let handle = Arc::new(Pimdb::open(cfg.clone(), db()).unwrap());
        let q6 = handle.prepare(QuerySource::Tpch("Q6")).unwrap();
        let q11 = handle.prepare(QuerySource::Tpch("Q11")).unwrap();
        std::thread::scope(|s| {
            let t6 = s.spawn(|| q6.execute().unwrap());
            let t11 = s.spawn(|| q11.execute().unwrap());
            let r6 = t6.join().unwrap();
            let r11 = t11.join().unwrap();
            assert_eq!(r6.raw_report().output, want_q6.output);
            assert_eq!(r11.raw_report().output, want_q11.output);
            assert_eq!(
                r6.metrics().exec_time_s.to_bits(),
                want_q6.metrics.exec_time_s.to_bits()
            );
        });
        // re-executing after the concurrent burst still matches
        let again = q6.execute().unwrap();
        assert_eq!(again.raw_report().output, want_q6.output);
    }

    #[test]
    fn shared_scans_replay_cached_filter_prefixes() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let count_src = "from supplier | filter s_suppkey < 50 | aggregate count() as n";
        let sum_src = "from supplier | filter s_suppkey < 50 | aggregate sum(s_acctbal) as s";
        let p_count = handle.prepare(count_src).unwrap();
        let p_sum = handle.prepare(sum_src).unwrap();
        // distinct plans over one relation share a canonical prefix key:
        // the suffix differs (count vs sum), the mask function does not
        let s1 = p_count.plan.scans[0].as_ref().expect("count plan is shareable");
        let s2 = p_sum.plan.scans[0].as_ref().expect("sum plan is shareable");
        assert!(s1.prefix_len > 0);
        assert_eq!(s1.key, s2.key, "same filter must normalize to one key");

        // oracle outputs from fresh handles (nothing cached, full runs)
        let fresh = |src: &str| {
            Pimdb::open(SystemConfig::default(), db())
                .unwrap()
                .prepare(src)
                .unwrap()
                .execute()
                .unwrap()
                .raw_report()
                .output
                .clone()
        };
        let want_count = fresh(count_src);
        let want_sum = fresh(sum_src);

        let r1 = p_count.execute().unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 1,
                invalidations: 0
            }
        );
        // second statement replays the cached mask, runs only its suffix
        let r2 = p_sum.execute().unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 1,
                misses: 1,
                invalidations: 0
            }
        );
        assert_eq!(r1.raw_report().output, want_count);
        assert_eq!(r2.raw_report().output, want_sum);

        // re-executing the first statement is a hit too, still exact
        let r3 = p_count.execute().unwrap();
        assert_eq!(r3.raw_report().output, want_count);
        assert_eq!(handle.shared_scan_counters().hits, 2);

        // a different literal is a different mask function: full run
        handle
            .prepare("from supplier | filter s_suppkey < 51 | aggregate count() as n")
            .unwrap()
            .execute()
            .unwrap();
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 2,
                misses: 2,
                invalidations: 0
            }
        );
    }

    #[test]
    fn dml_invalidates_cached_scan_masks() {
        let handle = Pimdb::open(SystemConfig::default(), db()).unwrap();
        let p = handle
            .prepare("from supplier | filter s_suppkey <= 10 | aggregate count() as n")
            .unwrap();
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 10);
        assert_eq!(handle.shared_scan_counters().misses, 1);
        // DML drops the relation's cached masks
        handle
            .execute_dml("delete from supplier where s_suppkey == 5")
            .unwrap();
        assert_eq!(handle.shared_scan_counters().invalidations, 1);
        // the re-run cannot replay the stale mask: it sees the deletion
        assert_eq!(p.execute().unwrap().raw_report().output.groups[0].count, 9);
        assert_eq!(
            handle.shared_scan_counters(),
            SharedScanCounters {
                hits: 0,
                misses: 2,
                invalidations: 1
            }
        );
    }
}
